"""Hot-path contracts for the fused selection engine (ISSUE 2):

  * candidate-gather gains: ``gains_at(state, K, cand) == gains(state, K)[cand]``
    for all four set functions (and their Pallas / gram-free variants),
  * vmapped SGE bank == sequential SGE under fixed keys,
  * gram-free facility location == Gram-materializing facility location
    (kernel vs ref on padded/odd shapes; greedy trajectories on fixtures),
  * power-of-two bucketing is exact masking (padded elements never selected,
    deterministic trajectories bit-equal to the unpadded run, one compile
    per bucket instead of one per class size),
  * blocked Gram assembly is the same function in every block for the
    data-dependent ``dot``/``rbf`` metrics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MiloPreprocessor,
    get_gram_free,
    gram_matrix,
    gram_matrix_blocked,
    greedy,
    greedy_importance,
    sge,
    stochastic_greedy,
)
from repro.core import lazy_greedy
from repro.core.gram_free import make_gram_free_facility_location
from repro.core.greedy import _NEG, _sge_bank, stochastic_candidate_count
from repro.core.similarity import normalize_rows
from repro.core.submodular import (
    disparity_min,
    disparity_sum,
    facility_location,
    gains_at,
    graph_cut,
    make_facility_location_pallas,
)

RNG = np.random.default_rng(0)

GRAM_FNS = {
    "facility_location": facility_location,
    "graph_cut": graph_cut,
    "disparity_sum": disparity_sum,
    "disparity_min": disparity_min,
}


def _fixture(n: int, d: int = 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    return z, gram_matrix(z)


# ---------------------------------------------------------------------------
# candidate-gather gains
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(GRAM_FNS))
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_gains_at_matches_full_gains(name, seed):
    """The O(n·s) gather path must agree with gains(state)[cand] bit-exactly,
    at several states along a greedy run and for duplicate candidates."""
    fn = GRAM_FNS[name]
    n = 48
    _, K = _fixture(n, seed=seed)
    rng = np.random.default_rng(seed)
    state = fn.init(K)
    for j in rng.permutation(n)[:6]:
        cand = jnp.asarray(rng.integers(0, n, size=13))  # duplicates allowed
        full = np.asarray(fn.gains(state, K))[np.asarray(cand)]
        fast = np.asarray(gains_at(fn, state, K, cand))
        np.testing.assert_array_equal(full, fast, err_msg=name)
        state = fn.update(state, K, jnp.asarray(j))


def test_gains_at_fallback_without_implementation():
    """A SetFunction without gains_at falls back to the full-gains gather."""
    fn = dataclasses.replace(facility_location, gains_at=None)
    _, K = _fixture(32)
    state = fn.init(K)
    cand = jnp.asarray([3, 7, 7, 0])
    np.testing.assert_array_equal(
        np.asarray(gains_at(fn, state, K, cand)),
        np.asarray(fn.gains(state, K))[np.asarray(cand)],
    )


def test_gains_at_pallas_facility_location():
    fn = make_facility_location_pallas(interpret=True, block_i=32, block_j=32)
    _, K = _fixture(64)
    state = fn.init(K)
    state = fn.update(state, K, jnp.asarray(5))
    cand = jnp.asarray([1, 9, 33, 63])
    np.testing.assert_allclose(
        np.asarray(gains_at(fn, state, K, cand)),
        np.asarray(facility_location.gains(state, K))[np.asarray(cand)],
        rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("name", sorted(GRAM_FNS))
def test_gains_at_gram_free(name):
    fn = get_gram_free(name)
    z, _ = _fixture(40)
    zn = normalize_rows(z)
    state = fn.init(zn)
    rng = np.random.default_rng(3)
    for j in [2, 11, 29]:
        cand = jnp.asarray(rng.integers(0, 40, size=9))
        np.testing.assert_allclose(
            np.asarray(gains_at(fn, state, zn, cand)),
            np.asarray(fn.gains(state, zn))[np.asarray(cand)],
            rtol=1e-6, atol=1e-6, err_msg=name,
        )
        state = fn.update(state, zn, jnp.asarray(j))


def test_stochastic_greedy_gather_matches_legacy_full_path():
    """Candidate-gather stochastic greedy follows the identical trajectory as
    the legacy full-gains evaluation under the same key."""
    _, K = _fixture(120, seed=4)
    k = 15
    s = stochastic_candidate_count(120, k, 0.01)
    legacy_fn = dataclasses.replace(facility_location, gains_at=None)
    key = jax.random.PRNGKey(11)
    a = stochastic_greedy(facility_location, K, k, key, s=s)
    b = stochastic_greedy(legacy_fn, K, k, key, s=s)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))


# ---------------------------------------------------------------------------
# vmapped SGE bank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["facility_location", "graph_cut"])
def test_sge_vmapped_equals_sequential(name):
    fn = GRAM_FNS[name]
    _, K = _fixture(90, seed=5)
    key = jax.random.PRNGKey(3)
    a = np.asarray(sge(fn, K, 12, key, n_subsets=5, vmapped=True))
    b = np.asarray(sge(fn, K, 12, key, n_subsets=5, vmapped=False))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (5, 12)
    # distinct near-optimal subsets, no duplicate indices within a run
    for run in a:
        assert len(set(run.tolist())) == 12
    assert len({tuple(r.tolist()) for r in a}) > 1


def test_sge_vmapped_is_one_compilation_per_shape():
    _, K = _fixture(64, seed=6)
    before = _sge_bank._cache_size()
    for seed in range(3):
        sge(facility_location, K, 8, jax.random.PRNGKey(seed), n_subsets=4)
    assert _sge_bank._cache_size() - before == 1


# ---------------------------------------------------------------------------
# gram-free facility location
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,ncand,d", [(128, 128, 16), (700, 321, 48),
                                       (65, 1000, 24), (1, 1, 8), (300, 1, 7)])
def test_gram_free_kernel_vs_ref_odd_shapes(n, ncand, d):
    """Pallas gram-free gains == pure-jnp oracle on padded/odd shapes
    (n not a multiple of the block, singleton ground sets/candidates)."""
    from repro.kernels.fl_gains import ops as fl_ops
    from repro.kernels.fl_gains.ref import fl_gains_gram_free_ref

    rng = np.random.default_rng(n + ncand)
    z = normalize_rows(jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)))
    zc = normalize_rows(jnp.asarray(rng.normal(size=(ncand, d)).astype(np.float32)))
    c = jnp.asarray(rng.uniform(size=(n,)).astype(np.float32))
    out = fl_ops.fl_gains_gram_free(z, zc, c, block_i=256, block_j=256,
                                    interpret=True)
    ref = fl_gains_gram_free_ref(z, zc, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)
    assert np.all(np.asarray(out) >= -1e-3)


@pytest.mark.parametrize("name", sorted(GRAM_FNS))
def test_gram_free_greedy_trajectory_matches_gram(name):
    """Acceptance: the gram-free path selects trajectories identical to the
    Gram-materializing path on test fixtures — with O(n·d + n) state instead
    of the (n, n) kernel."""
    z, K = _fixture(160, d=24, seed=7)
    zn = normalize_rows(z)
    a = np.asarray(greedy(GRAM_FNS[name], K, 16).indices)
    b = np.asarray(greedy(get_gram_free(name), zn, 16).indices)
    np.testing.assert_array_equal(a, b, err_msg=name)


def test_gram_free_pallas_fl_greedy_trajectory():
    z, K = _fixture(96, seed=8)
    zn = normalize_rows(z)
    fn = make_gram_free_facility_location(use_pallas=True, interpret=True,
                                          block_i=32, block_j=32)
    a = np.asarray(greedy(facility_location, K, 8).indices)
    b = np.asarray(greedy(fn, zn, 8).indices)
    np.testing.assert_array_equal(a, b)


def test_gram_free_sge_matches_gram_sge():
    """SGE with the MILO default easy function (graph-cut), fixed key."""
    z, K = _fixture(150, seed=9)
    zn = normalize_rows(z)
    key = jax.random.PRNGKey(21)
    a = np.asarray(sge(graph_cut, K, 15, key, n_subsets=4))
    b = np.asarray(sge(get_gram_free("graph_cut"), zn, 15, key, n_subsets=4))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# power-of-two bucketing / exact masking
# ---------------------------------------------------------------------------

def _pad_problem(K: jnp.ndarray, n_pad: int):
    n = K.shape[0]
    Kp = jnp.zeros((n_pad, n_pad), K.dtype).at[:n, :n].set(K)
    return Kp, jnp.arange(n_pad) < n


@pytest.mark.parametrize("name", sorted(GRAM_FNS))
def test_valid_mask_greedy_is_exact(name):
    """Zero-padding + valid mask reproduces the unpadded greedy trajectory
    and never selects a padded element.  (Gains agree to reduction-order
    rounding: the padded rows contribute exact zeros, but XLA may regroup
    the longer sum.)"""
    fn = GRAM_FNS[name]
    _, K = _fixture(75, seed=10)   # 75 -> bucket 128
    Kp, valid = _pad_problem(K, 128)
    r = greedy(fn, K, 12)
    rp = greedy(fn, Kp, 12, valid=valid)
    np.testing.assert_array_equal(np.asarray(r.indices), np.asarray(rp.indices))
    np.testing.assert_allclose(np.asarray(r.gains), np.asarray(rp.gains),
                               rtol=1e-5, atol=1e-6)
    assert np.asarray(rp.indices).max() < 75


@pytest.mark.parametrize("name", ["disparity_min", "facility_location"])
def test_valid_mask_importance_is_exact(name):
    fn = GRAM_FNS[name]
    _, K = _fixture(51, seed=11)
    Kp, valid = _pad_problem(K, 64)
    g = np.asarray(greedy_importance(fn, K))
    gp = np.asarray(greedy_importance(fn, Kp, valid=valid))[:51]
    np.testing.assert_allclose(g, gp, rtol=1e-5, atol=1e-6)


def test_valid_mask_sge_never_selects_padding():
    _, K = _fixture(70, seed=12)
    Kp, valid = _pad_problem(K, 128)
    subs = np.asarray(sge(graph_cut, Kp, 9, jax.random.PRNGKey(5),
                          n_subsets=6, valid=valid))
    assert subs.max() < 70
    for run in subs:
        assert len(set(run.tolist())) == 9


def test_bucketed_preprocessor_compiles_once_per_bucket():
    """8 distinct class sizes in the same pow2 bucket must not trigger 8
    recompiles of the SGE bank."""
    sizes = [33, 35, 37, 41, 45, 51, 57, 61]  # all bucket to 64
    labels = np.concatenate([np.full(s, i) for i, s in enumerate(sizes)])
    rng = np.random.default_rng(13)
    feats = rng.normal(size=(len(labels), 8)).astype(np.float32)
    before = _sge_bank._cache_size()
    md = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=3).preprocess(
        feats, labels, jax.random.PRNGKey(2)
    )
    added = _sge_bank._cache_size() - before
    assert added <= 3, f"{added} compiles for 8 same-bucket class sizes"
    # budgets respected and every selection in range
    assert md.class_budgets.sum() == md.k
    for s in md.sge_subsets:
        assert len(set(s.tolist())) == md.k


def test_bucketed_importance_matches_unbucketed_preprocess():
    rng = np.random.default_rng(14)
    feats = rng.normal(size=(300, 12)).astype(np.float32)
    labels = rng.integers(0, 4, size=300)
    md_b = MiloPreprocessor(subset_fraction=0.1, bucket_classes=True).preprocess(
        feats, labels, jax.random.PRNGKey(0))
    md_u = MiloPreprocessor(subset_fraction=0.1, bucket_classes=False).preprocess(
        feats, labels, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(md_b.wre_importance, md_u.wre_importance)
    np.testing.assert_allclose(md_b.wre_probs, md_u.wre_probs, rtol=1e-6)


def test_preprocessor_gram_free_matches_gram_path():
    rng = np.random.default_rng(15)
    feats = rng.normal(size=(240, 16)).astype(np.float32)
    labels = rng.integers(0, 3, size=240)
    md_g = MiloPreprocessor(subset_fraction=0.1).preprocess(
        feats, labels, jax.random.PRNGKey(1))
    md_f = MiloPreprocessor(subset_fraction=0.1, gram_free=True).preprocess(
        feats, labels, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(md_g.sge_subsets, md_f.sge_subsets)
    np.testing.assert_allclose(md_g.wre_importance, md_f.wre_importance,
                               rtol=2e-3, atol=2e-3)
    assert md_f.config["gram_free"] is True


def test_preprocessor_gram_free_rejects_non_cosine():
    with pytest.raises(ValueError, match="cosine"):
        MiloPreprocessor(gram_free=True, metric="rbf").preprocess(
            np.ones((10, 4), np.float32), np.zeros(10, np.int64),
            jax.random.PRNGKey(0))


def test_single_partition_skips_bucketing():
    """With one partition there is exactly one problem shape, so bucketing
    would only inflate memory/steps — the draw must match bucket_classes=False
    exactly (which is also the pre-bucketing behavior for a fixed seed)."""
    rng = np.random.default_rng(19)
    feats = rng.normal(size=(333, 8)).astype(np.float32)  # not a pow2
    a = MiloPreprocessor(subset_fraction=0.1, classwise=False,
                         n_sge_subsets=2).preprocess(
        feats, None, jax.random.PRNGKey(0))
    b = MiloPreprocessor(subset_fraction=0.1, classwise=False, n_sge_subsets=2,
                         bucket_classes=False).preprocess(
        feats, None, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(a.sge_subsets, b.sge_subsets)
    np.testing.assert_array_equal(a.wre_importance, b.wre_importance)


def test_preprocessor_singleton_class():
    """A class with a single member (bucket size 1) must survive bucketing
    and the gram-free route."""
    rng = np.random.default_rng(16)
    feats = rng.normal(size=(41, 8)).astype(np.float32)
    labels = np.concatenate([np.zeros(40, np.int64), np.ones(1, np.int64)])
    for gram_free in (False, True):
        md = MiloPreprocessor(subset_fraction=0.2, gram_free=gram_free).preprocess(
            feats, labels, jax.random.PRNGKey(3))
        for s in md.sge_subsets:
            assert len(set(s.tolist())) == md.k
            assert s.max() < 41
        assert np.isfinite(md.wre_probs).all()


# ---------------------------------------------------------------------------
# lazy gain reuse (greedy.lazy_greedy / greedy_importance(lazy_budget=...))
# ---------------------------------------------------------------------------

def _fl_fixtures(n: int, d: int = 16, seed: int = 20):
    z, K = _fixture(n, d=d, seed=seed)
    return {"gram": (facility_location, K),
            "gram_free": (make_gram_free_facility_location(), normalize_rows(z))}


@pytest.mark.parametrize("variant", ["gram", "gram_free"])
def test_lazy_greedy_matches_exact_trajectory(variant):
    """Within the shortlist horizon (k = n/4) the cached-gain engine picks
    identically to eager greedy; gains agree to reduction-order rounding."""
    fn, K = _fl_fixtures(192)[variant]
    k, budget = 48, 24
    a = greedy(fn, K, k)
    b = lazy_greedy(fn, K, k, budget=budget)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_allclose(np.asarray(a.gains), np.asarray(b.gains),
                               rtol=1e-5, atol=1e-6)


def test_lazy_greedy_counter_reduction():
    """Acceptance mechanism: the traced counter shows >= 3x fewer ground-row
    contractions than the eager engine's n-per-step on a full FL pass."""
    fn, z = _fl_fixtures(256)["gram_free"]
    n = 256
    res = lazy_greedy(fn, z, n, budget=n // 8)
    rows = np.asarray(res.rows_evaluated)
    assert set(rows.tolist()) <= {n // 8, n}
    eager_evals = n * n
    lazy_evals = n + rows.sum()  # + the init-time full evaluation
    assert eager_evals / lazy_evals >= 3.0, (eager_evals, lazy_evals)
    # early steps overflow the touched budget (full recompute), late steps
    # stay within it — the decaying-touched-set structure the engine exploits
    assert rows[0] == n and rows[-1] == n // 8


def test_lazy_greedy_importance_equivalent_order():
    """A full lazy pass reaches exhaustion: the greedy order may resolve
    sub-ulp near-ties differently from the eager pass (documented), but it
    selects the same elements with the same gain sequence."""
    fn, z = _fl_fixtures(160)["gram_free"]
    a = greedy(fn, z, 160)
    b = lazy_greedy(fn, z, 160, budget=20)
    assert set(np.asarray(a.indices).tolist()) == set(np.asarray(b.indices).tolist())
    np.testing.assert_allclose(np.asarray(a.gains), np.asarray(b.gains),
                               rtol=1e-4, atol=1e-5)
    ia = np.asarray(greedy_importance(fn, z))
    ib = np.asarray(greedy_importance(fn, z, lazy_budget=20))
    np.testing.assert_allclose(np.sort(ia), np.sort(ib), rtol=1e-4, atol=1e-5)


def test_lazy_greedy_importance_bucketed_padding():
    """Lazy reuse composes with size bucketing: padded rows are never touched
    (infinite cover), padded elements never selected, importance 0."""
    fn, z = _fl_fixtures(128)["gram_free"]
    zp = jnp.zeros((160, z.shape[1]), z.dtype).at[:128].set(z)
    valid = jnp.arange(160) < 128
    g = np.asarray(greedy_importance(fn, zp, valid=valid, lazy_budget=16))
    assert np.all(g[128:] == 0.0)
    ref = np.asarray(greedy_importance(fn, z, lazy_budget=16))
    np.testing.assert_allclose(np.sort(g[:128]), np.sort(ref), rtol=1e-4, atol=1e-5)


def test_lazy_greedy_requires_hooks():
    _, K = _fixture(32)
    with pytest.raises(ValueError, match="lazy hooks"):
        lazy_greedy(graph_cut, K, 4, budget=8)


def test_lazy_verify_argmax_restores_exact_near_ties():
    """CELF re-verification (verify_argmax=True): force the documented
    sub-ulp failure mode — cached-gain drift flipping an exact near-tie —
    and check the verified engine matches eager greedy bit-for-bit.

    Rows 12 and 40 are exact duplicates, so their true FL gains are
    bit-equal at every step and eager argmax always takes the LOWER index.
    A drifting ``delta_gains`` hook bumps the higher duplicate's cached
    gain by ~2 float32 ulps per lazy step, so the plain cached engine picks
    40 over 12 when the pair reaches the argmax; exact shortlist
    re-verification restores greedy's trajectory exactly (indices AND
    gains)."""
    from repro.core.submodular import LazyHooks, _fl_delta_gains

    n, d, k = 64, 8, 40
    rng = np.random.default_rng(11)
    z = rng.normal(size=(n, d)).astype(np.float32)
    z[40] = z[12]
    K = gram_matrix(jnp.asarray(z))
    bump = (jnp.arange(n) == 40).astype(jnp.float32) * 1e-6

    def drifting_delta(Km, rows, c_old, c_new):
        return _fl_delta_gains(Km, rows, c_old, c_new) + bump

    fn_drift = dataclasses.replace(
        facility_location, name="fl_drifting",
        lazy=LazyHooks(cover=lambda c: c, delta_gains=drifting_delta),
    )

    a = greedy(facility_location, K, k)
    ia = np.asarray(a.indices).tolist()
    assert 12 in ia, "fixture: the duplicate pair must be reached"
    # budget=n keeps every step on the lazy path, so the injected drift is
    # never reset by a full-recompute fallback
    plain = lazy_greedy(fn_drift, K, k, budget=n)
    assert np.asarray(plain.indices).tolist() != ia, (
        "fixture: the drift must actually flip the near-tie")
    ver = lazy_greedy(fn_drift, K, k, budget=n, verify_argmax=True)
    np.testing.assert_array_equal(np.asarray(ver.indices), np.asarray(a.indices))
    # gains are the exact re-evaluated ones: equal to greedy's to the
    # reduction-order ulp (the gather and full-matrix reductions may
    # round differently), nowhere near the injected drift
    np.testing.assert_allclose(np.asarray(ver.gains), np.asarray(a.gains),
                               rtol=3e-7, atol=1e-9)
    # the un-drifted engine also survives verification unchanged
    ver2 = lazy_greedy(facility_location, K, k, budget=n // 4,
                       verify_argmax=True)
    np.testing.assert_array_equal(np.asarray(ver2.indices), np.asarray(a.indices))
    np.testing.assert_allclose(np.asarray(ver2.gains), np.asarray(a.gains),
                               rtol=3e-7, atol=1e-9)


def test_lazy_budget_ignored_without_hooks():
    """greedy_importance(lazy_budget=...) on a hook-less function falls back
    to the eager pass instead of erroring (preprocessor wiring relies on it)."""
    _, K = _fixture(48)
    a = np.asarray(greedy_importance(disparity_min, K))
    b = np.asarray(greedy_importance(disparity_min, K, lazy_budget=8))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# post-exhaustion step guard (bucketed greedy_importance satellite)
# ---------------------------------------------------------------------------

def test_exhaustion_guard_skips_gain_evaluations():
    """The lax.cond guard must stop evaluating gains after the valid pool is
    exhausted: a callback-counting set function sees exactly n_valid calls on
    an n_pad-step bucketed importance run — with identical outputs."""
    calls = []

    def counting_gains(state, K):
        jax.debug.callback(lambda: calls.append(1))
        return disparity_min.gains(state, K)

    fn = dataclasses.replace(disparity_min, gains=counting_gains)
    _, K = _fixture(51, seed=11)
    Kp, valid = _pad_problem(K, 64)
    g = greedy_importance(fn, Kp, valid=valid)
    jax.effects_barrier()
    assert len(calls) == 51, f"guard leaked {len(calls) - 51} padded-step evals"
    ref = greedy_importance(disparity_min, Kp, valid=valid)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(ref))


def test_exhaustion_guard_emits_sentinel_outputs():
    """Skipped steps record (index 0, _NEG) — exactly what the unguarded
    degenerate argmax produced, so the importance scatter is unchanged."""
    _, K = _fixture(20, seed=12)
    Kp, valid = _pad_problem(K, 32)
    r = greedy(facility_location, Kp, 32, valid=valid)
    assert np.all(np.asarray(r.indices)[20:] == 0)
    assert np.all(np.asarray(r.gains)[20:] == _NEG)
    np.testing.assert_array_equal(
        np.asarray(r.indices)[:20],
        np.asarray(greedy(facility_location, K, 20).indices),
    )


# ---------------------------------------------------------------------------
# bucketed SGE candidate-count satellite (s from the valid geometry)
# ---------------------------------------------------------------------------

def test_sge_explicit_candidate_count():
    """sge(s=...) overrides the derived draw size and matches per-run
    stochastic greedy with the same s under the same key."""
    _, K = _fixture(90, seed=13)
    key = jax.random.PRNGKey(9)
    a = np.asarray(sge(facility_location, K, 10, key, n_subsets=3, s=7))
    keys = jax.random.split(key, 3)
    b = np.stack([
        np.asarray(stochastic_greedy(facility_location, K, 10, kk, s=7).indices)
        for kk in keys
    ])
    np.testing.assert_array_equal(a, b)


def test_exact_sge_candidates_quantifies_bucketing_approximation():
    """Bucketed SGE draws s from the padded geometry by default;
    exact_sge_candidates=True restores the per-class (n_c, k_c) draw size.
    The deterministic WRE pass is untouched either way; the stochastic bank
    changes but stays a valid near-optimal sample (quantified overlap)."""
    rng = np.random.default_rng(21)
    sizes = [75, 60, 44, 37]  # buckets 128/64/64/64: padded s != exact s
    labels = np.concatenate([np.full(s, i) for i, s in enumerate(sizes)])
    feats = rng.normal(size=(len(labels), 10)).astype(np.float32)
    key = jax.random.PRNGKey(4)
    pad = MiloPreprocessor(subset_fraction=0.2).preprocess(feats, labels, key)
    exact = MiloPreprocessor(subset_fraction=0.2,
                             exact_sge_candidates=True).preprocess(feats, labels, key)
    np.testing.assert_array_equal(pad.wre_importance, exact.wre_importance)
    assert exact.config["exact_sge_candidates"] is True
    # the draw geometry genuinely differs for at least one class...
    assert any(
        stochastic_candidate_count(s, max(1, round(0.2 * s)), 0.01)
        != stochastic_candidate_count(
            1 << (s - 1).bit_length(),
            1 << (max(1, round(0.2 * s)) - 1).bit_length(), 0.01)
        for s in sizes
    )
    # ...so the banks differ, while remaining comparable near-optimal
    # subsets of the same classes (majority overlap)
    assert not np.array_equal(pad.sge_subsets, exact.sge_subsets)
    overlap = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / pad.k
        for a, b in zip(pad.sge_subsets, exact.sge_subsets)
    ])
    assert 0.2 <= overlap < 1.0, f"overlap {overlap:.2f}"
    for s in exact.sge_subsets:
        assert len(set(s.tolist())) == exact.k


def test_exact_sge_candidates_noop_when_unbucketed():
    rng = np.random.default_rng(22)
    feats = rng.normal(size=(150, 8)).astype(np.float32)
    labels = rng.integers(0, 3, size=150)
    a = MiloPreprocessor(subset_fraction=0.1, bucket_classes=False).preprocess(
        feats, labels, jax.random.PRNGKey(1))
    b = MiloPreprocessor(subset_fraction=0.1, bucket_classes=False,
                         exact_sge_candidates=True).preprocess(
        feats, labels, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(a.sge_subsets, b.sge_subsets)


# ---------------------------------------------------------------------------
# blocked Gram metric consistency (satellite fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["cosine", "dot", "rbf"])
def test_gram_matrix_blocked_matches_unblocked(metric):
    """Each tile must use the GLOBAL shift (dot) / bandwidth (rbf), so the
    blocked assembly equals the one-shot Gram matrix."""
    rng = np.random.default_rng(17)
    z = jnp.asarray(rng.normal(size=(130, 10)).astype(np.float32))
    full = np.asarray(gram_matrix(z, metric=metric))
    blocked = np.asarray(gram_matrix_blocked(z, metric=metric, block=32))
    np.testing.assert_allclose(blocked, full, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["dot", "rbf"])
def test_gram_matrix_blocked_block_invariant(metric):
    """The assembled matrix must be the same function regardless of block
    size (the pre-fix per-tile statistics violated this)."""
    rng = np.random.default_rng(18)
    z = jnp.asarray(rng.normal(size=(97, 6)).astype(np.float32))
    a = np.asarray(gram_matrix_blocked(z, metric=metric, block=16))
    b = np.asarray(gram_matrix_blocked(z, metric=metric, block=64))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# shard/jit program caches survive across preprocess() calls (satellite fix)
# ---------------------------------------------------------------------------

def test_set_function_factories_are_memoized():
    """The engines jit with the SetFunction as a static argument, so every
    factory must return the SAME object for the same params — fresh closures
    per preprocess() call silently recompiled every engine every session."""
    from repro.core import get_gram_free, make_graph_cut
    from repro.core.gram_free import make_gram_free_graph_cut

    for name in ("facility_location", "graph_cut", "disparity_sum",
                 "disparity_min"):
        assert get_gram_free(name) is get_gram_free(name), name
    assert make_gram_free_facility_location(use_pallas=True, interpret=True) \
        is make_gram_free_facility_location(use_pallas=True, interpret=True)
    assert make_graph_cut(0.4) is make_graph_cut(0.4)
    assert make_gram_free_graph_cut(0.3) is not make_gram_free_graph_cut(0.4)


def test_second_preprocess_triggers_zero_new_compiles():
    """Cache-hit regression for the stale shard-program cache bug: an
    identical second preprocess() must reuse every compiled engine program.
    Counted via jax.monitoring's backend-compile event."""
    rng = np.random.default_rng(31)
    labels = np.repeat(np.arange(4), 25)
    feats = rng.normal(size=(100, 8)).astype(np.float32)

    def run():
        return MiloPreprocessor(
            subset_fraction=0.1, gram_free=True, lazy_gains=True,
            hard_fn="facility_location",
        ).preprocess(feats, labels, jax.random.PRNGKey(0))

    first = run()  # warm every jit cache
    compiles: list[str] = []

    def listener(name, duration, **kwargs):
        if name == "/jax/core/compile/backend_compile_duration":
            compiles.append(name)

    from jax._src import monitoring as _monitoring

    # private helper in the pinned jax; fall back to clearing every listener
    # (fine inside a test) rather than leaving ours registered forever if a
    # jax upgrade reorganizes the monitoring internals
    unregister = getattr(
        _monitoring, "_unregister_event_duration_listener_by_callback", None)
    jax.monitoring.register_event_duration_secs_listener(listener)
    try:
        second = run()
    finally:
        if unregister is not None:
            unregister(listener)
        else:  # pragma: no cover
            jax.monitoring.clear_event_listeners()
    assert compiles == [], f"second preprocess() recompiled {len(compiles)} programs"
    np.testing.assert_array_equal(first.sge_subsets, second.sge_subsets)
    np.testing.assert_array_equal(first.wre_importance, second.wre_importance)


# ---------------------------------------------------------------------------
# two-level lazy gather budget (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def test_gather_levels_cover_budget():
    from repro.core.greedy import _gather_levels

    assert _gather_levels(1) == (1,)
    assert _gather_levels(8) == (1, 2, 4, 8)
    assert _gather_levels(96) == (1, 2, 4, 8, 16, 32, 64, 96)
    for budget in (1, 3, 7, 64, 100):
        levels = _gather_levels(budget)
        assert levels[-1] == budget and sorted(levels) == list(levels)
        # every touched count m <= budget has a covering level
        assert all(any(lv >= m for lv in levels) for m in range(budget + 1))


@pytest.mark.parametrize("masked", [False, True])
def test_two_level_lazy_gather_bit_identical(masked):
    """Right-sizing the gather to the smallest covering pow2 level removes
    only exact-zero delta terms (surplus slots carry an infinite cover), so
    indices AND gains are bit-identical to the single-level path; the
    recorded per-step payload shrinks to the touched count's level."""
    fn, z = _fl_fixtures(192)["gram_free"]
    n, budget = 192, 24
    valid = jnp.arange(n) < 160 if masked else None
    a = lazy_greedy(fn, z, n, budget=budget, valid=valid)
    b = lazy_greedy(fn, z, n, budget=budget, valid=valid, two_level=True)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    ra, rb = np.asarray(a.rows_evaluated), np.asarray(b.rows_evaluated)
    # full recomputes (budget overflow) happen on exactly the same steps
    np.testing.assert_array_equal(ra == n, rb == n)
    # post-exhaustion guarded steps record 0 rows on both paths; the lazy
    # steps are the strictly-between ones
    lazy_a, lazy_b = ra[(ra > 0) & (ra < n)], rb[(rb > 0) & (rb < n)]
    assert np.all(lazy_a == budget)
    from repro.core.greedy import _gather_levels

    assert set(lazy_b.tolist()) <= set(_gather_levels(budget))
    # the payload actually shrinks on calm steps
    assert lazy_b.sum() < lazy_a.sum()


def test_two_level_importance_and_preprocessor_identical():
    """greedy_importance(lazy_two_level=True) and the preprocessor knob
    produce bit-identical artifacts to the single-level lazy path."""
    fn, z = _fl_fixtures(128)["gram_free"]
    a = greedy_importance(fn, z, lazy_budget=16)
    b = greedy_importance(fn, z, lazy_budget=16, lazy_two_level=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    rng = np.random.default_rng(40)
    feats = rng.normal(size=(120, 8)).astype(np.float32)
    labels = np.repeat(np.arange(3), 40)
    kw = dict(subset_fraction=0.2, gram_free=True, lazy_gains=True,
              hard_fn="facility_location")
    md1 = MiloPreprocessor(**kw).preprocess(feats, labels, jax.random.PRNGKey(0))
    md2 = MiloPreprocessor(lazy_two_level=True, **kw).preprocess(
        feats, labels, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(md1.sge_subsets, md2.sge_subsets)
    np.testing.assert_array_equal(md1.wre_importance, md2.wre_importance)
    np.testing.assert_array_equal(md1.wre_probs, md2.wre_probs)
    assert md2.config["lazy_two_level"] is True


# ---------------------------------------------------------------------------
# shape-bucketed engine warmup (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def _count_backend_compiles(run):
    """Run ``run()`` under jax.monitoring's backend-compile event listener
    and return the number of programs it compiled."""
    compiles: list[str] = []

    def listener(name, duration, **kwargs):
        if name == "/jax/core/compile/backend_compile_duration":
            compiles.append(name)

    from jax._src import monitoring as _monitoring

    unregister = getattr(
        _monitoring, "_unregister_event_duration_listener_by_callback", None)
    jax.monitoring.register_event_duration_secs_listener(listener)
    try:
        run()
    finally:
        if unregister is not None:
            unregister(listener)
        else:  # pragma: no cover
            jax.monitoring.clear_event_listeners()
    return len(compiles)


@pytest.mark.parametrize("gram_free", [True, False])
def test_warmup_precompiles_preprocess_programs(gram_free):
    """After warmup(buckets=...) on the upcoming class geometry, the real
    preprocess() triggers ZERO backend compiles — the whole point of
    pre-compiling the (n, k, budget) engine programs at session start."""
    from repro.core.partition import partition_by_class, proportional_budgets

    rng = np.random.default_rng(41)
    labels = np.concatenate([np.repeat(np.arange(3), 30), np.full(14, 3)])
    feats = rng.normal(size=(len(labels), 8)).astype(np.float32)
    pre = MiloPreprocessor(
        subset_fraction=0.1, gram_free=gram_free, lazy_gains=gram_free,
        hard_fn="facility_location" if gram_free else "disparity_min",
    )
    parts = partition_by_class(labels)
    k = max(1, int(round(0.1 * len(labels))))
    buckets = [(len(p.indices), b)
               for p, b in zip(parts, proportional_budgets(parts, k))]
    warmed = pre.warmup(buckets, d=feats.shape[1])
    assert warmed >= 1
    md = None

    def run():
        nonlocal md
        md = pre.preprocess(feats, labels, jax.random.PRNGKey(0))

    n_compiles = _count_backend_compiles(run)
    assert n_compiles == 0, f"preprocess compiled {n_compiles} programs after warmup"
    # warmup ran on dummy data: the real artifact is built from real features
    assert md.m == len(labels) and md.k == k


def test_warmup_dedupes_repeated_geometries():
    pre = MiloPreprocessor(subset_fraction=0.1, gram_free=True)
    assert pre.warmup([(30, 3)] * 10 + [(0, 0), (5, 0)], d=4) == 1
