"""Per-arch smoke tests: reduced config, one forward/train step, shapes+finite.

Also: decode==full-forward consistency, SSD-vs-sequential recurrence, MoE
dispatch semantics, attention impl equivalence.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, shape_applies
from repro.models import lm
from repro.models.moe import init_moe, moe, moe_dropless
from repro.models.ssm import _ssd_chunk_scan
from repro.optim.optimizers import adamw
from repro.train.train_state import init_train_state, make_train_step

ARCHS = list(registry.ARCHS)


def _ctx_for(cfg, B, key=2):
    if cfg.is_encdec:
        return jax.random.normal(jax.random.PRNGKey(key), (B, cfg.encoder_seq, cfg.d_model))
    if cfg.num_context_tokens:
        return jax.random.normal(jax.random.PRNGKey(key), (B, cfg.num_context_tokens, cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_shapes_and_finite(arch):
    cfg = registry.smoke(arch)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits, _ = lm.forward(params, cfg, tokens, context=_ctx_for(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = registry.smoke(arch)
    opt = adamw()
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = make_train_step(cfg, opt, lambda s: 1e-3)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    ctx = _ctx_for(cfg, B)
    if ctx is not None:
        batch["context"] = ctx
    new_state, metrics = jax.jit(step)(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    d0 = jax.tree.leaves(state.params)[1]
    d1 = jax.tree.leaves(new_state.params)[1]
    assert not np.allclose(np.asarray(d0, np.float32), np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ["yi-6b", "xlstm-125m", "jamba-1.5-large-398b",
                                  "whisper-small", "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_full_forward(arch):
    cfg = registry.smoke(arch)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, S, CACHE = 2, 16, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    ctx = _ctx_for(cfg, B)
    full, _ = lm.forward(params, cfg, tokens, context=ctx)
    caches = lm.init_caches(cfg, B, CACHE)
    _, caches = lm.prefill(params, cfg, tokens[:, :S], caches, context=ctx)
    dec, _ = lm.decode_step(params, cfg, tokens[:, S:S + 1], caches,
                            jnp.asarray(S, jnp.int32), context=ctx)
    err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, S])))
    rel = err / (float(jnp.max(jnp.abs(full[:, S]))) + 1e-9)
    assert rel < 0.02, (arch, rel)


def test_ssd_chunk_scan_matches_sequential():
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 37, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    a = jnp.asarray(rng.uniform(0.5, 1.0, size=(B, S, H)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    y = _ssd_chunk_scan(x, a, b, c, chunk=8)
    h = np.zeros((B, H, N, P))
    ys = np.zeros((B, S, H, P))
    an, bn, cn, xn = map(np.asarray, (a, b, c, x))
    for t in range(S):
        h = an[:, t][:, :, None, None] * h + np.einsum("bn,bhp->bhnp", bn[:, t], xn[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", cn[:, t], h)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)


def test_moe_capacity_matches_dropless_when_no_drops():
    pm = init_moe(jax.random.PRNGKey(2), 32, 64, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 17, 32))
    ya = moe(pm, x, top_k=2, group_size=64, capacity_factor=8.0)
    yb = moe_dropless(pm, x, top_k=2)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_under_tight_capacity():
    pm = init_moe(jax.random.PRNGKey(2), 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16))
    tight = moe(pm, x, top_k=2, group_size=128, capacity_factor=0.25)
    loose = moe(pm, x, top_k=2, group_size=128, capacity_factor=8.0)
    assert float(jnp.max(jnp.abs(tight - loose))) > 1e-4


def test_attention_impls_agree():
    import dataclasses

    from repro.models.attention import attention, init_attention

    p = init_attention(jax.random.PRNGKey(0), 32, 4, 2, 8, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 32))
    pos = jnp.arange(40)[None, :]
    outs = {}
    for impl in ("naive", "chunked", "pallas"):
        y, _ = attention(p, x, pos, impl=impl, interpret=True)
        outs[impl] = np.asarray(y)
    np.testing.assert_allclose(outs["naive"], outs["chunked"], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["naive"], outs["pallas"], rtol=1e-4, atol=1e-4)


def test_shape_applicability_matrix():
    cells = registry.all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, ok, _ in cells if not ok]
    # exactly the pure-attention archs skip long_500k
    assert set(skipped) == {
        (a, "long_500k")
        for a in ARCHS
        if not registry.get(a).subquadratic
    }
    assert len(skipped) == 8


def test_param_counts_are_plausible():
    # published ballparks (active params): yi-6b ~6e9, yi-9b ~8.8e9,
    # internlm2 ~1.9e9, stablelm ~12e9, phi3.5-moe total ~42e9 active ~6.6e9
    c = registry.get("yi-6b").param_count()
    assert 5.5e9 < c < 7e9, c
    c = registry.get("yi-9b").param_count()
    assert 8e9 < c < 10e9, c
    c = registry.get("stablelm-12b").param_count()
    assert 10e9 < c < 13.5e9, c
    moe = registry.get("phi3.5-moe-42b-a6.6b")
    assert 38e9 < moe.param_count() < 46e9, moe.param_count()
    assert 5.5e9 < moe.active_param_count() < 8e9, moe.active_param_count()
