"""Sharded-vs-single-device selection equivalence (ISSUE 3 tentpole).

The multi-device tests need a multi-device platform, which on CPU must be
forced via ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before*
jax initializes.  Under the plain tier-1 run (one device) a wrapper test
re-invokes this file in a subprocess with the flag set — so the equivalence
suite is exercised either way; CI's sharded-smoke job also runs it directly
with the flag exported.

Equivalence contract (see core.sharded):
  * selected trajectories (indices) bit-identical for all four engines,
  * gains bit-identical for the state-only set functions (disparity sum/min:
    no cross-shard arithmetic ever combines float values),
  * gains within float32 reduction-order rounding for facility location /
    graph cut (the psum over shard partials reassociates the row sum),
  * per-device memory: the z shard holds exactly n/ndev rows.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

MULTI = jax.device_count() >= 8

multi_device = pytest.mark.skipif(
    not MULTI, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
)


@pytest.mark.skipif(MULTI, reason="already on a multi-device platform")
def test_sharded_suite_under_forced_8_device_cpu():
    """Tier-1 entry point: run this file's multi-device tests for real."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider", __file__],
        env=env, cwd=Path(__file__).parents[1], capture_output=True, text=True,
        timeout=1500,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "passed" in r.stdout and "skipped" in r.stdout  # wrapper skipped


# ---------------------------------------------------------------------------
# multi-device equivalence
# ---------------------------------------------------------------------------

def _fixture(n: int, d: int = 16, seed: int = 0) -> jnp.ndarray:
    from repro.core.similarity import normalize_rows

    rng = np.random.default_rng(seed)
    return normalize_rows(jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)))


def _mesh():
    from repro.distributed.sharding import selection_mesh

    return selection_mesh(8)


_GAINS_BIT_EXACT = {"disparity_sum", "disparity_min"}


@multi_device
@pytest.mark.parametrize(
    "name", ["facility_location", "graph_cut", "disparity_sum", "disparity_min"]
)
def test_sharded_greedy_matches_single_device(name):
    from repro.core import get_gram_free, greedy, make_sharded_gram_free, sharded_greedy

    z = _fixture(256)
    k = 24
    a = greedy(get_gram_free(name), z, k)
    b = sharded_greedy(
        make_sharded_gram_free(name, n_shards=8), z, k, mesh=_mesh()
    )
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices),
                                  err_msg=name)
    if name in _GAINS_BIT_EXACT:
        np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains),
                                      err_msg=name)
    else:
        np.testing.assert_allclose(np.asarray(a.gains), np.asarray(b.gains),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


@multi_device
@pytest.mark.parametrize("name", ["facility_location", "graph_cut"])
def test_sharded_stochastic_greedy_and_sge_bank(name):
    """The Gumbel candidate draws use the replicated key and global n, so the
    stochastic trajectories are bit-identical too — singly and vmapped."""
    from repro.core import (
        get_gram_free,
        make_sharded_gram_free,
        sge,
        sharded_sge,
        sharded_stochastic_greedy,
        stochastic_greedy,
    )
    from repro.core.greedy import stochastic_candidate_count

    z = _fixture(256, seed=1)
    k = 20
    s = stochastic_candidate_count(256, k, 0.01)
    key = jax.random.PRNGKey(7)
    fn1 = get_gram_free(name)
    fns = make_sharded_gram_free(name, n_shards=8)
    a = stochastic_greedy(fn1, z, k, key, s=s)
    b = sharded_stochastic_greedy(fns, z, k, key, s=s, mesh=_mesh())
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    bank1 = sge(fn1, z, k, key, n_subsets=3)
    bank8 = sharded_sge(fns, z, k, key, n_subsets=3, mesh=_mesh())
    np.testing.assert_array_equal(np.asarray(bank1), np.asarray(bank8))


@multi_device
def test_sharded_greedy_importance_disparity_min_bit_exact():
    """The WRE default hard function: full n-step pass incl. a bucketed valid
    mask, bit-identical importance (exhaustion guard included)."""
    from repro.core import (
        get_gram_free,
        greedy_importance,
        make_sharded_gram_free,
        sharded_greedy_importance,
    )

    z = _fixture(256, seed=2)
    valid = jnp.arange(256) < 200
    zp = z.at[200:].set(0.0)
    fn1 = get_gram_free("disparity_min")
    fns = make_sharded_gram_free("disparity_min", n_shards=8)
    a = greedy_importance(fn1, zp, valid=valid)
    b = sharded_greedy_importance(fns, zp, mesh=_mesh(), valid=valid)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.all(np.asarray(a)[200:] == 0.0)


@multi_device
def test_sharded_greedy_importance_facility_location():
    from repro.core import (
        get_gram_free,
        greedy_importance,
        make_sharded_gram_free,
        sharded_greedy_importance,
    )

    z = _fixture(128, seed=3)
    a = greedy_importance(get_gram_free("facility_location"), z)
    b = sharded_greedy_importance(
        make_sharded_gram_free("facility_location", n_shards=8), z, mesh=_mesh()
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# sharded lazy gains (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------

@multi_device
@pytest.mark.parametrize("n,seed,masked", [(256, 0, False), (256, 1, False),
                                           (128, 3, False), (128, 4, True)])
def test_sharded_lazy_greedy_matches_single_device_lazy(n, seed, masked):
    """Shortlist-horizon lazy runs: indices bit-identical, gains within the
    documented ≤1 ulp (the ring psum reassociates the cached base gains; the
    delta corrections themselves are bit-exact), and the traced
    rows-evaluated counter identical — the delta path really ran under
    shard_map (a silent eager fallback would charge n rows every step)."""
    from repro.core import (
        get_gram_free,
        lazy_greedy,
        make_sharded_gram_free,
        sharded_lazy_greedy,
    )

    z = _fixture(n, seed=seed)
    valid = None
    if masked:
        n_live = n - n // 4
        z = z.at[n_live:].set(0.0)
        valid = jnp.arange(n) < n_live
    k, budget = n // 4, n // 8
    fn1 = get_gram_free("facility_location")
    fns = make_sharded_gram_free("facility_location", n_shards=8)
    a = lazy_greedy(fn1, z, k, budget=budget, valid=valid)
    b = sharded_lazy_greedy(fns, z, k, budget=budget, mesh=_mesh(),
                            valid=valid)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_allclose(np.asarray(a.gains), np.asarray(b.gains),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.rows_evaluated),
                                  np.asarray(b.rows_evaluated))
    # at least one step must have taken the lazy path for this to prove
    # anything; budget = n/8 guarantees it on these fixtures
    assert (np.asarray(b.rows_evaluated) == budget).any()


@multi_device
def test_sharded_lazy_importance_full_run_matches():
    """The composed WRE pass (sharded_greedy_importance(lazy_budget=...)):
    full exhaustive run over the ground set, importance equal to the
    single-device lazy pass to float-rounding ulps on the fixture (near-tie
    caveat documented in greedy.lazy_greedy applies only past the fixture's
    argmax gaps)."""
    from repro.core import (
        get_gram_free,
        greedy_importance,
        make_sharded_gram_free,
        sharded_greedy_importance,
    )

    z = _fixture(128, seed=3)
    fn1 = get_gram_free("facility_location")
    fns = make_sharded_gram_free("facility_location", n_shards=8)
    a = greedy_importance(fn1, z, lazy_budget=16)
    b = sharded_greedy_importance(fns, z, mesh=_mesh(), lazy_budget=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(a) == 0.0).tolist() == (np.asarray(b) == 0.0).tolist()


@multi_device
def test_ring_schedule_issues_exactly_n_shards_minus_one_hops():
    """The over-rotation fix (ROADMAP PR-3 follow-up): the first ring block
    is the shard's own z_local, so a full-gains evaluation must contain
    exactly n_shards - 1 ppermute eqns — statically countable now that the
    schedule is unrolled over the static shard count — and stay bit-exact
    against the psum-combined reference reduction."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import get_gram_free, make_sharded_gram_free

    z = _fixture(256, seed=5)
    mesh = _mesh()
    fns = make_sharded_gram_free("facility_location", n_shards=8)

    def full_gains(zs):
        return fns.gains(fns.init(zs), zs)

    run = shard_map(full_gains, mesh=mesh, in_specs=P("sel", None),
                    out_specs=P(None), check_rep=False)
    jaxpr = str(jax.make_jaxpr(run)(z))
    assert jaxpr.count("ppermute") == 7
    fn1 = get_gram_free("facility_location")
    np.testing.assert_allclose(np.asarray(jax.jit(run)(z)),
                               np.asarray(fn1.gains(fn1.init(z), z)),
                               rtol=1e-6, atol=1e-6)


@multi_device
def test_preprocessor_lazy_plus_sharded_composes():
    """MiloPreprocessor(lazy_gains=True, shard_selection=True) routes large
    classes through the sharded lazy engine (no silent eager fallback) and
    reproduces the single-device lazy artifact: SGE bank bit-identical,
    WRE importance within reduction-order ulps."""
    from repro.core import MiloPreprocessor
    from repro.core import sharded as sharded_mod

    rng = np.random.default_rng(14)
    sizes = [97, 83, 70, 45, 5]  # buckets 128/128/128/64/8 + a tiny class
    labels = np.concatenate([np.full(s, i) for i, s in enumerate(sizes)])
    feats = rng.normal(size=(len(labels), 12)).astype(np.float32)
    key = jax.random.PRNGKey(0)
    kw = dict(subset_fraction=0.1, gram_free=True, lazy_gains=True,
              hard_fn="facility_location")
    base = MiloPreprocessor(**kw).preprocess(feats, labels, key)

    seen_budgets = []
    orig = sharded_mod.sharded_greedy_importance

    def spy(fn, z, **kwargs):
        seen_budgets.append(kwargs.get("lazy_budget"))
        return orig(fn, z, **kwargs)

    sharded_mod.sharded_greedy_importance = spy
    try:
        shard = MiloPreprocessor(**kw, shard_selection=True).preprocess(
            feats, labels, key)
    finally:
        sharded_mod.sharded_greedy_importance = orig
    # every mesh-routed class carried a real touched-rows budget
    assert seen_budgets and all(b is not None for b in seen_budgets)
    np.testing.assert_array_equal(base.sge_subsets, shard.sge_subsets)
    np.testing.assert_allclose(base.wre_importance, shard.wre_importance,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(base.wre_probs, shard.wre_probs,
                               rtol=1e-5, atol=1e-7)
    assert shard.config["shard_selection"] is True
    assert shard.config["lazy_gains"] is True


@multi_device
def test_sharded_factories_are_memoized():
    """Two sessions with the same knobs must receive the SAME SetFunction
    objects, or every jit/shard-program cache keys on fresh closures and
    recompiles per session (the stale shard-program cache bug)."""
    from repro.core import make_sharded_gram_free

    for name in ("facility_location", "graph_cut", "disparity_sum",
                 "disparity_min"):
        assert make_sharded_gram_free(name, n_shards=8) is \
            make_sharded_gram_free(name, n_shards=8), name
    assert make_sharded_gram_free("graph_cut", n_shards=8) is not \
        make_sharded_gram_free("graph_cut", n_shards=4)


@multi_device
def test_sharded_valid_mask_never_selects_padding():
    from repro.core import make_sharded_gram_free, sharded_sge

    z = _fixture(128, seed=4).at[96:].set(0.0)
    valid = jnp.arange(128) < 96
    fns = make_sharded_gram_free("graph_cut", n_shards=8)
    subs = np.asarray(sharded_sge(fns, z, 9, jax.random.PRNGKey(5),
                                  n_subsets=4, mesh=_mesh(), valid=valid))
    assert subs.max() < 96
    for run in subs:
        assert len(set(run.tolist())) == 9


@multi_device
def test_shard_memory_scaling_per_device_rows():
    """Acceptance: the only O(n·d) array is sharded — each device holds
    exactly n/ndev feature rows; a pre-sharded input runs unchanged."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import get_gram_free, greedy, make_sharded_gram_free, sharded_greedy

    n, d = 512, 16
    z = _fixture(n, d=d, seed=5)
    mesh = _mesh()
    zs = jax.device_put(z, NamedSharding(mesh, P("sel", None)))
    shapes = {s.data.shape for s in zs.addressable_shards}
    assert shapes == {(n // 8, d)}
    assert len(zs.addressable_shards) == 8
    res = sharded_greedy(
        make_sharded_gram_free("disparity_min", n_shards=8), zs, 16, mesh=mesh
    )
    ref = greedy(get_gram_free("disparity_min"), z, 16)
    np.testing.assert_array_equal(np.asarray(res.indices), np.asarray(ref.indices))


@multi_device
def test_sharded_rejects_non_divisible_ground_set():
    from repro.core import make_sharded_gram_free, sharded_greedy

    z = _fixture(130, seed=6)
    fns = make_sharded_gram_free("graph_cut", n_shards=8)
    with pytest.raises(ValueError, match="not divisible"):
        sharded_greedy(fns, z, 8, mesh=_mesh())


@multi_device
def test_preprocessor_shard_selection_matches_single_device():
    """End to end: sharded preprocessing produces a bit-identical artifact
    (SGE bank AND WRE importance), including classes whose pow2 bucket is
    mesh-divisible and tiny classes that fall back to the local path."""
    from repro.core import MiloPreprocessor

    rng = np.random.default_rng(14)
    sizes = [97, 83, 70, 45, 5]  # buckets 128/128/128/64/8 — plus a tiny class
    labels = np.concatenate([np.full(s, i) for i, s in enumerate(sizes)])
    feats = rng.normal(size=(len(labels), 12)).astype(np.float32)
    key = jax.random.PRNGKey(0)
    base = MiloPreprocessor(subset_fraction=0.1, gram_free=True).preprocess(
        feats, labels, key)
    shard = MiloPreprocessor(subset_fraction=0.1, gram_free=True,
                             shard_selection=True).preprocess(feats, labels, key)
    np.testing.assert_array_equal(base.sge_subsets, shard.sge_subsets)
    np.testing.assert_array_equal(base.wre_importance, shard.wre_importance)
    np.testing.assert_array_equal(base.wre_probs, shard.wre_probs)
    assert shard.config["shard_selection"] is True


@multi_device
def test_milo_fixed_shard_selection_matches():
    from repro.selection import build_selector

    rng = np.random.default_rng(15)
    feats = rng.normal(size=(256, 12)).astype(np.float32)
    a = build_selector("milo_fixed", features=feats, k=24, gram_free=True)
    b = build_selector("milo_fixed", features=feats, k=24, shard_selection=True)
    np.testing.assert_array_equal(a.plan(0).indices, b.plan(0).indices)


@multi_device
def test_selection_mesh_validates_device_count():
    from repro.distributed.sharding import selection_mesh

    assert selection_mesh().shape["sel"] == jax.device_count()
    assert selection_mesh(4).shape["sel"] == 4
    with pytest.raises(ValueError, match="out of range"):
        selection_mesh(10**6)


@multi_device
def test_sharded_two_level_gather_bit_identical_and_smaller_payload():
    """ISSUE 5 satellite: the two-level gather budget under shard_map.
    Right-sizing the touched-row gather to the smallest covering pow2 level
    shrinks the one-owner psum payload (rows_evaluated records the level
    actually gathered) while indices AND gains stay bit-identical to the
    single-level sharded run."""
    from repro.core import make_sharded_gram_free, sharded_lazy_greedy
    from repro.core.greedy import _gather_levels

    n, budget = 256, 32
    z = _fixture(n, seed=6)
    fns = make_sharded_gram_free("facility_location", n_shards=8)
    a = sharded_lazy_greedy(fns, z, n, budget=budget, mesh=_mesh())
    b = sharded_lazy_greedy(fns, z, n, budget=budget, mesh=_mesh(),
                            two_level=True)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))
    ra, rb = np.asarray(a.rows_evaluated), np.asarray(b.rows_evaluated)
    np.testing.assert_array_equal(ra == n, rb == n)  # same fallback steps
    lazy_a, lazy_b = ra[ra < n], rb[rb < n]
    assert np.all(lazy_a == budget)
    assert set(lazy_b.tolist()) <= set(_gather_levels(budget))
    assert lazy_b.sum() < lazy_a.sum()  # the psum payload really shrank


@multi_device
def test_sharded_two_level_importance_matches_single_device():
    """sharded_greedy_importance(lazy_two_level=True) equals the
    single-device two-level pass (which itself is bit-identical to the
    single-level one) to the documented ring-psum rounding."""
    from repro.core import (
        get_gram_free,
        greedy_importance,
        make_sharded_gram_free,
        sharded_greedy_importance,
    )

    z = _fixture(128, seed=7)
    fn1 = get_gram_free("facility_location")
    fns = make_sharded_gram_free("facility_location", n_shards=8)
    a = greedy_importance(fn1, z, lazy_budget=16, lazy_two_level=True)
    b = sharded_greedy_importance(fns, z, mesh=_mesh(), lazy_budget=16,
                                  lazy_two_level=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
