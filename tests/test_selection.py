"""The unified selection engine: registry coverage, SelectionPlan contract,
versioned metadata artifacts, pipeline weight plumbing, and the MiloSession
facade."""
import os

import jax
import numpy as np
import pytest

from repro.core.metadata import MetadataMismatchError, MiloMetadata
from repro.core.milo import MiloPreprocessor, _normalize_probs
from repro.data.pipeline import Pipeline
from repro.selection import (
    PHASES,
    MiloSession,
    MiloSessionConfig,
    SelectionPlan,
    Selector,
    available_selectors,
    build_selector,
    ensure_selector,
    uniform_plan,
)

N, K, DIM, CLASSES = 120, 24, 10, 4


@pytest.fixture(scope="module")
def feats():
    return np.random.default_rng(0).normal(size=(N, DIM)).astype(np.float32)


@pytest.fixture(scope="module")
def labels():
    return np.arange(N, dtype=np.int64) % CLASSES


@pytest.fixture(scope="module")
def metadata(feats, labels):
    pre = MiloPreprocessor(subset_fraction=K / N, n_sge_subsets=3, gram_block=64)
    return pre.preprocess(feats, labels, jax.random.PRNGKey(0))


def _grad_fn():
    return np.random.default_rng(1).normal(size=(N, DIM))


def _val_grad_fn():
    return np.random.default_rng(2).normal(size=(DIM,))


def _build_kwargs(name, feats, metadata):
    return {
        "full": dict(n=N),
        "random": dict(n=N, k=K, seed=0),
        "adaptive_random": dict(n=N, k=K, R=2, seed=0),
        "milo": dict(metadata=metadata, total_epochs=12, seed=0),
        "milo_fixed": dict(features=feats, k=K),
        "el2n": dict(scores=np.random.default_rng(3).random(N), k=K),
        "selfsup_prune": dict(features=feats, k=K, n_prototypes=4, seed=0),
        "craig_pb": dict(grad_fn=_grad_fn, k=K, R=3),
        "gradmatch_pb": dict(grad_fn=_grad_fn, k=K, R=3),
        "glister": dict(grad_fn=_grad_fn, val_grad_fn=_val_grad_fn, k=K, R=3),
        "milo_hier": dict(features=feats, k=K, partition="random_blocks",
                          partition_block=32, refine_factor=2),
        "milo_targeted": dict(features=feats, queries=feats[:8], k=K,
                              labels=np.arange(N, dtype=np.int64) % CLASSES),
    }[name]


def test_registry_covers_all_selectors():
    assert available_selectors() == sorted([
        "milo", "milo_fixed", "random", "adaptive_random", "el2n",
        "selfsup_prune", "craig_pb", "gradmatch_pb", "glister", "full",
        "milo_hier", "milo_targeted",
    ])


@pytest.mark.parametrize("name", [
    "milo", "milo_fixed", "random", "adaptive_random", "el2n",
    "selfsup_prune", "craig_pb", "gradmatch_pb", "glister", "full",
    "milo_hier", "milo_targeted",
])
def test_every_selector_builds_and_plans(name, feats, metadata):
    sel = build_selector(name, **_build_kwargs(name, feats, metadata))
    expected_k = N if name == "full" else K
    for epoch in (0, 1, 5):
        plan = sel.plan(epoch).validate(N)
        assert plan.k == expected_k
        assert len(np.unique(plan.indices)) == expected_k
        assert plan.indices.min() >= 0 and plan.indices.max() < N
        assert plan.weights.shape == plan.indices.shape
        assert plan.phase in PHASES
        assert np.isfinite(plan.weights).all()
    # weighted strategies carry non-uniform weights; others are uniform
    if name in ("craig_pb", "gradmatch_pb"):
        assert plan.weights.std() > 0
    else:
        np.testing.assert_allclose(plan.weights, 1.0)


@pytest.mark.parametrize("name", [
    "milo", "milo_fixed", "random", "adaptive_random", "el2n",
    "selfsup_prune", "craig_pb", "gradmatch_pb", "glister", "full",
])
def test_selector_replays_deterministically(name, feats, metadata):
    kw = _build_kwargs(name, feats, metadata)
    a, b = build_selector(name, **kw), build_selector(name, **kw)
    for epoch in (0, 2, 7):
        pa, pb = a.plan(epoch), b.plan(epoch)
        np.testing.assert_array_equal(pa.indices, pb.indices)
        np.testing.assert_allclose(pa.weights, pb.weights)


def test_milo_plan_phases_follow_curriculum(metadata):
    sel = build_selector("milo", metadata=metadata, total_epochs=12, kappa=1 / 6, seed=0)
    assert sel.plan(0).phase == "sge"
    assert sel.plan(5).phase == "wre"
    assert sel.plan(0).provenance["config_hash"] == metadata.config_hash()


def test_build_selector_rejects_bad_config():
    with pytest.raises(KeyError):
        build_selector("no_such_strategy", n=4)
    with pytest.raises(TypeError):
        build_selector("random", n=10)  # missing k


def test_plan_validation():
    with pytest.raises(ValueError):
        SelectionPlan(np.array([0, 1]), np.array([1.0]), "fixed", 0)
    with pytest.raises(ValueError):
        uniform_plan(np.array([0, 1]), "bogus-phase", 0)
    with pytest.raises(ValueError):
        uniform_plan(np.array([0, 0]), "fixed", 0).validate(4)
    with pytest.raises(ValueError):
        uniform_plan(np.array([0, 9]), "fixed", 0).validate(4)


def test_legacy_shim_and_adapter():
    class Old:
        def indices_for_epoch(self, epoch):
            return np.arange(5)

    sel = ensure_selector(Old())
    assert isinstance(sel, Selector)
    plan = sel.plan(0)
    np.testing.assert_array_equal(plan.indices, np.arange(5))
    np.testing.assert_allclose(plan.weights, 1.0)
    # the ABC keeps indices_for_epoch as a deprecation shim
    with pytest.warns(DeprecationWarning):
        idx = sel.indices_for_epoch(0)
    np.testing.assert_array_equal(idx, np.arange(5))


# -- versioned metadata artifacts -------------------------------------------

def test_metadata_roundtrip_v2(tmp_path, metadata):
    p = os.path.join(tmp_path, "milo.npz")
    metadata.save(p)
    md2 = MiloMetadata.load(p)
    np.testing.assert_array_equal(md2.sge_subsets, metadata.sge_subsets)
    np.testing.assert_allclose(md2.wre_probs, metadata.wre_probs)
    assert md2.config == metadata.config
    assert md2.config_hash() == metadata.config_hash()
    # verified load paths
    MiloMetadata.load(p, expected_hash=metadata.config_hash())
    MiloMetadata.load(p, expected_config={"easy_fn": "graph_cut"})


def test_metadata_rejects_config_mismatch(tmp_path, metadata):
    p = os.path.join(tmp_path, "milo.npz")
    metadata.save(p)
    with pytest.raises(MetadataMismatchError):
        MiloMetadata.load(p, expected_hash="0" * 16)
    with pytest.raises(MetadataMismatchError):
        MiloMetadata.load(p, expected_config={"easy_fn": "facility_location"})


def test_metadata_loads_v1_artifacts(tmp_path, metadata):
    """Artifacts written before the header format must still load."""
    import json

    p = os.path.join(tmp_path, "v1.npz")
    np.savez(
        p,
        sge_subsets=metadata.sge_subsets,
        wre_probs=metadata.wre_probs,
        wre_importance=metadata.wre_importance,
        class_labels=metadata.class_labels,
        class_budgets=metadata.class_budgets,
        config=np.frombuffer(json.dumps(metadata.config).encode(), dtype=np.uint8),
    )
    md = MiloMetadata.load(p)
    assert md.config == metadata.config


# -- degenerate importance fallback -----------------------------------------

def test_normalize_probs_degenerate_falls_back_to_uniform():
    for bad in (np.zeros(8, np.float32),
                np.full(8, np.nan, np.float32),
                np.array([0, -1, 0, 0], np.float32)):
        p = _normalize_probs(bad)
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
        assert (p > 0).all()
    ok = _normalize_probs(np.array([1.0, 3.0], np.float32))
    np.testing.assert_allclose(ok, [0.25, 0.75])


# -- pipeline plumbing --------------------------------------------------------

class _WeightedToy(Selector):
    """Weights encode the index so batch alignment is checkable."""

    def plan(self, epoch):
        idx = np.arange(16, dtype=np.int64)
        return SelectionPlan(idx, (idx + 1).astype(np.float32), "fixed", epoch)


def test_pipeline_injects_aligned_weights():
    data = np.arange(16, dtype=np.float32)
    pipe = Pipeline(lambda idx: {"x": data[idx]}, _WeightedToy(), batch_size=4,
                    seed=0, prefetch=False)
    for batch in pipe.epoch(3):
        np.testing.assert_allclose(batch["weights"], batch["x"] + 1)


def test_pipeline_weight_injection_can_be_disabled():
    pipe = Pipeline(lambda idx: {"x": idx}, _WeightedToy(), batch_size=4,
                    seed=0, prefetch=False, weight_key=None)
    assert "weights" not in next(iter(pipe.epoch(0)))


def test_pipeline_prefetch_propagates_worker_errors():
    boom = RuntimeError("batch assembly failed")

    calls = []

    def make_batch(idx):
        calls.append(1)
        if len(calls) >= 2:
            raise boom
        return {"x": idx}

    pipe = Pipeline(make_batch, _WeightedToy(), batch_size=4, seed=0, prefetch=True)
    with pytest.raises(RuntimeError, match="batch assembly failed"):
        list(pipe.epoch(0))


def test_pipeline_prefetch_worker_exits_on_early_break():
    """Abandoning an epoch early (break / close()) must shut the prefetch
    worker down; before the stop event it stayed blocked forever on a full
    queue, pinning batch arrays."""
    import threading
    import time

    def make_batch(idx):
        return {"x": np.ones((len(idx), 64))}

    pipe = Pipeline(make_batch, _WeightedToy(), batch_size=2, seed=0,
                    prefetch=True)
    assert pipe.steps_per_epoch() > 3  # enough batches left to block on
    it = pipe.epoch(0)
    next(it)            # consume one batch...
    it.close()          # ...then abandon the epoch (same path as `break`)
    deadline = time.monotonic() + 5.0
    while time.monotonic() < deadline:
        workers = [t for t in threading.enumerate()
                   if t.name == "pipeline-prefetch" and t.is_alive()]
        if not workers:
            break
        time.sleep(0.05)
    assert not workers, "prefetch worker still alive after epoch abandoned"


# -- MiloSession facade -------------------------------------------------------

def test_session_end_to_end(tmp_path, feats, labels):
    path = os.path.join(tmp_path, "artifact.npz")
    cfg = MiloSessionConfig(
        subset_fraction=K / N, n_sge_subsets=3, total_epochs=4,
        gram_block=64, metadata_path=path, sub_steps=2,
    )
    session = MiloSession(cfg)
    md = session.preprocess(feats, labels)
    assert os.path.exists(path) and not session.loaded_from_artifact
    report = session.train(feats, labels, test_x=feats, test_y=labels)
    assert 0.0 <= report.final_acc <= 1.0 and report.steps == 4
    assert any(h.get("phase") == "sge" for h in report.history)

    # a fresh session must REUSE the artifact, then train a second model
    session2 = MiloSession(cfg)
    md2 = session2.preprocess(feats, labels)
    assert session2.loaded_from_artifact
    np.testing.assert_array_equal(md2.sge_subsets, md.sge_subsets)
    report2 = session2.train(feats, labels, test_x=feats, test_y=labels, seed=1)
    assert 0.0 <= report2.final_acc <= 1.0

    # a session with different preprocessing settings must refuse the artifact
    bad = MiloSession(MiloSessionConfig(
        subset_fraction=K / N, n_sge_subsets=3, total_epochs=4,
        gram_block=64, metadata_path=path, easy_fn="facility_location",
    ))
    with pytest.raises(MetadataMismatchError):
        bad.preprocess(feats, labels)


def test_session_head_covers_held_out_eval_classes(feats, labels, monkeypatch):
    """A test/val label outside the train range must still own a logit:
    sizing the head from train labels alone made accuracy gather clipped
    (silently wrong) logits under jit.  n_classes derives from train ∪ eval
    labels, with an explicit config override."""
    from repro.selection import session as session_mod

    sizes = []
    orig = session_mod._init_classifier

    def spy(key, d_in, n_classes, hidden, lr0, total_steps):
        sizes.append(n_classes)
        return orig(key, d_in, n_classes, hidden, lr0, total_steps)

    monkeypatch.setattr(session_mod, "_init_classifier", spy)
    session = MiloSession(MiloSessionConfig(
        selector="random", subset_fraction=K / N, total_epochs=2,
        n_sge_subsets=3))
    tx = feats[:10]
    ty = np.full((10,), CLASSES)  # a class the training split never saw
    report = session.train(feats, labels, test_x=tx, test_y=ty)
    assert sizes == [CLASSES + 1]
    assert 0.0 <= report.final_acc <= 1.0
    # explicit override wins over the derived value
    session_wide = MiloSession(MiloSessionConfig(
        selector="random", subset_fraction=K / N, total_epochs=2,
        n_sge_subsets=3, n_classes=CLASSES + 3))
    session_wide.train(feats, labels, test_x=tx, test_y=ty)
    assert sizes == [CLASSES + 1, CLASSES + 3]
    # an override narrower than the observed labels would reintroduce the
    # clipped-gather bug — it must refuse, not silently mis-measure
    session_narrow = MiloSession(MiloSessionConfig(
        selector="random", subset_fraction=K / N, total_epochs=2,
        n_sge_subsets=3, n_classes=CLASSES))
    with pytest.raises(ValueError, match="cannot cover label"):
        session_narrow.train(feats, labels, test_x=tx, test_y=ty)


def test_session_trains_other_registry_selectors(feats, labels):
    session = MiloSession(MiloSessionConfig(
        subset_fraction=K / N, n_sge_subsets=3, total_epochs=3,
        gram_block=64, sub_steps=1,
    ))
    session.preprocess(feats, labels)
    # selfsup_prune exercises the generic fallthrough: the session must
    # forward features/k/seed into the strategy's config
    for name in ("full", "random", "adaptive_random", "milo_fixed", "selfsup_prune"):
        extra = {"n_prototypes": 4} if name == "selfsup_prune" else {}
        report = session.train(feats, labels, test_x=feats, test_y=labels,
                               selector=name, **extra)
        assert 0.0 <= report.final_acc <= 1.0, name


def test_session_rejects_artifact_from_different_prep_seed(tmp_path, feats, labels):
    path = os.path.join(tmp_path, "artifact.npz")
    base = dict(subset_fraction=K / N, n_sge_subsets=3, total_epochs=3,
                gram_block=64, metadata_path=path)
    MiloSession(MiloSessionConfig(**base, seed=0)).preprocess(feats, labels)
    # a different preprocessing seed means different stochastic-greedy draws:
    # reuse must refuse, not silently serve seed-0 subsets
    with pytest.raises(MetadataMismatchError, match="prep_seed"):
        MiloSession(MiloSessionConfig(**base, seed=1)).preprocess(feats, labels)


def test_session_rejects_artifact_from_different_engine_knobs(tmp_path, feats, labels):
    """lazy_gains / exact_sge_candidates change the recorded trajectories, so
    a recorded mismatch must refuse reuse; shard_selection selects identically
    and is deliberately tolerated (artifacts stay portable across meshes)."""
    path = os.path.join(tmp_path, "artifact.npz")
    base = dict(subset_fraction=K / N, n_sge_subsets=3, total_epochs=3,
                gram_block=64, metadata_path=path)
    MiloSession(MiloSessionConfig(**base)).preprocess(feats, labels)
    with pytest.raises(MetadataMismatchError, match="exact_sge_candidates"):
        MiloSession(MiloSessionConfig(**base, exact_sge_candidates=True)
                    ).preprocess(feats, labels)
    with pytest.raises(MetadataMismatchError, match="lazy_gains"):
        MiloSession(MiloSessionConfig(**base, lazy_gains=True)
                    ).preprocess(feats, labels)
    # with lazy gains active the recompute threshold is trajectory-shaping:
    # an artifact built under one threshold must refuse another
    lazy_path = os.path.join(os.path.dirname(path), "lazy.npz")
    lazy_base = dict(base, metadata_path=lazy_path, lazy_gains=True,
                     hard_fn="facility_location")
    MiloSession(MiloSessionConfig(**lazy_base)).preprocess(feats, labels)
    with pytest.raises(MetadataMismatchError, match="lazy_threshold"):
        MiloSession(MiloSessionConfig(**lazy_base, lazy_threshold=0.5)
                    ).preprocess(feats, labels)
    reusing = MiloSession(MiloSessionConfig(**base, shard_selection=True,
                                            gram_free=False))
    # shard_selection=True without devices/gram_free never alters results;
    # the artifact check must not block on it
    with pytest.raises(MetadataMismatchError, match="gram_free"):
        # ...but gram_free itself is still enforced
        MiloSession(MiloSessionConfig(**base, gram_free=True)
                    ).preprocess(feats, labels)
    md = reusing.preprocess(feats, labels)
    assert reusing.loaded_from_artifact and md.config.get("shard_selection") is False


def test_session_rejects_artifact_from_different_dataset(tmp_path, feats, labels):
    path = os.path.join(tmp_path, "artifact.npz")
    cfg = MiloSessionConfig(subset_fraction=K / N, n_sge_subsets=3,
                            total_epochs=3, gram_block=64, metadata_path=path)
    MiloSession(cfg).preprocess(feats, labels)
    smaller = feats[: N // 2]
    with pytest.raises(MetadataMismatchError, match="different data"):
        MiloSession(cfg).preprocess(smaller, labels[: N // 2])
    # same length, different content: caught by the feature fingerprint
    shuffled = feats[::-1].copy()
    with pytest.raises(MetadataMismatchError, match="fingerprint"):
        MiloSession(cfg).preprocess(shuffled, labels)


def test_session_tune_rejects_unsupported_space_keys(feats, labels):
    session = MiloSession(MiloSessionConfig(subset_fraction=K / N, n_sge_subsets=3,
                                            total_epochs=3, gram_block=64))
    session.preprocess(feats, labels)
    with pytest.raises(ValueError, match="sub_steps"):
        session.tune(feats, labels, feats, labels,
                     {"lr": ("log", 0.01, 0.3), "sub_steps": ("choice", [1, 4])})


def test_session_windowed_selector_selects_once_per_window(feats, labels):
    calls = []

    def grad_fn():
        calls.append(1)
        return np.random.default_rng(1).normal(size=(N, DIM))

    session = MiloSession(MiloSessionConfig(subset_fraction=K / N, n_sge_subsets=3,
                                            total_epochs=4, gram_block=64, sub_steps=1))
    session.preprocess(feats, labels)
    session.train(feats, labels, test_x=feats, test_y=labels,
                  selector="craig_pb", grad_fn=grad_fn, R=2)
    # 4 epochs, R=2 -> windows {0, 1}.  One warm-up selection (untimed) plus
    # one per window inside fit — the epoch-0 recompute is deliberately
    # charged to the timed region, matching benchmarks/common.py; epochs
    # within a window reuse the memoized selection
    assert len(calls) == 3, calls


def test_session_tune_smoke(feats, labels):
    session = MiloSession(MiloSessionConfig(
        subset_fraction=K / N, n_sge_subsets=3, total_epochs=3,
        gram_block=64, sub_steps=1,
    ))
    session.preprocess(feats, labels)
    res = session.tune(feats, labels, feats, labels,
                       {"lr": ("log", 0.01, 0.3)}, search="random",
                       max_budget=3, eta=3)
    assert res.best_config is not None and len(res.trials) >= 2
