"""Integration: trainer + MILO pipeline + checkpoint restart; serving engine;
baselines; tuner."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: property tests guard individually
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = settings = st = None

from repro.baselines.selectors import (
    AdaptiveRandomSelector,
    CraigPBSelector,
    EL2NSelector,
    GlisterSelector,
    GradMatchPBSelector,
    MiloFixedSelector,
    RandomSelector,
    SelfSupPruneSelector,
)
from repro.configs import registry
from repro.core import CurriculumConfig, MiloPreprocessor, MiloSelector
from repro.data.datasets import TokenLMDataset
from repro.data.pipeline import FullSelector, Pipeline
from repro.models import lm
from repro.optim.optimizers import adamw, sgd_nesterov
from repro.optim.schedules import cosine, cyclic, linear_decay
from repro.serve.lm_engine import Request, ServeEngine
from repro.train.train_state import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig
from repro.tuning.tuner import RandomSearch, TPESearch, hyperband, kendall_tau


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = registry.smoke("internlm2-1.8b")
    ds = TokenLMDataset(n_docs=96, seq_len=32, vocab=cfg.vocab_size, seed=0)
    return cfg, ds


def _make_trainer(cfg, ds, selector, epochs, ckpt=None, lr=2e-3):
    pipe = Pipeline(ds.batch, selector, batch_size=8, seed=0, prefetch=False)
    opt = adamw()
    steps = max(1, pipe.steps_per_epoch() * epochs)
    step_fn = make_train_step(cfg, opt, cosine(lr, steps))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    tr = Trainer(step_fn, pipe, TrainerConfig(
        epochs=epochs, checkpoint_dir=ckpt,
        checkpoint_every_steps=4 if ckpt else 0, async_checkpoint=False,
        log_every_steps=1))
    return tr, state


def test_training_reduces_loss_with_milo(tiny_setup):
    cfg, ds = tiny_setup
    pre = MiloPreprocessor(subset_fraction=0.5, n_sge_subsets=2, classwise=False,
                           gram_block=128)
    md = pre.preprocess(ds.features(), None, jax.random.PRNGKey(0))
    sel = MiloSelector(md, CurriculumConfig(total_epochs=10))
    tr, state = _make_trainer(cfg, ds, sel, epochs=10, lr=3e-3)
    state = tr.fit(state)
    losses = [h["loss"] for h in tr.history if "loss" in h]
    assert losses[-1] < losses[0] * 0.9, (losses[0], losses[-1])


def test_checkpoint_restart_resumes_exactly(tiny_setup, tmp_path):
    cfg, ds = tiny_setup
    ck = str(tmp_path / "ck")
    sel = FullSelector(ds.n)
    tr, state = _make_trainer(cfg, ds, sel, epochs=1, ckpt=ck)
    final = tr.fit(state)
    steps_done = int(final.step)
    # new trainer restores from the final checkpoint and does nothing more
    tr2, state2 = _make_trainer(cfg, ds, sel, epochs=1, ckpt=ck)
    resumed = tr2.fit(state2)
    assert int(resumed.step) == steps_done
    a = np.asarray(jax.tree.leaves(final.params)[0], np.float32)
    b = np.asarray(jax.tree.leaves(resumed.params)[0], np.float32)
    np.testing.assert_array_equal(a, b)


def test_optimizers_and_schedules_step():
    cfg = registry.smoke("yi-6b")
    ds = TokenLMDataset(n_docs=16, seq_len=16, vocab=cfg.vocab_size)
    batch = ds.batch(np.arange(8))
    for opt in (adamw(), sgd_nesterov()):
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = jax.jit(make_train_step(cfg, opt, cosine(1e-3, 10)))
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
    for sched in (cosine(0.1, 100, warmup=10), cyclic(0.01, 0.1, 20), linear_decay(0.1, 0.1, 5)):
        vals = [float(sched(s)) for s in range(0, 100, 7)]
        assert all(v >= 0 for v in vals)


def test_serving_engine_batches_requests():
    cfg = registry.smoke("internlm2-1.8b")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, max_batch=2, max_len=32)
    rng = np.random.default_rng(0)
    for rid in range(4):  # more requests than slots -> queueing
        eng.submit(Request(rid, rng.integers(0, cfg.vocab_size, size=5).astype(np.int32),
                           max_new_tokens=4))
    done = eng.run(max_steps=100)
    assert len(done) == 4
    for r in done:
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.generated)


def test_baseline_selectors_contract():
    n, k = 64, 16
    feats = np.random.default_rng(0).normal(size=(n, 8)).astype(np.float32)

    def grad_fn():
        return np.random.default_rng(1).normal(size=(n, 8)).astype(np.float32)

    def val_grad_fn():
        return np.random.default_rng(2).normal(size=(8,)).astype(np.float32)

    selectors = [
        RandomSelector(n, k),
        AdaptiveRandomSelector(n, k, R=2),
        MiloFixedSelector(feats, k),
        EL2NSelector(np.random.default_rng(3).random(n), k),
        SelfSupPruneSelector(feats, k, n_prototypes=4),
        CraigPBSelector(grad_fn, k, R=2),
        GradMatchPBSelector(grad_fn, k, R=2),
        GlisterSelector(grad_fn, val_grad_fn, k, R=2),
    ]
    for sel in selectors:
        for e in (0, 1, 2):
            idx = np.asarray(sel.indices_for_epoch(e))
            assert idx.shape == (k,), type(sel).__name__
            assert len(set(idx.tolist())) == k
            assert idx.min() >= 0 and idx.max() < n
    # adaptive selectors change across windows; fixed ones don't
    ar = AdaptiveRandomSelector(n, k, R=1)
    assert set(ar.indices_for_epoch(0).tolist()) != set(ar.indices_for_epoch(1).tolist())
    rs = RandomSelector(n, k)
    assert set(rs.indices_for_epoch(0).tolist()) == set(rs.indices_for_epoch(5).tolist())


def test_hyperband_finds_good_config():
    # toy objective: score peaks at lr ~ 0.1, improves with budget
    def objective(cfg, budget):
        lr = cfg["lr"]
        return -abs(np.log10(lr) + 1.0) + 0.05 * np.log1p(budget)

    space = {"lr": ("log", 1e-4, 1.0)}
    res = hyperband(objective, RandomSearch(space, seed=0), max_budget=9, eta=3)
    assert 0.01 < res.best_config["lr"] < 1.0
    res_tpe = hyperband(objective, TPESearch(space, seed=0), max_budget=9, eta=3)
    assert abs(np.log10(res_tpe.best_config["lr"]) + 1.0) < 1.0


def test_kendall_tau():
    a = np.asarray([1.0, 2.0, 3.0, 4.0])
    assert kendall_tau(a, a) == 1.0
    assert kendall_tau(a, -a) == -1.0
    assert abs(kendall_tau(a, np.asarray([1.0, 2.0, 4.0, 3.0]))) < 1.0


def _kendall_tau_loop(a, b):
    """The former O(n²) pair-loop implementation, kept as the property-test
    oracle for the vectorized sign-outer-product version."""
    n = len(a)
    num = 0
    den = 0
    for i in range(n):
        for j in range(i + 1, n):
            x = np.sign(a[i] - a[j])
            y = np.sign(b[i] - b[j])
            if x and y:
                num += int(x == y) - int(x != y)
                den += 1
    return num / den if den else 0.0


def test_kendall_tau_matches_loop_with_ties():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 4, size=12).astype(float)   # plenty of ties
    b = rng.integers(0, 4, size=12).astype(float)
    assert kendall_tau(a, b) == pytest.approx(_kendall_tau_loop(a, b))
    # all-tied vectors have no comparable pairs
    assert kendall_tau(np.ones(5), np.arange(5.0)) == 0.0
    assert kendall_tau(np.arange(2.0), np.arange(2.0)) == 1.0


if st is not None:
    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(st.integers(-5, 5), min_size=2, max_size=20),
        st.lists(st.integers(-5, 5), min_size=2, max_size=20),
    )
    def test_kendall_tau_property_vs_loop(xs, ys):
        n = min(len(xs), len(ys))
        a = np.asarray(xs[:n], float)
        b = np.asarray(ys[:n], float)
        assert kendall_tau(a, b) == pytest.approx(_kendall_tau_loop(a, b))
