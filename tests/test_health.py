"""Numerical-health guardrail layer (ISSUE 8): input firewall, divergence
guard, trial quarantine, and degraded-mode fallbacks.

The load-bearing claims pinned here:
  * the input firewall catches every planted anomaly (non-finite rows,
    zero-norm rows, duplicates, constant features, degenerate class
    geometry) with deterministic repair / quarantine, and quarantined
    artifacts re-index cleanly back to the full ground set;
  * the zero-norm ``normalize_rows`` hazard (a silent phantom 0.5
    similarity) is detected, not silently scored;
  * the divergence guard skips a NaN step in-scan with the step counter
    still advancing, identically on the loop and fused paths, and a
    rollback run restored through the PR 7 checkpointer is BIT-IDENTICAL
    to the plain skip run (``GUARD_ROLLBACK_BIT_IDENTICAL_OK``);
  * a healthy guarded run is bit-identical to an unguarded one (the guard
    is pure observation until something trips);
  * hyperband quarantines raising / non-finite trials and still finds the
    ``best_config`` an identical sweep with those configs pre-excluded
    finds; a corrupt rung checkpoint raises a clean error, never KeyError;
  * the serving layer fails fast at a full queue and trips a per-key
    circuit breaker on deterministically-failing builds while ``health()``
    reports the degradation;
  * selector fallback chains degrade to a declared tier with full plan
    provenance instead of crashing.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.milo import MiloPreprocessor
from repro.core.similarity import normalize_rows, zero_norm_rows
from repro.data.pipeline import Pipeline
from repro.health import (
    CircuitBreaker,
    CircuitOpenError,
    DataHealthError,
    DivergenceError,
    FallbackExhaustedError,
    FallbackSelector,
    GUARD_KEY,
    GuardPolicy,
    SelectionDegenerateError,
    guarded_step,
    validate_features,
)
from repro.health.firewall import MAX_RECORDED_INDICES
from repro.models.classifier import init_mlp, nesterov_update, weighted_nll
from repro.selection import MiloSession, MiloSessionConfig, build_selector
from repro.selection.plan import uniform_plan
from repro.testing.faults import (
    fail_objective_for_configs,
    nan_at_step,
    poison_features,
)
from repro.train.trainer import Trainer, TrainerConfig
from repro.tuning.tuner import RandomSearch, hyperband


def _dataset(n=60, d=6, c=3, seed=0):
    rng = np.random.default_rng(seed)
    labs = rng.integers(0, c, n).astype(np.int64)
    feats = (rng.normal(size=(n, d)) + 0.5 * labs[:, None]).astype(np.float32)
    return feats, labs


# ---------------------------------------------------------------------------
# input firewall: detection, policies, provenance
# ---------------------------------------------------------------------------

def test_firewall_raise_names_every_planted_anomaly():
    feats, labs = _dataset()
    bad = poison_features(feats, nan_rows=[3], inf_rows=[7], zero_rows=[11])
    with pytest.raises(DataHealthError) as ei:
        validate_features(bad, labs)
    msg = str(ei.value)
    assert "nonfinite_rows=2" in msg and "zero_norm_rows=1" in msg
    # detection is exact, not heuristic
    _, rep = validate_features(bad, labs, policy=None)
    assert rep.nonfinite_rows == [3, 7]
    assert rep.zero_norm_rows == [11]
    assert rep.bad_rows == [3, 7, 11]
    assert not rep.clean


def test_firewall_clean_data_passes_untouched():
    feats, labs = _dataset()
    out, rep = validate_features(feats, labs)
    assert out is feats                        # no copy on the clean path
    assert rep.clean and rep.bad_rows == []


def test_firewall_repair_is_deterministic_and_total():
    feats, _ = _dataset()
    bad = poison_features(feats, nan_rows=[2, 9], zero_rows=[5])
    out1, rep1 = validate_features(bad, policy="repair")
    out2, rep2 = validate_features(bad, policy="repair")
    np.testing.assert_array_equal(out1, out2)   # bit-identical repair
    assert rep1.repaired_rows == rep2.repaired_rows == [2, 5, 9]
    assert np.isfinite(out1).all()
    assert (np.linalg.norm(out1, axis=1) > 0).all()
    # an all-NaN row repairs to the basis vector e_{i mod d}
    e2 = np.zeros(feats.shape[1], bad.dtype)
    e2[2 % feats.shape[1]] = 1.0
    np.testing.assert_array_equal(out1[2], e2)
    # untouched rows are byte-identical to the input
    keep = np.setdiff1d(np.arange(len(bad)), [2, 5, 9])
    np.testing.assert_array_equal(out1[keep], bad[keep])


def test_firewall_structural_anomalies_are_report_only():
    feats, _ = _dataset(n=40)
    feats[10] = feats[4]                       # duplicate row
    feats[:, 2] = 1.5                          # constant feature
    labs = np.zeros(40, np.int64)
    labs[-1] = 2                               # class 1 empty, class 2 singleton
    out, rep = validate_features(feats, labs, policy="quarantine",
                                 subset_fraction=0.9)
    assert out is feats                        # structural issues never mutate
    assert rep.duplicate_rows == [10]
    assert 2 in rep.constant_features
    assert rep.empty_classes == [1]
    assert rep.singleton_classes == [2]
    assert 2 in rep.overbudget_classes         # budget >= class size of 1
    assert rep.quarantined_rows == []          # nothing actionable to act on


def test_firewall_to_dict_truncates_examples_but_keeps_full_quarantine():
    feats, _ = _dataset(n=120)
    bad = poison_features(feats, nan_rows=range(50))
    _, rep = validate_features(bad, policy="quarantine")
    d = rep.to_dict()
    assert d["nonfinite_rows"]["count"] == 50
    assert len(d["nonfinite_rows"]["indices"]) == MAX_RECORDED_INDICES
    # quarantined_rows define what the artifact IS: stored in full
    assert d["quarantined_rows"] == list(range(50))
    json.dumps(d)                              # JSON-safe for artifact headers


def test_firewall_input_validation():
    feats, labs = _dataset()
    with pytest.raises(ValueError, match="policy"):
        validate_features(feats, policy="explode")
    with pytest.raises(ValueError, match="2-D"):
        validate_features(feats.ravel())
    with pytest.raises(ValueError, match="labels length"):
        validate_features(feats, labs[:-1])
    with pytest.raises(TypeError, match="floating"):
        poison_features(labs, nan_rows=[0])


# ---------------------------------------------------------------------------
# satellite 1: the zero-norm normalize_rows hazard is detected, not scored
# ---------------------------------------------------------------------------

def test_zero_norm_row_regression_phantom_similarity_is_flagged():
    """A zero-norm row passes ``normalize_rows`` silently as an exact zero
    vector and then scores a constant phantom 0.5 against every other row
    under the rescaled cosine.  The firewall must catch what the kernel
    deliberately tolerates (zero rows double as padding sentinels)."""
    feats, _ = _dataset(n=16)
    bad = poison_features(feats, zero_rows=[6])
    z = np.asarray(normalize_rows(jnp.asarray(bad)))
    np.testing.assert_array_equal(z[6], np.zeros(bad.shape[1]))  # silent
    sim_row = 0.5 * (1.0 + z @ z[6])           # the rescaled-cosine column
    np.testing.assert_allclose(sim_row, 0.5)   # phantom mid-similarity
    # the detection pair: the kernel-side mask and the host-side firewall
    mask = np.asarray(zero_norm_rows(jnp.asarray(bad)))
    assert mask[6] and mask.sum() == 1
    with pytest.raises(DataHealthError, match="zero_norm_rows"):
        validate_features(bad)


# ---------------------------------------------------------------------------
# firewall wired into preprocessing: quarantined artifacts re-index cleanly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gram_free", [False, True])
def test_preprocess_quarantine_artifact_remaps_to_full_ground_set(gram_free):
    feats, labs = _dataset(n=80)
    bad = poison_features(feats, nan_rows=[5], zero_rows=[17, 40])
    pre = MiloPreprocessor(subset_fraction=0.25, n_sge_subsets=2,
                           gram_free=gram_free, firewall="quarantine")
    md = pre.preprocess(bad, labs, jax.random.PRNGKey(0))
    # artifact is indexed over the FULL ground set
    assert md.wre_probs.shape[0] == 80
    assert md.class_labels.shape[0] == 80
    for q in (5, 17, 40):
        assert md.wre_probs[q] == 0.0          # can never be drawn
        assert md.wre_importance[q] == 0.0
        assert not np.any(md.sge_subsets == q)  # never selected
    assert np.isfinite(md.wre_probs).all()
    # provenance records the exclusions in full
    assert md.config["firewall"] == "quarantine"
    assert md.config["data_health"]["quarantined_rows"] == [5, 17, 40]
    # quarantine is deterministic: a second pass is bit-identical
    md2 = pre.preprocess(bad, labs, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(md.sge_subsets, md2.sge_subsets)
    np.testing.assert_array_equal(md.wre_probs, md2.wre_probs)


def test_preprocess_firewall_raise_refuses_poisoned_ground_set():
    feats, labs = _dataset()
    bad = poison_features(feats, nan_rows=[0])
    pre = MiloPreprocessor(subset_fraction=0.2, n_sge_subsets=2,
                           firewall="raise")
    with pytest.raises(DataHealthError):
        pre.preprocess(bad, labs, jax.random.PRNGKey(0))


def test_preprocess_without_firewall_leaves_config_untouched():
    """Legacy artifact hash stability: no firewall -> no new config keys."""
    feats, labs = _dataset()
    md = MiloPreprocessor(subset_fraction=0.2, n_sge_subsets=2).preprocess(
        feats, labs, jax.random.PRNGKey(0))
    assert "firewall" not in md.config and "data_health" not in md.config
    md2 = MiloPreprocessor(subset_fraction=0.2, n_sge_subsets=2,
                           firewall="raise").preprocess(
        feats, labs, jax.random.PRNGKey(0))
    assert md2.config["firewall"] == "raise"
    assert md2.config["data_health"]["clean"]
    # the selection outputs themselves are identical (clean data)
    np.testing.assert_array_equal(md.sge_subsets, md2.sge_subsets)


def test_session_artifact_firewall_mismatch_raises(tmp_path):
    from repro.core.metadata import MetadataMismatchError

    feats, labs = _dataset(n=80)
    path = str(tmp_path / "milo.npz")
    base = dict(subset_fraction=0.2, n_sge_subsets=2, metadata_path=path)
    MiloSession(MiloSessionConfig(firewall="repair", **base)).preprocess(
        feats, labs)
    # same artifact, different firewall expectation -> config bug, refuse
    with pytest.raises(MetadataMismatchError, match="firewall"):
        MiloSession(MiloSessionConfig(firewall=None, **base)).preprocess(
            feats, labs)
    # matching expectation reuses the artifact
    s = MiloSession(MiloSessionConfig(firewall="repair", **base))
    md = s.preprocess(feats, labs)
    assert md.config["firewall"] == "repair"


# ---------------------------------------------------------------------------
# satellite 3: degenerate class geometry yields valid, bit-identical plans
# ---------------------------------------------------------------------------

def _degenerate_cases():
    feats, _ = _dataset(n=40)
    n = len(feats)
    labs_gap = np.where(np.arange(n) % 2 == 0, 0, 2).astype(np.int64)
    labs_single = np.zeros(n, np.int64)
    labs_single[-1] = 1
    feats_dup = feats.copy()
    feats_dup[n // 2:] = feats_dup[n // 2]     # one class of clones
    labs_half = (np.arange(n) >= n // 2).astype(np.int64)
    labs_skew = np.zeros(n, np.int64)
    labs_skew[-2:] = 1                         # 2-row class, budget >= size
    return {
        "empty_class": (feats, labs_gap, 0.3),
        "singleton_class": (feats, labs_single, 0.3),
        "duplicate_class": (feats_dup, labs_half, 0.3),
        "k_ge_class_size": (feats, labs_skew, 0.95),
    }


@pytest.mark.parametrize("gram_free", [False, True])
@pytest.mark.parametrize("case", sorted(_degenerate_cases()))
def test_degenerate_geometry_valid_and_bit_identical(case, gram_free):
    feats, labs, frac = _degenerate_cases()[case]
    pre = MiloPreprocessor(subset_fraction=frac, n_sge_subsets=2,
                           gram_free=gram_free)
    md1 = pre.preprocess(feats, labs, jax.random.PRNGKey(0))
    md2 = pre.preprocess(feats, labs, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(md1.sge_subsets, md2.sge_subsets)
    np.testing.assert_array_equal(md1.wre_probs, md2.wre_probs)
    assert np.isfinite(md1.wre_probs).all()
    assert (md1.wre_probs >= 0).all()
    n = len(feats)
    assert ((md1.sge_subsets >= 0) & (md1.sge_subsets < n)).all()
    # the firewall's report-only pass names the degeneracy for provenance
    _, rep = validate_features(feats, labs, policy=None, subset_fraction=frac)
    assert not rep.clean or case == "duplicate_class" or rep.duplicate_rows


# ---------------------------------------------------------------------------
# divergence guard: fused skip semantics
# ---------------------------------------------------------------------------

class _TinyState(NamedTuple):
    p: jax.Array
    step: jax.Array


def _tiny_step(state, batch):
    loss = jnp.sum(state.p * batch["x"])
    return _TinyState(state.p - 0.1 * batch["x"], state.step + 1), {
        "loss": loss}


def test_guarded_step_skips_nonfinite_and_advances_counter():
    g = jax.jit(guarded_step(nan_at_step(_tiny_step, step=1), GuardPolicy()))
    s = _TinyState(jnp.ones(3), jnp.zeros((), jnp.int32))
    s, m0 = g(s, {"x": jnp.ones(3)})
    assert float(m0[GUARD_KEY]) == 0.0
    p_before = np.asarray(s.p)
    s, m1 = g(s, {"x": jnp.ones(3)})           # poisoned step
    assert float(m1[GUARD_KEY]) == 1.0
    np.testing.assert_array_equal(np.asarray(s.p), p_before)  # update skipped
    assert int(s.step) == 2                    # counter still advanced
    s, m2 = g(s, {"x": jnp.ones(3)})           # healthy again (no livelock)
    assert float(m2[GUARD_KEY]) == 0.0
    assert not np.array_equal(np.asarray(s.p), p_before)


def test_guarded_step_max_loss_spike_counts_as_bad():
    g = guarded_step(_tiny_step, GuardPolicy(max_loss=1.0))
    s = _TinyState(jnp.ones(3), jnp.zeros((), jnp.int32))
    _, m = g(s, {"x": jnp.ones(3)})            # loss = 3.0 > 1.0
    assert float(m[GUARD_KEY]) == 1.0
    _, m = g(s, {"x": jnp.ones(3) * 0.1})      # loss = 0.3 <= 1.0
    assert float(m[GUARD_KEY]) == 0.0


def test_guard_policy_validates_action():
    with pytest.raises(ValueError, match="guard action"):
        GuardPolicy(action="panic")


def test_guarded_step_inside_scan_matches_step_loop():
    g = jax.jit(guarded_step(nan_at_step(_tiny_step, step=2), GuardPolicy()))
    xs = {"x": jnp.tile(jnp.arange(3.0) + 1, (5, 1))}
    s0 = _TinyState(jnp.ones(3), jnp.zeros((), jnp.int32))
    s_loop = s0
    for t in range(5):
        s_loop, _ = g(s_loop, {"x": xs["x"][t]})
    s_scan, ms = jax.lax.scan(lambda st, b: g(st, b), s0, xs)
    np.testing.assert_array_equal(np.asarray(s_scan.p), np.asarray(s_loop.p))
    np.testing.assert_array_equal(np.asarray(ms[GUARD_KEY]),
                                  [0.0, 0.0, 1.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# divergence guard on the Trainer: skip / rollback / abort
# ---------------------------------------------------------------------------

N_TR, D_TR, C_TR, K_TR, BATCH_TR = 256, 8, 4, 96, 16   # 6 steps per epoch


class _State(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array


def _cls_step(state, batch):
    loss, g = jax.value_and_grad(weighted_nll)(
        state.params, batch["x"], batch["y"], batch["weights"])
    p, m = nesterov_update(state.params, state.mom, g, 0.05)
    return _State(p, m, state.step + 1), {"loss": loss}


def _run_guarded(action=None, *, nan_step=None, ckpt_dir=None, fused=True,
                 epochs=3):
    feats, labs = _dataset(n=N_TR, d=D_TR, c=C_TR, seed=0)
    step = _cls_step if nan_step is None else nan_at_step(_cls_step,
                                                          step=nan_step)
    sel = build_selector("adaptive_random", n=N_TR, k=K_TR, R=1, seed=3)
    pipe = Pipeline(None, sel, BATCH_TR, seed=1,
                    arrays={"x": feats, "y": labs})
    tr = Trainer(
        jax.jit(step), pipe,
        TrainerConfig(epochs=epochs, log_every_steps=1,
                      checkpoint_dir=ckpt_dir,
                      checkpoint_every_steps=5 if ckpt_dir else 0,
                      async_checkpoint=False,
                      guard=None if action is None else GuardPolicy(
                          action=action)),
        fused=fused, superstep=32)
    params = init_mlp(jax.random.PRNGKey(0), D_TR, C_TR)
    state = _State(params, jax.tree.map(jnp.zeros_like, params),
                   jnp.zeros((), jnp.int32))
    return tr.fit(state, resume=bool(ckpt_dir)), tr


def _params_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a.params),
                               jax.tree.leaves(b.params)))


def test_guard_healthy_path_bit_identical_to_unguarded():
    """On clean data the guard is pure observation: same final params."""
    ref, tr_ref = _run_guarded(None)
    out, tr_out = _run_guarded("skip_step")
    assert _params_equal(ref, out)
    assert tr_out.guard_report() is None       # nothing tripped
    # the flag rode the existing metrics drain: every record carries it
    recs = [h for h in tr_out.history if "loss" in h]
    assert recs and all(h[GUARD_KEY] == 0.0 for h in recs)


def test_guard_rollback_bit_identical_to_skip(tmp_path):
    """The acceptance criterion: a NaN-injected run under ``rollback``
    (checkpoint restore + re-seeded replay) ends BIT-IDENTICAL to the same
    run under ``skip_step`` (in-scan zero-update), on both trainer paths."""
    skip, tr_skip = _run_guarded("skip_step", nan_step=8)
    assert int(skip.step) == 18
    rep = tr_skip.guard_report()
    assert rep["skipped_steps"] == 1 and rep["rollbacks"] == 0
    assert rep["events"] == [{"action": "skip_step", "step": 9, "epoch": 1}]

    rb, tr_rb = _run_guarded("rollback", nan_step=8,
                             ckpt_dir=str(tmp_path / "ckpt"))
    assert int(rb.step) == 18
    rep = tr_rb.guard_report()
    assert rep["rollbacks"] == 1 and rep["skipped_steps"] == 1
    restores = [h for h in tr_rb.history if h.get("guard") == "rollback"]
    assert len(restores) == 1 and restores[0]["restored_step"] == 5
    assert _params_equal(skip, rb)

    loop, tr_loop = _run_guarded("skip_step", nan_step=8, fused=False)
    assert _params_equal(skip, loop)
    assert tr_loop.guard_report()["skipped_steps"] == 1
    print("GUARD_ROLLBACK_BIT_IDENTICAL_OK")


def test_guard_abort_raises_divergence_error():
    with pytest.raises(DivergenceError):
        _run_guarded("abort", nan_step=8)


def test_guard_rollback_without_checkpoint_raises():
    with pytest.raises(DivergenceError, match="checkpoint"):
        _run_guarded("rollback", nan_step=8)   # no checkpoint_dir configured


def test_guard_rollback_budget_exhaustion_raises(tmp_path):
    feats, labs = _dataset(n=N_TR, d=D_TR, c=C_TR, seed=0)
    sel = build_selector("adaptive_random", n=N_TR, k=K_TR, R=1, seed=3)
    pipe = Pipeline(None, sel, BATCH_TR, seed=1,
                    arrays={"x": feats, "y": labs})
    tr = Trainer(
        jax.jit(nan_at_step(_cls_step, step=8)), pipe,
        TrainerConfig(epochs=3, checkpoint_dir=str(tmp_path),
                      checkpoint_every_steps=5, async_checkpoint=False,
                      guard=GuardPolicy(action="rollback", max_rollbacks=0)),
        fused=True, superstep=32)
    params = init_mlp(jax.random.PRNGKey(0), D_TR, C_TR)
    state = _State(params, jax.tree.map(jnp.zeros_like, params),
                   jnp.zeros((), jnp.int32))
    with pytest.raises(DivergenceError, match="rollback"):
        tr.fit(state, resume=True)


# ---------------------------------------------------------------------------
# hyperband trial quarantine (+ satellite 6: corrupt rung checkpoints)
# ---------------------------------------------------------------------------

HB_SPACE = {"lr": ("log", 1e-4, 1e-1), "hidden": ("choice", [16, 32, 64])}


def _hb_obj(cfg, budget):
    return -abs(cfg["lr"] - 0.01) * 100 + budget * 0.001 + cfg["hidden"] * 1e-5


def test_hyperband_quarantines_failing_trials_identically():
    """Three scripted always-failing configs must not change ``best_config``
    relative to a sweep where those configs are pre-excluded (scored with a
    finite floor).  RandomSearch's config stream ignores history, so the
    two sweeps see the identical trial sequence."""
    ref = hyperband(_hb_obj, RandomSearch(HB_SPACE, seed=7), max_budget=9,
                    eta=3)
    fail_cfgs = [dict(t["config"]) for t in ref.trials[:3]]

    def pre_excluded(cfg, budget):
        if any(cfg == c for c in fail_cfgs):
            return -1e9                        # finite floor: never promoted
        return _hb_obj(cfg, budget)

    excluded = hyperband(pre_excluded, RandomSearch(HB_SPACE, seed=7),
                         max_budget=9, eta=3)
    failing = fail_objective_for_configs(_hb_obj, fail_configs=fail_cfgs)
    quar = hyperband(failing, RandomSearch(HB_SPACE, seed=7), max_budget=9,
                     eta=3)
    assert quar.best_config == excluded.best_config
    assert quar.failed_trials == failing.failures_injected == 3
    failed = [t for t in quar.trials if t.get("failed")]
    assert len(failed) == 3
    assert all(t["score"] == -np.inf and "injected" in t["error"]
               for t in failed)
    # healthy trials carry no failure keys (checkpoint schema unchanged)
    assert all("failed" not in t
               for t in quar.trials if not t.get("failed"))


def test_hyperband_nonfinite_score_is_quarantined():
    calls = [0]

    def sometimes_nan(cfg, budget):
        calls[0] += 1
        return float("nan") if calls[0] == 2 else _hb_obj(cfg, budget)

    res = hyperband(sometimes_nan, RandomSearch(HB_SPACE, seed=3),
                    max_budget=9, eta=3)
    assert res.failed_trials == 1
    bad = [t for t in res.trials if t.get("failed")]
    assert len(bad) == 1 and "non-finite" in bad[0]["error"]
    assert np.isfinite(res.best_score)


def test_hyperband_all_trials_failed_raises():
    def always(cfg, budget):
        raise RuntimeError("diverged")

    with pytest.raises(RuntimeError, match="all .* failed"):
        hyperband(always, RandomSearch(HB_SPACE, seed=1), max_budget=3, eta=3)


def test_hyperband_failed_trials_survive_checkpoint_roundtrip(tmp_path):
    ck = str(tmp_path / "hb.json")
    fail_cfgs_holder = []

    ref = hyperband(_hb_obj, RandomSearch(HB_SPACE, seed=7), max_budget=9,
                    eta=3)
    fail_cfgs_holder = [dict(t["config"]) for t in ref.trials[:2]]
    failing = fail_objective_for_configs(_hb_obj,
                                         fail_configs=fail_cfgs_holder)
    run1 = hyperband(failing, RandomSearch(HB_SPACE, seed=7), max_budget=9,
                     eta=3, checkpoint=ck)
    assert run1.failed_trials == 2
    # a finished checkpoint round-trips -inf scores and failure records
    run2 = hyperband(_hb_obj, RandomSearch(HB_SPACE, seed=7), max_budget=9,
                     eta=3, checkpoint=ck)
    assert run2.failed_trials == 2
    assert run2.best_config == run1.best_config
    assert [t for t in run2.trials if t.get("failed")] == \
        [t for t in run1.trials if t.get("failed")]


@pytest.mark.parametrize("damage", ["truncate", "missing_key", "wrong_type"])
def test_hyperband_corrupt_checkpoint_raises_clean_error(tmp_path, damage):
    """Satellite 6: a torn / partially-written rung checkpoint must raise a
    clean 'corrupt hyperband checkpoint' error, never a KeyError from deep
    inside the resume bookkeeping."""
    ck = str(tmp_path / "hb.json")
    hyperband(_hb_obj, RandomSearch(HB_SPACE, seed=2), max_budget=3, eta=3,
              checkpoint=ck)
    if damage == "truncate":
        size = os.path.getsize(ck)
        with open(ck, "r+b") as f:
            f.truncate(size // 2)
    elif damage == "missing_key":
        state = json.load(open(ck))
        del state["trials"]                    # valid JSON, torn schema
        json.dump(state, open(ck, "w"))
    else:
        json.dump([1, 2, 3], open(ck, "w"))    # valid JSON, wrong shape
    with pytest.raises(ValueError, match="corrupt hyperband checkpoint"):
        hyperband(_hb_obj, RandomSearch(HB_SPACE, seed=2), max_budget=3,
                  eta=3, checkpoint=ck)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_breaker_closed_open_halfopen_cycle():
    clk = _Clock()
    br = CircuitBreaker(threshold=2, cooldown=10.0, clock=clk)
    br.check("k")                              # closed: no-op
    br.record_failure("k")
    br.check("k")                              # 1 failure < threshold
    br.record_failure("k")
    assert br.state("k") == "open"
    with pytest.raises(CircuitOpenError, match="fast-failing"):
        br.check("k")
    clk.t = 10.0                               # cooldown elapsed
    assert br.state("k") == "half_open"
    br.check("k")                              # first caller becomes probe
    with pytest.raises(CircuitOpenError, match="probe"):
        br.check("k")                          # concurrent callers fast-fail
    br.record_failure("k")                     # probe failed: re-open
    assert br.state("k") == "open"
    clk.t = 20.0
    br.check("k")
    br.record_success("k")                     # probe succeeded: closed
    assert br.state("k") == "closed"
    br.check("k")
    assert br.snapshot() == {}                 # success clears the key


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(threshold=3, cooldown=1.0)
    for _ in range(2):
        br.record_failure("k")
    br.record_success("k")                     # streak broken
    for _ in range(2):
        br.record_failure("k")
    assert br.state("k") == "closed"           # never reached 3 consecutive
    snap = br.snapshot()
    assert snap["k"] == {"state": "closed", "failures": 2}


# ---------------------------------------------------------------------------
# server hardening: bounded queue, breaker-gated builds, health()
# ---------------------------------------------------------------------------

def _serve_config(**kw):
    base = dict(subset_fraction=0.2, n_sge_subsets=2, gram_free=True,
                total_epochs=4, sub_steps=2)
    base.update(kw)
    return MiloSessionConfig(**base)


def test_server_overload_fast_fails_at_submit(monkeypatch):
    import threading

    from repro.serve import MiloServer, ServerOverloadedError

    feats, labs = _dataset(n=80)
    entered, release = threading.Event(), threading.Event()

    def blocking_build(self, *a, **kw):
        entered.set()
        release.wait(60)
        raise RuntimeError("never built")

    monkeypatch.setattr(MiloSession, "build_metadata", blocking_build)
    try:
        with MiloServer(_serve_config(), num_workers=1, max_queue=2) as srv:
            r1 = srv.submit("preprocess", features=feats, labels=labs)
            assert entered.wait(30)            # worker is stuck in the build
            srv.submit("preprocess", features=feats, labels=labs)
            srv.submit("preprocess", features=feats, labels=labs)
            with pytest.raises(ServerOverloadedError, match="queue full"):
                srv.submit("preprocess", features=feats, labels=labs)
            h = srv.health()
            assert h["status"] == "degraded"
            assert h["queue"] == {"depth": 2, "limit": 2}
            release.set()
            with pytest.raises(RuntimeError, match="never built"):
                srv.result(r1, timeout=60)
    finally:
        release.set()

    with pytest.raises(ValueError, match="max_queue"):
        MiloServer(_serve_config(), max_queue=0)


def test_server_breaker_trips_on_deterministic_build_failure(monkeypatch):
    from repro.serve import MiloServer

    feats, labs = _dataset(n=80)
    calls = [0]

    def always_broken(self, *a, **kw):
        calls[0] += 1
        raise ValueError("poisoned ground set")

    monkeypatch.setattr(MiloSession, "build_metadata", always_broken)
    br = CircuitBreaker(threshold=2, cooldown=1e9)
    with MiloServer(_serve_config(), num_workers=1, breaker=br) as srv:
        for _ in range(2):
            rid = srv.submit("preprocess", features=feats, labels=labs)
            with pytest.raises(ValueError, match="poisoned"):
                srv.result(rid, timeout=60)
        # circuit open: the third request fast-fails WITHOUT building
        rid = srv.submit("preprocess", features=feats, labels=labs)
        with pytest.raises(CircuitOpenError):
            srv.result(rid, timeout=60)
        assert calls[0] == 2                   # the build never ran again
        h = srv.health()
        assert h["status"] == "degraded" and len(h["tripped_keys"]) == 1
        # 2 real build failures + 1 breaker fast-fail (also a failed
        # resolution from the store's point of view)
        assert h["store"]["build_failures"] == 3
        from repro.serve import artifact_request_config

        key = srv.store.key_for(srv.data_fingerprint(feats),
                                artifact_request_config(srv.config))
        assert srv.store.failures_for(key) >= 2   # per-key failure streak
        assert srv.store.failures_for(("no", "such")) == 0


def test_server_health_ok_and_recovers(tmp_path):
    from repro.serve import MiloServer

    feats, labs = _dataset(n=80)
    with MiloServer(_serve_config(), store_root=str(tmp_path / "store"),
                    num_workers=1) as srv:
        h = srv.health()
        assert h["status"] == "ok" and h["breakers"] == {}
        rid = srv.submit("preprocess", features=feats, labels=labs)
        out = srv.result(rid, timeout=120)
        assert out["source"] == "built"
        h = srv.health()
        assert h["status"] == "ok" and h["failures"] == 0
        assert h["queue"]["depth"] == 0
        json.dumps(h)                          # endpoint-ready
    assert srv.health()["status"] == "stopped"


# ---------------------------------------------------------------------------
# degraded-mode selection: fallback chains
# ---------------------------------------------------------------------------

class _StubSelector:
    def __init__(self, weights=None, exc=None):
        self.weights = weights
        self.exc = exc
        self.resets = 0

    def plan(self, epoch):
        if self.exc is not None:
            raise self.exc
        return dataclasses.replace(
            uniform_plan(np.arange(4), "adaptive", epoch),
            weights=np.asarray(self.weights, np.float64))

    def reset_cache(self):
        self.resets += 1


def test_fallback_selector_degrades_with_provenance():
    good = _StubSelector(weights=[1.0, 1.0, 1.0, 1.0])
    fb = FallbackSelector([
        ("milo", lambda: _StubSelector(exc=SelectionDegenerateError("empty"))),
        ("adaptive_random", lambda: good),
    ])
    plan = fb.plan(0)
    assert fb.active_name == "adaptive_random"
    assert plan.provenance["fallback_from"] == "milo"
    assert plan.provenance["fallback_selector"] == "adaptive_random"
    assert len(fb.events) == 1 and fb.events[0]["stage"] == "plan"
    # the chain never goes back: the next plan skips the degenerate tier
    fb.plan(1)
    assert len(fb.events) == 1
    fb.reset_cache()
    assert good.resets == 1


def test_fallback_selector_catches_build_failures_and_nonfinite_weights():
    def broken_factory():
        raise ValueError("cannot build")

    fb = FallbackSelector([
        ("milo", broken_factory),
        ("el2n", lambda: _StubSelector(weights=[1.0, np.nan, 1.0, 1.0])),
        ("adaptive_random",
         lambda: _StubSelector(weights=[1.0, 1.0, 1.0, 1.0])),
    ])
    plan = fb.plan(0)
    assert np.isfinite(plan.weights).all()
    stages = [(e["selector"], e["stage"]) for e in fb.events]
    assert stages == [("milo", "build"), ("el2n", "plan")]


def test_fallback_selector_exhaustion_and_mismatch_passthrough():
    from repro.core.metadata import MetadataMismatchError

    with pytest.raises(ValueError, match="at least one"):
        FallbackSelector([])
    fb = FallbackSelector(
        [("a", lambda: _StubSelector(exc=ValueError("x")))])
    with pytest.raises(FallbackExhaustedError, match="a\\(plan\\)"):
        fb.plan(0)
    # config bugs are never degraded around
    fb2 = FallbackSelector([
        ("a", lambda: _StubSelector(exc=MetadataMismatchError("wrong"))),
        ("b", lambda: _StubSelector(weights=[1.0] * 4)),
    ])
    with pytest.raises(MetadataMismatchError):
        fb2.plan(0)


def test_session_selector_fallback_chain():
    """A session with a declared fallback chain degrades a failing primary
    (milo_fixed without features is a build-time ValueError) to
    adaptive_random, with the hop recorded in plan provenance."""
    cfg = MiloSessionConfig(selector="milo_fixed", subset_fraction=0.25,
                            selector_fallback=("adaptive_random",))
    sel = MiloSession(cfg).selector(n=64)
    plan = sel.plan(0)
    plan.validate(64)
    assert sel.active_name == "adaptive_random"
    assert plan.provenance["fallback_from"] == "milo_fixed"
    assert plan.provenance["fallback_events"][0]["stage"] == "build"
    # without the chain the same config raises
    bare = MiloSessionConfig(selector="milo_fixed", subset_fraction=0.25)
    with pytest.raises(ValueError, match="features"):
        MiloSession(bare).selector(n=64)
