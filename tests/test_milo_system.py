"""System-level behaviour of MILO: preprocessing artifacts, curriculum
selector, metadata persistence, and the paper's qualitative claims at CPU
scale (set-function hardness ordering; WRE bias; amortization)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CurriculumConfig,
    MiloMetadata,
    MiloPreprocessor,
    MiloSelector,
    gram_matrix,
    greedy,
)
from repro.core.submodular import disparity_min, graph_cut
from repro.data.datasets import GaussianMixtureDataset


@pytest.fixture(scope="module")
def dataset():
    return GaussianMixtureDataset(n=600, n_classes=6, dim=16, seed=0)


@pytest.fixture(scope="module")
def metadata(dataset):
    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4, gram_block=256)
    return pre.preprocess(dataset.features(), dataset.y, jax.random.PRNGKey(0))


def test_preprocess_artifact_structure(dataset, metadata):
    md = metadata
    assert md.k == 60
    assert md.sge_subsets.shape == (4, 60)
    for s in md.sge_subsets:
        assert len(set(s.tolist())) == 60
        assert s.min() >= 0 and s.max() < dataset.n
    np.testing.assert_allclose(md.wre_probs.sum(), 1.0, rtol=1e-5)
    assert (md.wre_probs > 0).all()
    # class-wise budgets cover every class proportionally
    assert md.class_budgets.sum() == 60
    assert (md.class_budgets > 0).all()


def test_sge_subsets_are_class_stratified(dataset, metadata):
    for s in metadata.sge_subsets:
        labs = dataset.y[s]
        counts = np.bincount(labs, minlength=6)
        assert (counts >= 5).all(), "every class represented per paper's partitioning"


def test_metadata_roundtrip(tmp_path, metadata):
    p = os.path.join(tmp_path, "milo.npz")
    metadata.save(p)
    md2 = MiloMetadata.load(p)
    np.testing.assert_array_equal(md2.sge_subsets, metadata.sge_subsets)
    np.testing.assert_allclose(md2.wre_probs, metadata.wre_probs)
    assert md2.config["easy_fn"] == "graph_cut"


def test_selector_follows_curriculum(metadata):
    cur = CurriculumConfig(total_epochs=12, kappa=1 / 6, R=1)
    sel = MiloSelector(metadata, cur, seed=0)
    # SGE phase: subsets come from the bank
    bank = {tuple(sorted(s.tolist())) for s in metadata.sge_subsets}
    for e in range(cur.sge_epochs):
        assert tuple(sorted(sel.indices_for_epoch(e).tolist())) in bank
    # WRE phase: fresh subsets, all valid, deterministic in (seed, epoch)
    a = sel.indices_for_epoch(5)
    sel2 = MiloSelector(metadata, cur, seed=0)
    np.testing.assert_array_equal(a, sel2.indices_for_epoch(5))
    b = sel.indices_for_epoch(6)
    assert set(a.tolist()) != set(b.tolist()), "R=1 must re-sample every epoch"


def test_representation_selects_easy_diversity_selects_hard(dataset):
    """Paper App. E: graph-cut subsets are 'easier' (dense-core) than
    disparity-min subsets (tail) — here measured with ground-truth hardness."""
    feats = dataset.features()
    k = 40
    hard_rate = {}
    for name, fn in [("graph_cut", graph_cut), ("disparity_min", disparity_min)]:
        # classwise to mirror the pipeline
        picks = []
        for c in np.unique(dataset.y):
            idx = np.nonzero(dataset.y == c)[0]
            K = gram_matrix(jnp.asarray(feats[idx]))
            sel = np.asarray(greedy(fn, K, k // 6).indices)
            picks.extend(idx[sel].tolist())
        hard_rate[name] = dataset.is_hard[picks].mean()
    assert hard_rate["disparity_min"] > hard_rate["graph_cut"] + 0.1, hard_rate


def test_wre_prefers_high_importance(metadata):
    """Samples drawn by WRE must be enriched in high-importance elements."""
    sel_counts = np.zeros(metadata.m)
    for t in range(200):
        idx = np.asarray(
            jax.jit(lambda key: jnp.zeros(()))(jax.random.PRNGKey(0))
        )  # warm no-op to keep jit cache tidy
        s = MiloSelector(metadata, CurriculumConfig(total_epochs=4, kappa=0.0, R=1), seed=t)
        sel_counts[s.indices_for_epoch(0)] += 1
    hi = metadata.wre_probs > np.quantile(metadata.wre_probs, 0.9)
    lo = metadata.wre_probs < np.quantile(metadata.wre_probs, 0.1)
    assert sel_counts[hi].mean() > sel_counts[lo].mean()


def test_amortization_selection_is_constant_time(metadata):
    """Per-epoch selection cost must not depend on dataset size (table lookup
    or Gumbel top-k) — the model-agnostic decoupling claim."""
    import time

    sel = MiloSelector(metadata, CurriculumConfig(total_epochs=10, kappa=0.5, R=1))
    sel.indices_for_epoch(6)  # warm
    t0 = time.perf_counter()
    for e in range(6, 10):
        sel._cache_epoch = -1  # defeat cache
        sel.indices_for_epoch(e)
    dt = (time.perf_counter() - t0) / 4
    assert dt < 0.25, f"WRE draw took {dt:.3f}s — not O(k log m)-ish"
