"""SSD chunk Pallas kernel: shape sweep vs the oracle and the model's scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk import ops
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_pallas
from repro.models.ssm import _ssd_chunk_scan

RNG = np.random.default_rng(0)


def _inputs(B, L, H, P, N, dtype=np.float32):
    return (
        jnp.asarray(RNG.normal(size=(B, L, H, P)).astype(dtype)),
        jnp.asarray(RNG.uniform(0.6, 1.0, size=(B, L, H)).astype(dtype)),
        jnp.asarray(RNG.normal(size=(B, L, N)).astype(dtype)),
        jnp.asarray(RNG.normal(size=(B, L, N)).astype(dtype)),
        jnp.asarray(RNG.normal(size=(B, H, N, P)).astype(np.float32) * 0.1),
    )


@pytest.mark.parametrize("B,L,H,P,N,bh", [
    (1, 8, 4, 4, 4, 4),
    (2, 16, 8, 8, 6, 4),
    (1, 32, 8, 4, 8, 8),
])
def test_ssd_chunk_kernel_vs_oracle(B, L, H, P, N, bh):
    x, a, b, c, h = _inputs(B, L, H, P, N)
    y_k, h_k = ssd_chunk_pallas(x, a, b, c, h, block_h=bh, interpret=True)
    y_r, h_r = jax.vmap(ssd_chunk_ref)(x, a, b, c, h)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-4)


def test_ssd_scan_matches_model_scan():
    B, S, H, P, N = 2, 40, 8, 4, 6
    x, a, b, c, _ = _inputs(B, S, H, P, N)
    y_ref, h_ref = _ssd_chunk_scan(x, a, b, c, chunk=8, return_state=True)
    y_p, h_p = ops.ssd_scan(x, a, b, c, chunk=8, use_pallas=True,
                            block_h=4, interpret=True)
    np.testing.assert_allclose(y_p, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h_p, h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_state_carry_composes():
    """Two chunks via the kernel == one double-length oracle chunk."""
    B, L, H, P, N = 1, 8, 4, 4, 4
    x, a, b, c, h0 = _inputs(B, 2 * L, H, P, N)
    y_full, h_full = jax.vmap(ssd_chunk_ref)(x, a, b, c, h0)
    y1, h1 = ssd_chunk_pallas(x[:, :L], a[:, :L], b[:, :L], c[:, :L], h0,
                              block_h=4, interpret=True)
    y2, h2 = ssd_chunk_pallas(x[:, L:], a[:, L:], b[:, L:], c[:, L:], h1,
                              block_h=4, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h2, h_full, rtol=1e-4, atol=1e-4)


def test_model_level_pallas_ssm_matches_chunked():
    """cfg.ssm_impl='pallas' routes mamba through the kernel — logits match."""
    import dataclasses

    from repro.configs import registry
    from repro.models import lm

    cfg = registry.smoke("jamba-1.5-large-398b")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l1, _ = lm.forward(params, cfg, tok)
    l2, _ = lm.forward(params, dataclasses.replace(cfg, ssm_impl="pallas"), tok)
    np.testing.assert_allclose(np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                               rtol=1e-3, atol=1e-3)
