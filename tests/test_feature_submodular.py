"""Kernel-free (landmark) submodular selection — quality vs the exact kernel
path, memory scaling, and engine compatibility (the paper's stated future
work, implemented; see core/feature_submodular.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import facility_location, gram_matrix, greedy
from repro.core.feature_submodular import (
    feature_facility_location,
    feature_graph_cut,
    feature_greedy_select,
    kmeans_pp_landmarks,
    landmark_features,
)
from repro.data.datasets import GaussianMixtureDataset


@pytest.fixture(scope="module")
def clustered():
    ds = GaussianMixtureDataset(n=400, n_classes=8, dim=16, seed=0)
    return jnp.asarray(ds.features()), ds


def test_landmark_features_shape_and_range(clustered):
    z, _ = clustered
    phi = landmark_features(jax.random.PRNGKey(0), z, 32)
    assert phi.shape == (400, 32)
    assert float(jnp.min(phi)) >= -1e-3 and float(jnp.max(phi)) <= 1 + 1e-3


def test_kmeans_pp_covers_clusters(clustered):
    z, ds = clustered
    centers = kmeans_pp_landmarks(jax.random.PRNGKey(1), z, 16)
    # every sample should be close to some landmark (coverage)
    d2 = jnp.min(jnp.sum((z[:, None] - centers[None]) ** 2, -1), axis=1)
    assert float(jnp.mean(jnp.sqrt(d2))) < float(jnp.std(z)) * 3


def test_feature_fl_greedy_near_exact_objective(clustered):
    """Landmark-FL selection must recover >=90% of the exact-FL objective."""
    z, _ = clustered
    k = 20
    K = gram_matrix(z)
    exact = greedy(facility_location, K, k)
    m_exact = np.zeros(z.shape[0], bool)
    m_exact[np.asarray(exact.indices)] = True
    v_exact = float(facility_location.evaluate(jnp.asarray(m_exact), K))

    sel = feature_greedy_select(jax.random.PRNGKey(0), z, k)
    m_feat = np.zeros(z.shape[0], bool)
    m_feat[np.asarray(sel.indices)] = True
    v_feat = float(facility_location.evaluate(jnp.asarray(m_feat), K))
    assert v_feat >= 0.9 * v_exact, (v_feat, v_exact)
    assert len(set(np.asarray(sel.indices).tolist())) == k


def test_feature_fl_gain_consistency(clustered):
    """Incremental gains must equal evaluate-deltas on the Φ ground set."""
    z, _ = clustered
    phi = landmark_features(jax.random.PRNGKey(0), z[:64], 16)
    fn = feature_facility_location
    state = fn.init(phi)
    mask = np.zeros(64, bool)
    rng = np.random.default_rng(0)
    for j in rng.permutation(64)[:8]:
        gains = np.asarray(fn.gains(state, phi))
        before = float(fn.evaluate(jnp.asarray(mask), phi))
        mask[j] = True
        after = float(fn.evaluate(jnp.asarray(mask), phi))
        np.testing.assert_allclose(gains[j], after - before, rtol=1e-4, atol=1e-4)
        state = fn.update(state, phi, jnp.asarray(j))


def test_feature_graph_cut_monotone_prefix(clustered):
    z, _ = clustered
    phi = landmark_features(jax.random.PRNGKey(0), z[:64], 16)
    res = greedy(feature_graph_cut, phi, 10)
    gains = np.asarray(res.gains)
    assert np.all(np.diff(gains) <= 1e-3), "diminishing returns along greedy"


def test_memory_scaling_vs_kernel():
    """The whole point: Φ is m x L, not m x m."""
    m, L = 2048, 64
    z = jnp.asarray(np.random.default_rng(0).normal(size=(m, 24)), jnp.float32)
    phi = landmark_features(jax.random.PRNGKey(0), z, L)
    assert phi.size == m * L
    assert m * m // phi.size == m // L  # 32x smaller than the Gram matrix here
