"""Hierarchical partition-then-refine selection (ISSUE 9).

The load-bearing claims pinned here:
  * the flat (``by_class``, ``refine_factor=1``) path through the refactored
    ``PartitionStrategy`` pipeline is BIT-identical to the pre-refactor
    preprocessor — golden SHA-256 hashes of every artifact array AND the
    config hash, for the gram and gram-free routes;
  * partition strategies produce disjoint covers with the documented
    block-size / label-purity / determinism properties;
  * ``proportional_budgets`` honors the min-1 floor (the [1,1,1,97] k=4
    starvation regression lives in test_exploration.py);
  * the two-level pipeline's objective stays within 5% of the exact flat
    greedy on a seeded n=4096 facility-location fixture (quantified ratio);
  * firewall quarantine composes with hierarchical decomposition — the
    two-level local→union→global index maps never resurrect a quarantined
    row, and the artifact still re-indexes over the full ground set;
  * hierarchical provenance is stamped into the artifact and ENFORCED on
    reuse (session load + adopt refuse a partition/refine mismatch);
  * ``milo_hier`` / ``milo_targeted`` are buildable through the registry
    and produce valid fixed plans;
  * warmup pre-compiles the hierarchical geometry: a real hierarchical
    preprocess after warmup records zero backend compiles.
"""
from __future__ import annotations

import hashlib

import jax
import numpy as np
import pytest

from repro.core.gram_free import make_gram_free_facility_location
from repro.core.greedy import greedy, lazy_greedy, refine
from repro.core.milo import MiloPreprocessor, hierarchical_select, targeted_select
from repro.core.partition import (
    BalancedBlocks,
    ByClass,
    RandomBlocks,
    make_partition_strategy,
    partition_by_class,
    proportional_budgets,
)
from repro.core.similarity import normalize_rows
from repro.core.metadata import MetadataMismatchError
from repro.selection import MiloSession, MiloSessionConfig, build_selector
from repro.testing.faults import poison_features


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _golden_dataset():
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(240, 16)).astype(np.float32)
    labels = rng.integers(0, 4, size=240).astype(np.int64)
    return feats, labels


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def _fl_value(feats: np.ndarray, idx: np.ndarray) -> float:
    """Exact facility-location objective (rescaled cosine) of a subset."""
    z = feats.astype(np.float64)
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    sim = 0.5 + 0.5 * z @ z[np.asarray(idx)].T
    return float(sim.max(axis=1).sum())


# ---------------------------------------------------------------------------
# partition strategies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", [
    ByClass(),
    RandomBlocks(block_size=32, seed=3),
    BalancedBlocks(block_size=32),
])
def test_partition_strategies_cover_and_disjoint(strategy):
    _, labels = _golden_dataset()
    m = len(labels)
    parts = strategy.partition(labels, m)
    seen = np.concatenate([p.indices for p in parts])
    assert len(seen) == m
    assert np.array_equal(np.sort(seen), np.arange(m))


def test_by_class_matches_legacy_partition():
    _, labels = _golden_dataset()
    legacy_parts = partition_by_class(labels)
    new = ByClass().partition(labels, len(labels))
    assert len(new) == len(legacy_parts)
    for a, b in zip(new, legacy_parts):
        assert a.label == b.label
        np.testing.assert_array_equal(a.indices, b.indices)
    # no labels -> one catch-all partition over the whole ground set
    solo = ByClass().partition(None, 7)
    assert len(solo) == 1
    np.testing.assert_array_equal(solo[0].indices, np.arange(7))


def test_random_blocks_size_bound_and_seed_determinism():
    parts = RandomBlocks(block_size=32, seed=3).partition(None, 240)
    assert all(len(p.indices) <= 32 for p in parts)
    again = RandomBlocks(block_size=32, seed=3).partition(None, 240)
    for a, b in zip(parts, again):
        np.testing.assert_array_equal(a.indices, b.indices)
    other = RandomBlocks(block_size=32, seed=4).partition(None, 240)
    assert any(not np.array_equal(a.indices, b.indices)
               for a, b in zip(parts, other))


def test_balanced_blocks_keep_class_purity():
    _, labels = _golden_dataset()
    parts = BalancedBlocks(block_size=30).partition(labels, len(labels))
    assert all(len(p.indices) <= 30 for p in parts)
    for p in parts:
        assert np.all(labels[p.indices] == p.label)
    # more partitions than classes: the oversize classes got split
    assert len(parts) > len(np.unique(labels))


def test_make_partition_strategy_registry():
    assert make_partition_strategy("by_class").name == "by_class"
    s = make_partition_strategy("random_blocks", block_size=7, seed=9)
    assert (s.block_size, s.seed) == (7, 9)
    assert make_partition_strategy("balanced_blocks", block_size=5).block_size == 5
    with pytest.raises(ValueError, match="unknown partition strategy"):
        make_partition_strategy("kmeans")
    with pytest.raises(ValueError, match="block_size"):
        RandomBlocks(block_size=0)


# ---------------------------------------------------------------------------
# flat-path neutrality: the refactor must not move a single bit
# ---------------------------------------------------------------------------

_GOLDEN = {
    # (gram_free) -> (sge, probs, importance, config_hash) pinned on the
    # pre-refactor class-wise monolith; any drift in the default path is a
    # regression even if selection quality looks unchanged
    False: ("183e11afc7d59924", "462fb2939d3fb31f",
            "5c3f1bd23d053f1a", "13532c3cc89b55af"),
    True: ("183e11afc7d59924", "a312eeb4ce603ac4",
           "4adf99770a3ef6fa", "010d8c24a018bbee"),
}


@pytest.mark.parametrize("gram_free", [False, True])
def test_flat_path_bit_identical_to_pre_refactor_golden(gram_free):
    feats, labels = _golden_dataset()
    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4,
                           gram_free=gram_free)
    md = pre.preprocess(feats, labels, jax.random.PRNGKey(0), prep_seed=0)
    want_sge, want_probs, want_imp, want_cfg = _GOLDEN[gram_free]
    assert _sha(np.asarray(md.sge_subsets, np.int64)) == want_sge
    assert _sha(np.asarray(md.wre_probs, np.float32)) == want_probs
    assert _sha(np.asarray(md.wre_importance, np.float32)) == want_imp
    assert md.config_hash() == want_cfg
    # legacy hash stability: the flat path stamps NO partition keys
    for key in ("partition", "partition_block", "partition_seed",
                "refine_factor"):
        assert key not in md.config
    assert list(md.class_budgets) == [6, 4, 8, 6]


# ---------------------------------------------------------------------------
# hierarchical artifacts
# ---------------------------------------------------------------------------

def test_hierarchical_artifact_valid_and_stamped():
    feats, labels = _golden_dataset()
    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4,
                           gram_free=True, partition="random_blocks",
                           partition_block=64, refine_factor=2)
    md = pre.preprocess(feats, labels, jax.random.PRNGKey(0), prep_seed=0)
    k = md.k
    assert md.sge_subsets.shape == (4, k)
    for slot in np.asarray(md.sge_subsets):
        assert len(set(slot.tolist())) == k, "bank rows must be unique"
        assert slot.min() >= 0 and slot.max() < len(labels)
    assert md.config["partition"] == "random_blocks"
    assert md.config["partition_block"] == 64
    assert md.config["partition_seed"] == 0
    assert md.config["refine_factor"] == 2
    probs = np.asarray(md.wre_probs, np.float64)
    assert np.isfinite(probs).all() and probs.min() >= 0
    np.testing.assert_allclose(probs.sum(), 1.0, rtol=1e-5)
    assert sum(md.class_budgets) == k
    # deterministic: a second pass is bit-identical
    md2 = pre.preprocess(feats, labels, jax.random.PRNGKey(0), prep_seed=0)
    np.testing.assert_array_equal(md.sge_subsets, md2.sge_subsets)
    np.testing.assert_array_equal(md.wre_probs, md2.wre_probs)


def test_refine_factor_alone_activates_hierarchical_stamping():
    """rf > 1 changes the bank (wider level-0 + refine) even under the
    paper's by_class split, so it must be stamped and enforced."""
    feats, labels = _golden_dataset()
    md = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4,
                          gram_free=True, refine_factor=2).preprocess(
        feats, labels, jax.random.PRNGKey(0), prep_seed=0)
    assert md.config["partition"] == "by_class"
    assert md.config["refine_factor"] == 2
    for slot in np.asarray(md.sge_subsets):
        assert len(set(slot.tolist())) == md.k


# ---------------------------------------------------------------------------
# approximation quality: two-level vs exact flat greedy (quantified)
# ---------------------------------------------------------------------------

def test_hierarchical_fl_objective_within_5pct_of_exact_flat_greedy():
    rng = np.random.default_rng(7)
    n, d, k = 4096, 32, 128
    feats = rng.normal(size=(n, d)).astype(np.float32)

    zn = normalize_rows(np.asarray(feats))
    flat = greedy(make_gram_free_facility_location(), zn, k)
    f_flat = _fl_value(feats, np.asarray(flat.indices))

    idx, info = hierarchical_select(
        feats, k, partition="random_blocks", block_size=512,
        refine_factor=2, gram_free=True, return_info=True)
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k
    assert info["n_partitions"] == 8
    assert info["peak_partition_rows"] <= 512
    f_hier = _fl_value(feats, idx)
    ratio = f_hier / f_flat
    assert ratio >= 0.95, f"hierarchical/flat objective ratio {ratio:.4f}"


def test_hierarchical_select_edge_cases():
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(40, 8)).astype(np.float32)
    # k == 0 and k > n both clamp cleanly
    assert hierarchical_select(feats, 0).shape == (0,)
    idx = hierarchical_select(feats, 100, partition="random_blocks",
                              block_size=16)
    assert len(set(idx.tolist())) == 40
    # one partition (block >= n) degrades to plain greedy
    one = hierarchical_select(feats, 5, partition="random_blocks",
                              block_size=64, refine_factor=2)
    zn = normalize_rows(np.asarray(feats))
    direct = np.asarray(greedy(make_gram_free_facility_location(), zn, 10).indices)
    # level-0 oversamples to 10 winners; refine keeps an FL-greedy 5 of them
    assert set(one.tolist()) <= set(direct.tolist())


# ---------------------------------------------------------------------------
# quarantine x hierarchy: two-level index maps compose with the firewall
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gram_free", [False, True])
def test_quarantine_composes_with_hierarchical_decomposition(gram_free):
    rng = np.random.default_rng(0)
    labs = rng.integers(0, 3, 80).astype(np.int64)
    feats = (rng.normal(size=(80, 6)) + 0.5 * labs[:, None]).astype(np.float32)
    bad = poison_features(feats, nan_rows=[5], zero_rows=[17, 40])
    pre = MiloPreprocessor(subset_fraction=0.25, n_sge_subsets=2,
                           gram_free=gram_free, firewall="quarantine",
                           partition="random_blocks", partition_block=16,
                           refine_factor=2)
    md = pre.preprocess(bad, labs, jax.random.PRNGKey(0))
    # artifact re-indexes over the FULL ground set through BOTH remaps:
    # quarantine keep-map o (partition local -> union -> global)
    assert md.wre_probs.shape[0] == 80
    for q in (5, 17, 40):
        assert md.wre_probs[q] == 0.0
        assert md.wre_importance[q] == 0.0
        assert not np.any(md.sge_subsets == q)
    assert np.isfinite(np.asarray(md.wre_probs)).all()
    for slot in np.asarray(md.sge_subsets):
        assert len(set(slot.tolist())) == md.k
        assert slot.min() >= 0 and slot.max() < 80
    assert md.config["firewall"] == "quarantine"
    assert md.config["data_health"]["quarantined_rows"] == [5, 17, 40]
    assert md.config["partition"] == "random_blocks"
    assert md.config["refine_factor"] == 2
    md2 = pre.preprocess(bad, labs, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(md.sge_subsets, md2.sge_subsets)
    np.testing.assert_array_equal(md.wre_probs, md2.wre_probs)


# ---------------------------------------------------------------------------
# artifact reuse: hierarchical provenance is enforced, not advisory
# ---------------------------------------------------------------------------

def _session_cfg(path, **kw):
    return MiloSessionConfig(subset_fraction=0.1, n_sge_subsets=2,
                             metadata_path=str(path), **kw)


def test_artifact_reuse_enforces_partition_config(tmp_path):
    feats, labels = _golden_dataset()
    path = tmp_path / "hier.npz"
    hier = dict(partition="random_blocks", partition_block=64,
                refine_factor=2)
    MiloSession(_session_cfg(path, **hier)).preprocess(feats, labels)

    # same hierarchical config: loads without recompute
    s2 = MiloSession(_session_cfg(path, **hier))
    s2.preprocess(feats, labels)
    assert s2.loaded_from_artifact

    # any partition/refine disagreement refuses the artifact
    for bad in (dict(partition="by_class"),
                dict(partition="random_blocks", partition_block=32,
                     refine_factor=2),
                dict(partition="random_blocks", partition_block=64,
                     partition_seed=1, refine_factor=2),
                dict(partition="random_blocks", partition_block=64,
                     refine_factor=3)):
        with pytest.raises(MetadataMismatchError, match="partition|refine"):
            MiloSession(_session_cfg(path, **bad)).preprocess(feats, labels)

    # legacy flat artifact: flat session loads, hierarchical session refuses
    flat_path = tmp_path / "flat.npz"
    MiloSession(_session_cfg(flat_path)).preprocess(feats, labels)
    s3 = MiloSession(_session_cfg(flat_path))
    s3.preprocess(feats, labels)
    assert s3.loaded_from_artifact
    with pytest.raises(MetadataMismatchError, match="partition"):
        MiloSession(_session_cfg(flat_path, **hier)).preprocess(feats, labels)


def test_adopt_metadata_enforces_partition_config(tmp_path):
    feats, labels = _golden_dataset()
    hier = dict(partition="random_blocks", partition_block=64,
                refine_factor=2)
    md = MiloSession(MiloSessionConfig(
        subset_fraction=0.1, n_sge_subsets=2, **hier)).build_metadata(
        feats, labels)
    flat_session = MiloSession(MiloSessionConfig(
        subset_fraction=0.1, n_sge_subsets=2))
    with pytest.raises(MetadataMismatchError, match="partition"):
        flat_session.adopt_metadata(md)
    hier_session = MiloSession(MiloSessionConfig(
        subset_fraction=0.1, n_sge_subsets=2, **hier))
    assert hier_session.adopt_metadata(md) is md


# ---------------------------------------------------------------------------
# refine engine
# ---------------------------------------------------------------------------

def test_refine_matches_greedy_and_lazy_trajectories():
    rng = np.random.default_rng(11)
    zn = normalize_rows(np.asarray(rng.normal(size=(256, 16)).astype(np.float32)))
    fn = make_gram_free_facility_location()
    k = 24
    eager = greedy(fn, zn, k)
    plain = refine(fn, zn, k)
    np.testing.assert_array_equal(np.asarray(plain.indices),
                                  np.asarray(eager.indices))
    lazy = refine(fn, zn, k, lazy_budget=32)
    np.testing.assert_array_equal(np.asarray(lazy.indices),
                                  np.asarray(eager.indices))
    ref = lazy_greedy(fn, zn, k, budget=32)
    np.testing.assert_array_equal(np.asarray(lazy.indices),
                                  np.asarray(ref.indices))


# ---------------------------------------------------------------------------
# targeted (query-conditioned) selection
# ---------------------------------------------------------------------------

def test_targeted_select_covers_queries():
    rng = np.random.default_rng(2)
    labs = rng.integers(0, 4, 400).astype(np.int64)
    feats = (rng.normal(size=(400, 16)) + 2.0 * labs[:, None]).astype(np.float32)
    target = 2
    q_idx = np.where(labs == target)[0][:12]
    queries = feats[q_idx]
    k = 8
    idx, info = targeted_select(feats, queries, k, labels=labs,
                                refine_factor=4, return_info=True)
    assert idx.shape == (k,) and len(set(idx.tolist())) == k
    assert info["n_partitions"] == 4

    def coverage(sel):
        z = feats.astype(np.float64)
        z /= np.linalg.norm(z, axis=1, keepdims=True)
        q = queries.astype(np.float64)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        return float((0.5 + 0.5 * z[np.asarray(sel)] @ q.T).max(axis=0).mean())

    # query FL saturates once each query has a near-duplicate in the subset
    # (picks past that point are near-zero-gain), so the sharp claims are
    # coverage dominance over the untargeted pipeline and a concentrated
    # majority — not a 100% hit-rate
    untargeted = hierarchical_select(feats, k, labels=labs,
                                     partition="by_class", refine_factor=4)
    assert coverage(idx) > coverage(untargeted)
    hit = float(np.mean(labs[idx] == target))
    base = float(np.mean(labs[untargeted] == target))
    assert hit >= 0.5 and hit > base, f"targeted hit {hit} vs baseline {base}"


def test_registry_builds_hier_and_targeted_selectors():
    rng = np.random.default_rng(3)
    labs = rng.integers(0, 3, 150).astype(np.int64)
    feats = (rng.normal(size=(150, 8)) + labs[:, None]).astype(np.float32)

    hier = build_selector("milo_hier", features=feats, k=15, labels=labs,
                          partition="balanced_blocks", partition_block=32,
                          refine_factor=2)
    plan = hier.plan(0)
    plan.validate(len(feats))
    assert plan.phase == "fixed"
    assert len(set(plan.indices.tolist())) == 15
    assert plan.provenance["selector"] == "milo_hier"
    # fixed plan: identical across epochs
    np.testing.assert_array_equal(plan.indices, hier.plan(5).indices)

    targeted = build_selector("milo_targeted", features=feats,
                              queries=feats[labs == 1][:6], k=5, labels=labs)
    tplan = targeted.plan(0)
    tplan.validate(len(feats))
    assert len(set(tplan.indices.tolist())) == 5
    assert tplan.provenance["selector"] == "milo_targeted"


# ---------------------------------------------------------------------------
# warmup covers the hierarchical geometry
# ---------------------------------------------------------------------------

def _count_backend_compiles(run):
    compiles: list[str] = []

    def listener(name, duration, **kwargs):
        if name == "/jax/core/compile/backend_compile_duration":
            compiles.append(name)

    from jax._src import monitoring as _monitoring

    unregister = getattr(
        _monitoring, "_unregister_event_duration_listener_by_callback", None)
    jax.monitoring.register_event_duration_secs_listener(listener)
    try:
        run()
    finally:
        if unregister is not None:
            unregister(listener)
        else:  # pragma: no cover
            jax.monitoring.clear_event_listeners()
    return len(compiles)


def test_warmup_precompiles_hierarchical_programs():
    """MiloServer.warm replays the strategy's decomposition through warmup;
    after it, a real hierarchical preprocess must compile NOTHING new."""
    rng = np.random.default_rng(41)
    labels = np.concatenate([np.repeat(np.arange(3), 30), np.full(14, 3)])
    feats = rng.normal(size=(len(labels), 8)).astype(np.float32)
    pre = MiloPreprocessor(subset_fraction=0.1, gram_free=True,
                           lazy_gains=True, hard_fn="facility_location",
                           partition="random_blocks", partition_block=32,
                           refine_factor=2)
    parts = pre.partition_strategy().partition(labels, len(labels))
    k = max(1, int(round(0.1 * len(labels))))
    buckets = [(len(p.indices), b)
               for p, b in zip(parts, proportional_budgets(parts, k))]
    assert pre.warmup(buckets, d=feats.shape[1]) >= 1
    md = None

    def run():
        nonlocal md
        md = pre.preprocess(feats, labels, jax.random.PRNGKey(0))

    n_compiles = _count_backend_compiles(run)
    assert n_compiles == 0, f"preprocess compiled {n_compiles} after warmup"
    assert md.config["partition"] == "random_blocks"
