"""Distribution substrate tests: sharding rules, checkpoint reshard/restart,
compression, elasticity, straggler monitor, pipeline determinism.

Runs on a small forced-host-device mesh (8 devices) — set before jax init
via a subprocess-safe env guard in conftest? No: this file relies on
xdist-free single-process execution and sets the flag only if jax is not yet
initialized with devices (pytest runs this in the same process as other
tests, so we use the CPU single-device path where possible and reserve the
8-device checks for the subprocess test).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import CheckpointManager
from repro.data.pipeline import FullSelector, Pipeline
from repro.distributed.compression import (
    compress_with_feedback,
    init_error_feedback,
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
)
from repro.distributed.fault_tolerance import StragglerMonitor, elastic_plan, restart_state


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(12).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.all_steps() == [2, 3]  # keep_last=2 garbage-collected step 1
    out = mgr.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(12).reshape(3, 4) + 3)
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((64, 64))}
    mgr.save_async(10, tree)
    mgr.wait()
    assert mgr.latest_step() == 10
    # no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_restore_with_resharding_single_device(tmp_path):
    """Restore with explicit shardings (single-device NamedSharding here;
    the multi-device reshard path is covered in the subprocess test)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out = mgr.restore(1, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]


def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    c = int8_compress(x)
    y = int8_decompress(c)
    rel = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
    assert rel < 1.5 / 127


def test_topk_compression_keeps_largest():
    x = jnp.asarray(np.r_[np.zeros(90), np.arange(1, 11)].astype(np.float32))
    vals, idx = topk_compress(x, density=0.1)
    y = topk_decompress(vals, idx, x.shape)
    np.testing.assert_allclose(np.asarray(y)[-10:], np.arange(1, 11))
    assert float(jnp.sum(jnp.abs(y[:90]))) == 0.0


def test_error_feedback_preserves_signal_over_steps():
    """With error feedback, the accumulated applied gradient converges to the
    accumulated true gradient (compression noise does not bias)."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    ef = init_error_feedback(g_true)
    applied = jnp.zeros((256,))
    steps = 30
    for _ in range(steps):
        out, ef = compress_with_feedback(g_true, ef, scheme="topk", density=0.05)
        applied = applied + out["w"]
    target = g_true["w"] * steps
    # direction aligned and magnitude within 20%
    cos = float(jnp.vdot(applied, target) / (jnp.linalg.norm(applied) * jnp.linalg.norm(target)))
    assert cos > 0.97
    assert 0.8 < float(jnp.linalg.norm(applied) / jnp.linalg.norm(target)) < 1.2


def test_elastic_plan_preserves_global_batch():
    p = elastic_plan(256, model_parallel=16, global_batch=256, microbatch_per_replica=16)
    assert p.mesh_shape == (16, 16) and p.grad_accum == 1
    # lose half the data axis -> accumulate 2x
    p = elastic_plan(128, model_parallel=16, global_batch=256, microbatch_per_replica=16)
    assert p.mesh_shape == (8, 16) and p.grad_accum == 2
    with pytest.raises(ValueError):
        elastic_plan(100, model_parallel=16, global_batch=256, microbatch_per_replica=16)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(warmup_steps=3, z_threshold=3.0)
    for i in range(20):
        mon.observe(i, 0.1 + 0.001 * (i % 3))
    assert mon.observe(20, 1.5) is True
    assert mon.flagged and mon.flagged[-1][0] == 20


def test_restart_state_deterministic():
    a = restart_state(7, 123, steps_per_epoch=10)
    b = restart_state(7, 123, steps_per_epoch=10)
    assert a == b and a["epoch"] == 12 and a["step_in_epoch"] == 3


def test_pipeline_deterministic_and_restartable():
    ds = np.arange(100)
    pipe = Pipeline(lambda idx: {"x": ds[idx]}, FullSelector(100), batch_size=8, seed=3,
                    prefetch=False)
    full = [b["x"].tolist() for b in pipe.epoch(2)]
    replay = [b["x"].tolist() for b in pipe.epoch(2, start_step=5)]
    assert replay == full[5:], "restart must replay the identical tail"
    again = [b["x"].tolist() for b in pipe.epoch(2)]
    assert again == full


def test_sharding_rules_divisibility_guard():
    """Non-divisible dims must replicate instead of relying on uneven GSPMD."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("model",))
    assert shd.maybe(mesh, 10, "model") == "model"  # divisible by 1
    # use the spec helper directly with a fake 16-wide mesh via monkeypatched
    # axis size: covered end-to-end by the dry-run, here just the API shape
    spec = shd._leaf_spec(mesh, "groups/b0/mixer/wq", (4, 64, 4, 16))
    assert isinstance(spec, P)


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import CheckpointManager
import sys

tmp = sys.argv[1]
from repro.launch.mesh import make_mesh
mesh1 = make_mesh((4, 2), ("data", "model"))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh1, P("data", "model")))
mgr = CheckpointManager(tmp)
mgr.save(5, {"w": x})
# elastic restart onto a DIFFERENT mesh shape
mesh2 = make_mesh((2, 4), ("data", "model"))
out = mgr.restore(5, {"w": x}, shardings={"w": NamedSharding(mesh2, P("data", "model"))})
assert out["w"].sharding.mesh.shape["data"] == 2
np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(64.0).reshape(8, 8))
print("RESHARD_OK")
"""


def test_checkpoint_elastic_reshard_multidevice(tmp_path):
    """Save on a (4,2) mesh, restore onto (2,4) — in a subprocess so the
    forced 8-device runtime never leaks into this test session."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=120,
    )
    assert "RESHARD_OK" in r.stdout, r.stderr[-2000:]
