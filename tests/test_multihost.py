"""Multi-host execution with coordinated fault tolerance (ISSUE 10).

Two tiers:

* **In-process (always run):** the multihost runtime primitives (env-driven
  init, barriers, heartbeat liveness with an injectable clock), the
  two-phase coordinated checkpoint protocol simulated with two
  ``CheckpointManager``s rendezvousing over a ``FileBarrier`` (all-or-nothing
  publication, torn-shard skipping visible to every host, dead-host
  detection), checksummed compression payloads, the ArtifactStore's
  cross-process lockfile, and the server's host-liveness health verdict.

* **Real two-process (env-gated):** set ``MILO_MULTIHOST_TESTS=1`` to launch
  actual coordinated jax process pairs (gloo CPU collectives) and pin the
  tentpole claims for real: a 2-process selection run — the global ``sel``
  mesh spanning both hosts — is BIT-identical to a single process exposing
  the same two devices, and SIGKILLing one host mid-epoch then restarting
  the pair reproduces the uninterrupted run's final params exactly
  (``MULTIHOST_KILL_RESUME_BIT_IDENTICAL_OK``).  CI's multihost-smoke job
  runs these on two local CPU processes.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import subprocess
import sys
import threading
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import CheckpointManager
from repro.core import get_gram_free, greedy, sharded_greedy
from repro.core.sharded import (
    _raise_if_corrupt,
    make_sharded_facility_location,
)
from repro.core.similarity import normalize_rows
from repro.distributed import multihost
from repro.distributed.compression import (
    CheckedPayload,
    CompressionIntegrityError,
    Int8Compressed,
    check_payload,
    compress_with_feedback,
    decompress_checked,
    init_error_feedback,
    int8_compress_checked,
    int8_decompress,
    payload_ok,
)
from repro.distributed.fault_tolerance import HostLossError
from repro.distributed.multihost import (
    FileBarrier,
    HeartbeatMonitor,
    HeartbeatWriter,
)
from repro.distributed.sharding import selection_mesh
from repro.serve import ArtifactStore, MiloServer
from repro.testing.faults import launch_hosts

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MULTIHOST = os.environ.get("MILO_MULTIHOST_TESTS") == "1"
two_process = pytest.mark.skipif(
    not MULTIHOST,
    reason="set MILO_MULTIHOST_TESTS=1 to launch real two-process jax jobs "
    "(CI multihost-smoke runs them)",
)


class State(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array


# ---------------------------------------------------------------------------
# runtime primitives
# ---------------------------------------------------------------------------

def test_initialize_is_noop_without_multihost_env(monkeypatch):
    """No env triplet, no args → initialize() must not touch the runtime."""
    for var in ("MILO_COORDINATOR", "MILO_NUM_PROCESSES", "MILO_PROCESS_ID"):
        monkeypatch.delenv(var, raising=False)
    assert multihost.initialize() is False
    # num_processes < 2 is also a no-op, not an error
    assert multihost.initialize("localhost:1", num_processes=1) is False
    assert multihost.process_count() == jax.process_count()
    assert multihost.is_coordinator() == (jax.process_index() == 0)


def test_single_process_mesh_and_global_put_round_trip():
    mesh = selection_mesh()
    assert not multihost.mesh_spans_processes(mesh)
    assert multihost.default_barrier() is None  # no coordination service
    # global_put is a uniform-placement no-op semantically: values survive
    x = jnp.arange(12.0).reshape(4, 3)
    from jax.sharding import PartitionSpec as P

    out = multihost.global_put(x, mesh, P(None, None))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_file_barrier_rendezvous_and_timeout(tmp_path):
    root = str(tmp_path / "bar")
    b0 = FileBarrier(root, 0, 2, timeout=10.0)
    b1 = FileBarrier(root, 1, 2, timeout=10.0)
    t = threading.Thread(target=b1.wait, args=("go",))
    t.start()
    b0.wait("go")
    t.join(timeout=10)
    assert not t.is_alive()

    alone = FileBarrier(str(tmp_path / "bar2"), 0, 2, timeout=0.2)
    with pytest.raises(HostLossError) as ei:
        alone.wait("nobody_comes")
    assert ei.value.hosts == (1,)


def test_heartbeat_staleness_is_a_pure_function_of_the_clock(tmp_path):
    t = {"now": 100.0}
    clock = lambda: t["now"]
    hb = str(tmp_path / "hb")
    w0 = HeartbeatWriter(hb, 0, clock=clock)
    w1 = HeartbeatWriter(hb, 1, clock=clock)
    mon = HeartbeatMonitor(hb, timeout=5.0, expected=2, clock=clock)
    w0.beat(0)
    w1.beat(0)
    assert mon.stale_hosts() == []
    mon.check()  # no raise
    # host 1 goes quiet; host 0 keeps beating
    t["now"] = 110.0
    w0.beat(7)
    assert mon.ages()[0] == pytest.approx(0.0)
    assert mon.ages()[1] == pytest.approx(10.0)
    assert mon.stale_hosts() == [1]
    with pytest.raises(HostLossError) as ei:
        mon.check()
    assert ei.value.hosts == (1,)


def test_heartbeat_never_seen_host_counts_stale_from_creation(tmp_path):
    """A host that never wrote a beat must not be invisible: expected hosts
    with no beacon age from the monitor's creation."""
    t = {"now": 0.0}
    hb = str(tmp_path / "hb")
    mon = HeartbeatMonitor(hb, timeout=5.0, expected=2, clock=lambda: t["now"])
    HeartbeatWriter(hb, 0, clock=lambda: t["now"]).beat(0)
    t["now"] = 6.0
    assert set(mon.stale_hosts()) == {0, 1}
    snap = mon.snapshot()
    assert snap["stale"] == [0, 1] and snap["expected"] == 2
    json.dumps(snap)  # JSON-safe for health()


# ---------------------------------------------------------------------------
# two-phase coordinated distributed checkpoint (simulated two hosts)
# ---------------------------------------------------------------------------

def _tree(offset: float = 0.0):
    return {"a": jnp.arange(12.0).reshape(3, 4) + offset,
            "b": {"c": jnp.ones((64,), jnp.float32) * (1 + offset)}}


def _two_host_save(ckpt_root, bar_root, step, tree, *, extra=None,
                   timeout=30.0):
    """Run one coordinated save on two CheckpointManagers (threads)."""
    mgrs = [
        CheckpointManager(
            ckpt_root, process_index=i, process_count=2,
            barrier=FileBarrier(bar_root, i, 2, timeout=timeout),
            barrier_timeout=timeout,
        )
        for i in range(2)
    ]
    errs: list[BaseException | None] = [None, None]

    def run(i):
        try:
            mgrs[i].save(step, tree, extra=extra)
        except BaseException as e:  # surfaced to the test
            errs[i] = e

    threads = [threading.Thread(target=run, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not any(t.is_alive() for t in threads), "a host hung in the save"
    return mgrs, errs


def test_two_phase_save_publishes_one_global_manifest(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    mgrs, errs = _two_host_save(ckpt, str(tmp_path / "bar"), 3, _tree(1.0),
                                extra={"process_count": 2})
    assert errs == [None, None]
    for mgr in mgrs:
        man = mgr.validate_step(3)
        assert man["format"] == 3
        assert man["num_shards"] == 2
        assert man["hosts"] == [0, 1]
        assert set(man["checksums"]) == {"shard_0.npz", "shard_1.npz"}
        assert man["extra"] == {"process_count": 2}
        assert mgr.latest_valid_step() == 3
    # replicated shards merge to the saved tree on restore
    out = mgrs[0].restore(3, _tree())
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.arange(12.0).reshape(3, 4) + 1.0)
    # no staging leftovers after a successful publish
    assert not os.path.exists(os.path.join(ckpt, "step_3.tmp"))


def test_torn_multihost_shard_skipped_on_every_host(tmp_path):
    """A published checkpoint losing ONE host's shard pages must be skipped
    by ``latest_valid_step`` on all hosts — the global manifest's checksums
    make the damage visible everywhere."""
    ckpt = str(tmp_path / "ckpt")
    _two_host_save(ckpt, str(tmp_path / "bar1"), 1, _tree(1.0))
    mgrs, errs = _two_host_save(ckpt, str(tmp_path / "bar2"), 2, _tree(2.0))
    assert errs == [None, None]
    shard1 = os.path.join(ckpt, "step_2", "shard_1.npz")
    size = os.path.getsize(shard1)
    with open(shard1, "r+b") as f:
        f.truncate(size // 2)
    for mgr in mgrs:
        assert not mgr.is_valid_step(2)
        assert mgr.latest_valid_step() == 1


def test_dead_host_publishes_nothing(tmp_path):
    """Host 1 never shows up: host 0 raises ``HostLossError`` naming it and
    NO checkpoint is published — all-or-nothing."""
    ckpt = str(tmp_path / "ckpt")
    mgr = CheckpointManager(
        ckpt, process_index=0, process_count=2,
        barrier=FileBarrier(str(tmp_path / "bar"), 0, 2, timeout=0.3),
        barrier_timeout=0.3,
    )
    with pytest.raises(HostLossError) as ei:
        mgr.save(5, _tree())
    assert ei.value.hosts == (1,)
    assert mgr.latest_valid_step() is None
    assert not os.path.exists(os.path.join(ckpt, "step_5"))


def test_multiprocess_manager_requires_a_barrier(tmp_path):
    """process_count > 1 with no coordination service and no injected
    barrier must fail loudly, not write an uncoordinated checkpoint."""
    mgr = CheckpointManager(str(tmp_path), process_index=0, process_count=2)
    with pytest.raises(RuntimeError, match="barrier"):
        mgr.save(1, _tree())


def test_single_host_manifest_format_unchanged(tmp_path):
    """The single-process path keeps writing format-2 manifests — the
    multi-host protocol must not perturb existing checkpoint consumers."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    man = mgr.validate_step(1)
    assert man["format"] == 2 and man["num_shards"] == 1


def test_resume_from_two_host_checkpoint_records_topology_change(tmp_path):
    """A single-process Trainer resuming a 2-host checkpoint restores the
    merged state and surfaces the process-count change in its history (the
    elastic-restart observable for the launch layer)."""
    from repro.data.pipeline import Pipeline
    from repro.models.classifier import init_mlp, nesterov_update, weighted_nll
    from repro.selection import build_selector
    from repro.train.trainer import Trainer, TrainerConfig

    N, D, C, K, BATCH = 128, 8, 4, 64, 16
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(N, D)).astype(np.float32)
    labs = rng.integers(0, C, size=N).astype(np.int64)

    def train_step(state, batch):
        loss, g = jax.value_and_grad(weighted_nll)(
            state.params, batch["x"], batch["y"], batch["weights"])
        p, m = nesterov_update(state.params, state.mom, g, 0.05)
        return State(p, m, state.step + 1), {"loss": loss}

    params = init_mlp(jax.random.PRNGKey(0), D, C)
    state = State(params, jax.tree.map(jnp.zeros_like, params),
                  jnp.zeros((), jnp.int32))
    ckpt = str(tmp_path / "ckpt")
    # a checkpoint written by a (fictional) 2-host run whose GLOBAL device
    # count happens to match this resume's (CPU: 1 device either way)
    _, errs = _two_host_save(
        ckpt, str(tmp_path / "bar"), 4, state,
        extra={"device_count": jax.device_count(), "process_count": 2,
               "data_seed": 1, "batch_size": BATCH},
    )
    assert errs == [None, None]

    sel = build_selector("adaptive_random", n=N, k=K, R=1, seed=3)
    pipe = Pipeline(None, sel, BATCH, seed=1, arrays={"x": feats, "y": labs})
    tr = Trainer(jax.jit(train_step), pipe,
                 TrainerConfig(epochs=2, checkpoint_dir=ckpt), fused=True)
    tr.fit(state, resume=True)
    recs = [h for h in tr.history if h.get("elastic")]
    assert len(recs) == 1 and recs[0]["step"] == 4
    assert recs[0]["process_count"] == [2, 1]
    assert tr.elastic is None  # device count unchanged → no re-tiling plan


# ---------------------------------------------------------------------------
# compression: checksummed payloads, EF determinism, exactness escape hatch
# ---------------------------------------------------------------------------

def test_int8_round_trip_exact_on_grid_and_exact_escape_hatch():
    """Values on the int8 grid survive compression bit-exactly, and the
    ``compress=None`` escape hatch is bit-identical to the single-device
    engine (the exactness contract the compressed path is measured against)."""
    q = jnp.arange(-127, 128, dtype=jnp.int32).astype(jnp.int8)
    x = q.astype(jnp.float32) * 0.5       # scale is exactly 0.5
    p = int8_compress_checked(x)
    assert bool(payload_ok(p))
    np.testing.assert_array_equal(np.asarray(p.q), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(decompress_checked(p)),
                                  np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(int8_decompress(Int8Compressed(p.q, p.scale))),
        np.asarray(x))

    rng = np.random.default_rng(0)
    z = normalize_rows(jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32)))
    fn_exact = make_sharded_facility_location(n_shards=1)
    assert "_c8" not in fn_exact.name
    a = greedy(get_gram_free("facility_location"), z, 8)
    b = sharded_greedy(fn_exact, z, 8, mesh=selection_mesh(1))
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.gains), np.asarray(b.gains))


def test_error_feedback_deterministic_under_fixed_seed():
    rng = np.random.default_rng(3)
    grads = {"w": jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))}
    key = jax.random.PRNGKey(5)

    def run():
        ef = init_error_feedback(grads)
        outs = []
        for _ in range(3):
            out, ef = compress_with_feedback(grads, ef, scheme="int8", key=key)
            outs.append(out)
        return outs, ef

    outs1, ef1 = run()
    outs2, ef2 = run()
    for o1, o2 in zip(outs1, outs2):
        for k in o1:
            np.testing.assert_array_equal(np.asarray(o1[k]), np.asarray(o2[k]))
    for k in grads:
        np.testing.assert_array_equal(np.asarray(ef1.residual[k]),
                                      np.asarray(ef2.residual[k]))
    # error feedback carries the quantization residual forward: after one
    # round, residual + delivered == accumulated signal, never dropped
    ef0 = init_error_feedback(grads)
    out1, ef_next = compress_with_feedback(grads, ef0, scheme="int8", key=key)
    np.testing.assert_allclose(
        np.asarray(ef_next.residual["w"]) + np.asarray(out1["w"]),
        np.asarray(grads["w"]) + np.asarray(ef0.residual["w"]),
        rtol=0, atol=1e-6)


def test_checksum_rejects_bit_flipped_payload():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(257,)).astype(np.float32))
    p = int8_compress_checked(x)
    check_payload(p)  # intact passes
    bad = CheckedPayload(p.q.at[3].set(p.q[3] ^ 1), p.scale, p.checksum)
    assert not bool(payload_ok(bad))
    assert np.isnan(np.asarray(decompress_checked(bad))).all()
    with pytest.raises(CompressionIntegrityError):
        check_payload(bad)


def test_compressed_setfunction_naming_and_corrupt_gain_guard():
    fnc = make_sharded_facility_location(n_shards=2, compress="int8",
                                         compress_rounds=3)
    assert fnc.name.endswith("_c8r3")  # distinct jit-cache identity
    with pytest.raises(ValueError, match="unknown compression scheme"):
        make_sharded_facility_location(n_shards=2, compress="zstd")

    class Compressed:
        name = "x_c8r2"

    class Exact:
        name = "x"

    with pytest.raises(CompressionIntegrityError):
        _raise_if_corrupt(Compressed, jnp.array([1.0, jnp.nan]))
    _raise_if_corrupt(Compressed, jnp.array([1.0, 2.0]))   # clean passes
    _raise_if_corrupt(Exact, jnp.array([jnp.nan]))          # not compressed


# ---------------------------------------------------------------------------
# ArtifactStore: cross-process O_EXCL lockfile
# ---------------------------------------------------------------------------

class _FakeArtifact:
    """Stands in for MiloMetadata where only ``save(path)``/``config`` matter."""

    config: dict = {}

    def save(self, path):
        with open(path, "wb") as f:
            f.write(b"artifact")


def test_store_lock_dead_pid_takeover(tmp_path):
    """A lockfile whose holder PID is dead is stolen (tombstone rename) and
    the build proceeds — a SIGKILLed builder cannot wedge the key."""
    store = ArtifactStore(str(tmp_path / "root"), lock_poll=0.001)
    key = ("f" * 16, "c" * 16)
    lock = store.path_for(key) + ".lock"
    dead = subprocess.Popen([sys.executable, "-c", "pass"])
    dead.wait()
    with open(lock, "w") as f:
        f.write(str(dead.pid))
    _, _, source = store.get_or_build(key, {}, _FakeArtifact)
    assert source == "built"
    assert store.lock_steals == 1
    assert not os.path.exists(lock)          # released after the build


def test_store_lock_live_holder_waiter_loads_peer_result(tmp_path):
    """While a LIVE process holds the lock, a waiter polls; the moment the
    holder's artifact lands on disk the waiter loads it instead of building."""
    store = ArtifactStore(str(tmp_path / "root"), lock_poll=0.005)
    key = ("a" * 16, "b" * 16)
    path = store.path_for(key)
    lock = path + ".lock"
    with open(lock, "w") as f:
        f.write(str(os.getpid()))            # alive: never stolen

    # a peer's finished artifact, produced through the same save path
    peer = os.path.join(str(tmp_path), "peer.npz")
    _FakeArtifact().save(peer)

    def never_builds():
        raise AssertionError("waiter must not build while a peer holds the lock")

    results: list = []
    import repro.serve.store as store_mod

    orig_load = store_mod.MiloMetadata.load

    def fake_load(p, expected_config=None):
        assert p == path
        return _FakeArtifact()

    store_mod.MiloMetadata.load = staticmethod(fake_load)
    try:
        t = threading.Thread(
            target=lambda: results.append(
                store.get_or_build(key, {}, never_builds)))
        t.start()
        import time as _time

        _time.sleep(0.05)                    # waiter is polling the lock
        os.replace(peer, path)               # peer's atomic publish lands
        t.join(timeout=30)
    finally:
        store_mod.MiloMetadata.load = orig_load
        os.unlink(lock)
    assert not t.is_alive()
    assert results and results[0][2] == "disk"
    assert store.lock_waits == 1 and store.builds == 0


def test_store_lock_timeout_builds_without_lock(tmp_path):
    """A stuck-but-alive holder only stalls waiters until lock_timeout; then
    the waiter builds redundantly (atomic save ⇒ no torn file) rather than
    hang forever."""
    ticks = iter(float(i) for i in range(1000))
    store = ArtifactStore(
        str(tmp_path / "root"), lock_timeout=0.5,
        clock=lambda: next(ticks), sleep=lambda s: None,
    )
    key = ("d" * 16, "e" * 16)
    lock = store.path_for(key) + ".lock"
    with open(lock, "w") as f:
        f.write(str(os.getpid()))            # alive and never releasing
    _, _, source = store.get_or_build(key, {}, _FakeArtifact)
    assert source == "built"
    assert store.lock_timeouts == 1 and store.lock_waits == 1
    assert os.path.exists(lock)              # not ours: never released


# ---------------------------------------------------------------------------
# server health: per-host heartbeat liveness
# ---------------------------------------------------------------------------

def test_server_health_degrades_on_stale_host(tmp_path):
    t = {"now": 0.0}
    clock = lambda: t["now"]
    hb = str(tmp_path / "hb")
    w0 = HeartbeatWriter(hb, 0, clock=clock)
    w1 = HeartbeatWriter(hb, 1, clock=clock)
    w0.beat(0)
    w1.beat(0)
    mon = HeartbeatMonitor(hb, timeout=5.0, expected=2, clock=clock)
    with MiloServer(num_workers=1, heartbeat_monitor=mon) as srv:
        h = srv.health()
        assert h["status"] == "ok"
        assert h["hosts"]["stale"] == [] and set(h["hosts"]["ages"]) == {"0", "1"}
        t["now"] = 10.0
        w0.beat(1)                           # host 0 alive, host 1 silent
        h = srv.health()
        assert h["status"] == "degraded"
        assert h["hosts"]["stale"] == [1]
        assert h["hosts"]["ages"]["1"] == pytest.approx(10.0)
    assert srv.health()["status"] == "stopped"


# ---------------------------------------------------------------------------
# real two-process jobs (env-gated; CI multihost-smoke)
# ---------------------------------------------------------------------------

#: children each expose ONE CPU device so the global mesh is 2 devices —
#: the same logical mesh the single-process reference forces locally
CHILD_ENV = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "XLA_FLAGS": ""}

SELECT_SCRIPT = r"""
import json, sys
out = sys.argv[1]
from repro.distributed import multihost
multihost.initialize()
import jax
import numpy as np
import jax.numpy as jnp
from repro.core import make_sharded_gram_free, sharded_greedy
from repro.core.similarity import normalize_rows
from repro.distributed.sharding import selection_mesh
from repro.selection import build_selector

assert jax.device_count() == 2, jax.device_count()
rng = np.random.default_rng(0)
feats = rng.normal(size=(256, 16)).astype(np.float32)
z = normalize_rows(jnp.asarray(feats))
mesh = selection_mesh()
fn = make_sharded_gram_free("facility_location", n_shards=2)
res = sharded_greedy(fn, z, 24, mesh=mesh)
fnc = make_sharded_gram_free("facility_location", n_shards=2,
                             compress="int8", compress_rounds=2)
resc = sharded_greedy(fnc, z, 24, mesh=mesh)
plan = build_selector("milo_fixed", features=feats, k=32,
                      shard_selection=True).plan(0)
bits = lambda a: np.asarray(a, np.float32).view(np.uint32).tolist()
payload = {
    "devices": jax.device_count(),
    "indices": np.asarray(res.indices).tolist(),
    "gains_bits": bits(res.gains),
    "c_indices": np.asarray(resc.indices).tolist(),
    "c_gains_bits": bits(resc.gains),
    "plan_indices": np.asarray(plan.indices).tolist(),
    "plan_weights_bits": bits(plan.weights),
    "plan_phase": plan.phase,
}
with open(f"{out}.{jax.process_index()}.json", "w") as f:
    json.dump(payload, f)
print("SELECT_DONE", jax.process_index())
"""

TRAIN_SCRIPT = r"""
import sys
mode, ckpt_dir, hb_dir, out = sys.argv[1:5]
from repro.distributed import multihost
multihost.initialize()
import numpy as np, jax, jax.numpy as jnp
from typing import NamedTuple
from repro.data.pipeline import Pipeline
from repro.models.classifier import init_mlp, nesterov_update, weighted_nll
from repro.selection import build_selector
from repro.train.trainer import Trainer, TrainerConfig

N, D, C, K, BATCH = 256, 8, 4, 96, 16      # 6 steps per epoch
rng = np.random.default_rng(0)
feats = rng.normal(size=(N, D)).astype(np.float32)
labs = rng.integers(0, C, size=N).astype(np.int64)

class State(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array

def train_step(state, batch):
    loss, g = jax.value_and_grad(weighted_nll)(
        state.params, batch["x"], batch["y"], batch["weights"])
    p, m = nesterov_update(state.params, state.mom, g, 0.05)
    return State(p, m, state.step + 1), {"loss": loss}

sel = build_selector("adaptive_random", n=N, k=K, R=1, seed=3)
pipe = Pipeline(None, sel, BATCH, seed=1, arrays={"x": feats, "y": labs})
tr = Trainer(jax.jit(train_step), pipe,
             TrainerConfig(epochs=3, checkpoint_dir=ckpt_dir,
                           checkpoint_every_steps=4, async_checkpoint=False,
                           log_every_steps=1, barrier_timeout=10.0,
                           heartbeat_dir=(None if hb_dir == "none" else hb_dir),
                           heartbeat_timeout=300.0),
             fused=False)
if mode == "kill":
    from repro.testing.faults import KillHost
    tr.monitor = KillHost(10, process_to_kill=1)   # mid-epoch 1
params = init_mlp(jax.random.PRNGKey(0), D, C)
state = State(params, jax.tree.map(jnp.zeros_like, params),
              jnp.zeros((), jnp.int32))
state = tr.fit(state, resume=True)
flat = {f"p{i}": np.asarray(l)
        for i, l in enumerate(jax.tree.leaves(state.params))}
np.savez(f"{out}.{jax.process_index()}.npz", step=int(state.step), **flat)
print("TRAIN_COMPLETE", jax.process_index(), int(state.step))
"""


def _run_single(script, argv, *, force_devices=None, timeout=300):
    """Run the same child script as ONE process (the bit-identity reference)."""
    env = dict(os.environ)
    for var in ("MILO_COORDINATOR", "MILO_NUM_PROCESSES", "MILO_PROCESS_ID"):
        env.pop(var, None)
    env.update(CHILD_ENV)
    if force_devices:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={force_devices}")
    r = subprocess.run(
        [sys.executable, "-c", script, *[str(a) for a in argv]],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT,
        timeout=timeout,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r


@two_process
def test_two_process_selection_plan_bit_identical(tmp_path):
    """The tentpole equivalence: 2 coordinated processes × 1 device each run
    the SAME logical selection programs as 1 process × 2 forced devices —
    indices, gains (exact AND compressed), and the SelectionPlan are
    bit-identical, and every host observes identical replicated results."""
    out2 = str(tmp_path / "two")
    results = launch_hosts(SELECT_SCRIPT, [out2], num_processes=2,
                           env=CHILD_ENV, cwd=REPO_ROOT, timeout=420.0)
    for r in results:
        assert r.returncode == 0, (r.process_id, r.stderr[-3000:])
        assert "SELECT_DONE" in r.stdout

    ref = str(tmp_path / "ref")
    _run_single(SELECT_SCRIPT, [ref], force_devices=2, timeout=420)

    with open(f"{ref}.0.json") as f:
        want = json.load(f)
    for i in range(2):
        with open(f"{out2}.{i}.json") as f:
            got = json.load(f)
        assert got == want, f"process {i} diverged from single-process run"


@two_process
def test_two_process_kill_resume_bit_identical(tmp_path):
    """SIGKILL host 1 mid-epoch (two-phase checkpoints every 4 steps),
    restart the pair, and require final params BIT-identical to both the
    uninterrupted two-process run and a plain single-process run."""
    # uninterrupted two-process reference
    ref = launch_hosts(
        TRAIN_SCRIPT, ["run", str(tmp_path / "ck_ref"), "none",
                       str(tmp_path / "ref")],
        num_processes=2, env=CHILD_ENV, cwd=REPO_ROOT, timeout=420.0)
    for r in ref:
        assert r.returncode == 0, (r.process_id, r.stderr[-3000:])
        assert "TRAIN_COMPLETE" in r.stdout

    # single-process reference (no coordination service at all)
    _run_single(TRAIN_SCRIPT,
                ["run", str(tmp_path / "ck_one"), "none",
                 str(tmp_path / "one")], timeout=420)

    # kill host 1 mid-epoch; heartbeats on (the beat path runs for real)
    ck = str(tmp_path / "ck")
    hb = str(tmp_path / "hb")
    dead = launch_hosts(
        TRAIN_SCRIPT, ["kill", ck, hb, str(tmp_path / "dead")],
        num_processes=2, env=CHILD_ENV, cwd=REPO_ROOT, timeout=420.0)
    assert dead[1].returncode == -signal.SIGKILL, dead[1].returncode
    # the survivor detects the loss (checkpoint barrier timeout) and exits
    # NONZERO — never hangs, never completes (rc may be the HostLossError
    # exit or the runtime's shutdown abort; both are loud failures)
    assert dead[0].returncode != 0, dead[0].returncode
    assert "TRAIN_COMPLETE" not in dead[0].stdout
    assert "HostLossError" in dead[0].stderr, dead[0].stderr[-3000:]

    # the two-phase protocol left only complete checkpoints behind
    view = CheckpointManager(ck)
    assert view.latest_valid_step() == 8
    assert view.validate_step(8)["num_shards"] == 2

    # restart the pair: resumes from step 8, replays deterministically
    res = launch_hosts(
        TRAIN_SCRIPT, ["run", ck, hb, str(tmp_path / "res")],
        num_processes=2, env=CHILD_ENV, cwd=REPO_ROOT, timeout=420.0)
    for r in res:
        assert r.returncode == 0, (r.process_id, r.stderr[-3000:])
        assert "TRAIN_COMPLETE" in r.stdout

    def load(path):
        with np.load(path) as z:
            return {k: z[k] for k in z.files}

    want = load(str(tmp_path / "ref") + ".0.npz")
    assert int(want["step"]) == 18
    for name in ("ref.1", "one.0", "res.0", "res.1"):
        got = load(str(tmp_path / name) + ".npz")
        assert int(got["step"]) == 18, name
        for k in want:
            np.testing.assert_array_equal(want[k], got[k], err_msg=name)
    print("MULTIHOST_KILL_RESUME_BIT_IDENTICAL_OK")
