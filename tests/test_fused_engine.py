"""Device-resident fused training engine (ISSUE 5 tentpole).

Equivalence contract: ``Trainer(fused=True, superstep=S)`` consumes the same
(seed, epoch, step) batch stream as the per-batch step loop — same permuted
plan indices, same weights, same remainder handling — so final params and
history must agree; checkpoints land on the same global steps and restart
replay from a mid-epoch checkpoint reproduces the uninterrupted run.  The
superstep donates the input state's buffers (zero-copy state updates).

Batched hyperband: ``hyperband(..., batched_objective=...)`` evaluates all
surviving configs of a rung in one call with bookkeeping identical to the
sequential path — same trial stream, same best config under fixed seeds.
"""
from __future__ import annotations

import shutil
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Pipeline
from repro.models.classifier import init_mlp, nesterov_update, weighted_nll
from repro.selection import build_selector
from repro.train.engine import epoch_engine, make_superstep, segment_length
from repro.train.trainer import Trainer, TrainerConfig
from repro.tuning.tuner import (
    RandomSearch,
    hyperband,
    shape_bucketed_objective,
    stack_configs,
)

N, D, CLASSES = 256, 8, 4
K, BATCH = 96, 16          # 6 steps per epoch


class _State(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array


def _train_step(state: _State, batch: dict):
    loss, g = jax.value_and_grad(weighted_nll)(
        state.params, batch["x"], batch["y"], batch["weights"]
    )
    params, mom = nesterov_update(state.params, state.mom, g, 0.05)
    return _State(params, mom, state.step + 1), {"loss": loss}


_STEP = jax.jit(_train_step)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(N, D)).astype(np.float32)
    labs = rng.integers(0, CLASSES, size=N).astype(np.int64)
    return feats, labs


def _init_state(seed: int = 0) -> _State:
    params = init_mlp(jax.random.PRNGKey(seed), D, CLASSES)
    return _State(params, jax.tree.map(jnp.zeros_like, params),
                  jnp.zeros((), jnp.int32))


def _pipelines(feats, labs, selector=None, **kw):
    sel = selector or build_selector("adaptive_random", n=N, k=K, R=1, seed=3)

    def make_batch(idx):
        return {"x": feats[idx], "y": labs[idx]}

    loop = Pipeline(make_batch, sel, BATCH, seed=1, prefetch=False, **kw)
    fused = Pipeline(None, sel, BATCH, seed=1,
                     arrays={"x": feats, "y": labs}, **kw)
    return loop, fused


def _assert_params_close(a, b, **kw):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), **kw)


# ---------------------------------------------------------------------------
# fused vs loop equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("superstep", [1, 4, 32])
def test_fused_matches_loop_params_and_history(data, superstep):
    """Same (seed, epoch, step) stream: final params and per-step history
    agree between the fused engine and the per-batch loop, for supersteps
    below, at, and above the epoch length."""
    feats, labs = data
    loop_pipe, fused_pipe = _pipelines(feats, labs)
    tcfg = TrainerConfig(epochs=3, log_every_steps=1)
    tr_loop = Trainer(_STEP, loop_pipe, tcfg)
    tr_fused = Trainer(_STEP, fused_pipe, tcfg, fused=True, superstep=superstep)
    assert tr_fused.fused_active() and not tr_loop.fused_active()

    s_loop = tr_loop.fit(_init_state(), resume=False)
    s_fused = tr_fused.fit(_init_state(), resume=False)

    assert int(s_loop.step) == int(s_fused.step) == 18
    _assert_params_close(s_loop.params, s_fused.params, rtol=1e-5, atol=1e-6)
    assert len(tr_loop.history) == len(tr_fused.history) == 18
    for ha, hb in zip(tr_loop.history, tr_fused.history):
        # wall/straggler are wall-clock observables; everything else matches
        assert (ha["step"], ha["epoch"], ha["phase"]) == (
            hb["step"], hb["epoch"], hb["phase"])
        np.testing.assert_allclose(ha["loss"], hb["loss"], rtol=1e-6)


def test_fused_respects_log_every_and_weights(data):
    """Plan weights flow into the on-device batches (non-uniform weights
    change the loss) and log_every_steps>1 thins history identically."""
    feats, labs = data
    md_sel = build_selector("craig_pb", grad_fn=lambda: feats, k=K, R=1)
    assert not np.allclose(md_sel.plan(0).weights, 1.0)  # genuinely weighted
    loop_pipe, fused_pipe = _pipelines(feats, labs, selector=md_sel)
    tcfg = TrainerConfig(epochs=2, log_every_steps=2)
    tr_loop = Trainer(_STEP, loop_pipe, tcfg)
    tr_fused = Trainer(_STEP, fused_pipe, tcfg, fused=True, superstep=4)
    s_loop = tr_loop.fit(_init_state(), resume=False)
    s_fused = tr_fused.fit(_init_state(), resume=False)
    _assert_params_close(s_loop.params, s_fused.params, rtol=1e-5, atol=1e-6)
    assert [h["step"] for h in tr_fused.history] == [2, 4, 6, 8, 10, 12]
    for ha, hb in zip(tr_loop.history, tr_fused.history):
        np.testing.assert_allclose(ha["loss"], hb["loss"], rtol=1e-6)


def test_async_history_drain_bit_identical(data):
    """ISSUE 6 satellite: the overlapped device→host metrics drain
    (TrainerConfig.async_history, the default) produces history records
    BIT-identical to the synchronous drain — same keys, same float bits,
    same order, with eval records landing at the same positions — because
    only the copy's wall-clock timing moves, never its content."""
    feats, labs = data

    def eval_fn(state):
        return {"pnorm": jnp.sqrt(sum(
            jnp.sum(l * l) for l in jax.tree.leaves(state.params)))}

    histories = {}
    for async_history in (True, False):
        _, fused_pipe = _pipelines(feats, labs)
        tcfg = TrainerConfig(epochs=3, log_every_steps=2,
                             eval_every_epochs=1, async_history=async_history)
        tr = Trainer(_STEP, fused_pipe, tcfg, fused=True, superstep=4,
                     eval_fn=eval_fn)
        assert tr.fused_active()
        tr.fit(_init_state(), resume=False)
        histories[async_history] = tr.history

    a, b = histories[True], histories[False]
    assert len(a) == len(b) and len(a) > 0
    assert any("eval" in h for h in a)
    for ha, hb in zip(a, b):
        assert set(ha) == set(hb)
        for key in ha:
            if key == "wall":
                continue  # the only observable allowed to move
            assert ha[key] == hb[key], (key, ha, hb)


def test_fused_wrap_padded_remainder_matches_loop(data):
    """drop_remainder=False wrap-pads the final short batch identically on
    both paths."""
    feats, labs = data
    sel = build_selector("random", n=N, k=90, seed=5)   # 90 % 16 != 0
    loop_pipe, fused_pipe = _pipelines(feats, labs, selector=sel,
                                       drop_remainder=False)
    tcfg = TrainerConfig(epochs=2, log_every_steps=1)
    tr_loop = Trainer(_STEP, loop_pipe, tcfg)
    tr_fused = Trainer(_STEP, fused_pipe, tcfg, fused=True, superstep=4)
    s_loop = tr_loop.fit(_init_state(), resume=False)
    s_fused = tr_fused.fit(_init_state(), resume=False)
    assert int(s_loop.step) == int(s_fused.step) == 12
    _assert_params_close(s_loop.params, s_fused.params, rtol=1e-5, atol=1e-6)


def test_fused_falls_back_without_column_store(data):
    """A custom make_batch pipeline (no arrays) silently takes the loop
    path; a custom put_batch forces it too."""
    feats, labs = data
    loop_pipe, fused_pipe = _pipelines(feats, labs)
    tr = Trainer(_STEP, loop_pipe, TrainerConfig(epochs=1), fused=True)
    assert not tr.fused_active()
    state = tr.fit(_init_state(), resume=False)
    assert int(state.step) == 6
    tr2 = Trainer(_STEP, fused_pipe, TrainerConfig(epochs=1), fused=True,
                  put_batch=lambda b: b)
    assert not tr2.fused_active()


def test_device_epoch_matches_epoch_batches(data):
    """device_epoch's (indices, weights) stream is exactly the content of
    epoch()'s batches, including start_step offsets and wrap padding."""
    feats, labs = data
    for drop in (True, False):
        sel = build_selector("random", n=N, k=90, seed=7)
        pipe = Pipeline(None, sel, BATCH, seed=2, drop_remainder=drop,
                        arrays={"x": feats, "y": labs})
        for start in (0, 2):
            idx, w = pipe.device_epoch(4, start_step=start)
            batches = list(pipe.epoch(4, start_step=start))
            assert idx.shape[0] == len(batches)
            for t, b in enumerate(batches):
                np.testing.assert_array_equal(
                    np.asarray(feats[np.asarray(idx[t])]), b["x"])
                np.testing.assert_array_equal(np.asarray(w[t]), b["weights"])


def test_pipeline_arrays_validation(data):
    feats, labs = data
    sel = build_selector("random", n=N, k=K, seed=0)
    with pytest.raises(ValueError, match="length"):
        Pipeline(None, sel, BATCH, arrays={"x": feats, "y": labs[:-1]})
    with pytest.raises(ValueError, match="weight_key"):
        Pipeline(None, sel, BATCH,
                 arrays={"x": feats, "weights": np.ones(N, np.float32)})
    with pytest.raises(ValueError, match="arrays"):
        Pipeline(None, sel, BATCH)
    plain = Pipeline(lambda i: {"x": feats[i]}, sel, BATCH)
    assert not plain.supports_device_epoch
    with pytest.raises(ValueError, match="device_epoch"):
        plain.device_epoch(0)


# ---------------------------------------------------------------------------
# checkpointing: boundaries + mid-epoch restart replay
# ---------------------------------------------------------------------------

def test_fused_mid_epoch_restart_replay(data, tmp_path):
    """Resuming from a mid-epoch checkpoint replays the identical stream:
    the resumed run's final params match the uninterrupted run's."""
    feats, labs = data
    _, fused_pipe = _pipelines(feats, labs)

    def make_trainer(ckpt_dir):
        # 6 steps/epoch, checkpoint every 5: step 5 is mid-epoch 0
        return Trainer(
            _STEP, fused_pipe,
            TrainerConfig(epochs=2, checkpoint_dir=ckpt_dir,
                          checkpoint_every_steps=5, async_checkpoint=False,
                          log_every_steps=1),
            fused=True, superstep=32,
        )

    full_dir = str(tmp_path / "full")
    tr_full = make_trainer(full_dir)
    s_full = tr_full.fit(_init_state(), resume=False)
    assert int(s_full.step) == 12

    # the engine cut segments exactly on the checkpoint boundary
    tr = make_trainer(str(tmp_path / "probe"))
    assert tr.ckpt.all_steps() == []
    assert sorted(tr_full.ckpt.all_steps()) == [5, 10, 12]

    # resume from the MID-EPOCH step-5 checkpoint only
    resume_dir = str(tmp_path / "resume")
    shutil.copytree(f"{full_dir}/step_5", f"{resume_dir}/step_5")
    tr_res = make_trainer(resume_dir)
    s_res = tr_res.fit(_init_state(), resume=True)
    assert int(s_res.step) == 12
    _assert_params_close(s_full.params, s_res.params, rtol=1e-6, atol=1e-7)
    # replayed history covers exactly the post-restore steps
    assert [h["step"] for h in tr_res.history] == list(range(6, 13))


def test_segment_length_boundaries():
    assert segment_length(32, 0, 100, 0) == 32
    assert segment_length(32, 0, 7, 0) == 7
    assert segment_length(8, 13, 100, 5) == 2     # next ckpt at step 15
    assert segment_length(8, 15, 100, 5) == 5
    assert segment_length(1, 0, 100, 0) == 1
    with pytest.raises(ValueError):
        segment_length(0, 0, 10, 0)


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

def test_superstep_donates_state_buffers(data):
    """The input state's buffers are invalidated by the superstep call —
    the zero-copy update the donation exists for."""
    feats, labs = data
    superstep = make_superstep(_STEP)
    state = _init_state()
    batches = {
        "x": jnp.asarray(feats[:32]).reshape(2, 16, D),
        "y": jnp.asarray(labs[:32]).reshape(2, 16),
        "weights": jnp.ones((2, 16), jnp.float32),
    }
    out, metrics = superstep(state, batches)
    assert metrics["loss"].shape == (2,)
    assert state.params["w1"].is_deleted()
    assert not out.params["w1"].is_deleted()
    # the resident buffers are NOT donated: an epoch reuses them every call
    engine = epoch_engine(_STEP)
    bufs = {"x": jnp.asarray(feats), "y": jnp.asarray(labs)}
    idx = jnp.arange(32, dtype=jnp.int32).reshape(2, 16)
    w = jnp.ones((2, 16), jnp.float32)
    out2, _ = engine(out, bufs, idx, w)
    assert out.params["w1"].is_deleted()
    assert not bufs["x"].is_deleted()
    assert epoch_engine(_STEP) is engine  # program cache shared per step fn


# ---------------------------------------------------------------------------
# session wiring
# ---------------------------------------------------------------------------

def test_session_fused_training_matches_loop(data):
    from repro.selection.session import MiloSession, MiloSessionConfig

    feats, labs = data
    base = dict(selector="random", subset_fraction=K / N, total_epochs=4,
                batch_size=BATCH, seed=0)
    r_loop = MiloSession(MiloSessionConfig(**base)).train(
        feats, labs, test_x=feats[:40], test_y=labs[:40])
    r_fused = MiloSession(MiloSessionConfig(fused_training=True, superstep=4,
                                            **base)).train(
        feats, labs, test_x=feats[:40], test_y=labs[:40])
    assert r_loop.steps == r_fused.steps
    np.testing.assert_allclose(r_loop.final_acc, r_fused.final_acc, atol=1e-6)
    losses_l = [h["loss"] for h in r_loop.history if "loss" in h]
    losses_f = [h["loss"] for h in r_fused.history if "loss" in h]
    np.testing.assert_allclose(losses_l, losses_f, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# batched hyperband rungs
# ---------------------------------------------------------------------------

def test_batched_hyperband_identical_to_sequential():
    """A deterministic objective: batched evaluation must reproduce the
    sequential trial stream EXACTLY (same configs, budgets, scores, best)."""

    def objective(cfg, budget):
        return -abs(np.log10(cfg["lr"]) + 1.0) + 0.05 * np.log1p(budget)

    def batched(configs, budget):
        return [objective(c, budget) for c in configs]

    space = {"lr": ("log", 1e-4, 1.0)}
    seq = hyperband(objective, RandomSearch(space, seed=0), max_budget=9, eta=3)
    bat = hyperband(None, RandomSearch(space, seed=0), max_budget=9, eta=3,
                    batched_objective=batched)
    assert seq.best_config == bat.best_config
    assert seq.best_score == bat.best_score
    assert seq.trials == bat.trials
    assert seq.total_epochs == bat.total_epochs


def test_batched_hyperband_vmapped_objective_matches():
    """A genuinely vmapped jax objective over stacked lr leaves picks the
    same best config as its scalar counterpart."""

    def score_impl(lr):
        return -jnp.abs(jnp.log10(lr) + 1.0)

    score = jax.jit(score_impl)
    score_batch = jax.jit(jax.vmap(score_impl))

    def objective(cfg, budget):
        return float(score(jnp.asarray(cfg["lr"], jnp.float32)))

    def batched(configs, budget):
        lrs = jnp.asarray(stack_configs(configs)["lr"], jnp.float32)
        return np.asarray(score_batch(lrs))

    space = {"lr": ("log", 1e-4, 1.0)}
    seq = hyperband(objective, RandomSearch(space, seed=1), max_budget=9, eta=3)
    bat = hyperband(None, RandomSearch(space, seed=1), max_budget=9, eta=3,
                    batched_objective=batched)
    assert seq.best_config == bat.best_config
    assert [t["config"] for t in seq.trials] == [t["config"] for t in bat.trials]
    np.testing.assert_allclose([t["score"] for t in seq.trials],
                               [t["score"] for t in bat.trials], rtol=1e-6)


def test_batched_hyperband_guards():
    space = {"lr": ("log", 1e-4, 1.0)}
    with pytest.raises(ValueError, match="objective"):
        hyperband(None, RandomSearch(space, seed=0))
    with pytest.raises(ValueError, match="scores"):
        hyperband(None, RandomSearch(space, seed=0), max_budget=9, eta=3,
                  batched_objective=lambda cfgs, b: [0.0])


def test_shape_bucketed_hyperband_identical_to_sequential():
    """A rung mixing ``hidden`` widths cannot be stacked into one vmap;
    the shape-bucketed wrapper must vmap within each hidden bucket and
    still reproduce the sequential trial stream EXACTLY."""

    def score_impl(lr, hidden):
        return -jnp.abs(jnp.log10(lr) + 1.0) - 0.01 * jnp.abs(hidden - 16.0)

    score_batch = jax.jit(jax.vmap(score_impl, in_axes=(0, None)))
    calls: list[tuple[int, int]] = []

    def objective(cfg, budget):
        return float(score_impl(jnp.float32(cfg["lr"]),
                                jnp.float32(cfg["hidden"])))

    def batched(configs, budget):
        hidden = {c["hidden"] for c in configs}
        assert len(hidden) == 1, "bucketing must hand same-shape configs only"
        calls.append((len(configs), hidden.pop()))
        lrs = jnp.asarray(stack_configs(configs)["lr"], jnp.float32)
        return np.asarray(score_batch(lrs, jnp.float32(configs[0]["hidden"])))

    space = {"lr": ("log", 1e-4, 1.0), "hidden": ("choice", [8, 16])}
    seq = hyperband(objective, RandomSearch(space, seed=2), max_budget=9, eta=3)
    bat = hyperband(None, RandomSearch(space, seed=2), max_budget=9, eta=3,
                    batched_objective=shape_bucketed_objective(batched))
    assert seq.best_config == bat.best_config
    assert [t["config"] for t in seq.trials] == [t["config"] for t in bat.trials]
    np.testing.assert_allclose([t["score"] for t in seq.trials],
                               [t["score"] for t in bat.trials], rtol=1e-6)
    # hidden really varied, so the wrapper had to split at least one rung
    assert len({h for _, h in calls}) == 2
    assert any(n > 1 for n, _ in calls), "same-hidden configs must batch"


def test_shape_bucketed_objective_guards():
    wrapped = shape_bucketed_objective(lambda cfgs, b: [0.0])
    with pytest.raises(ValueError, match="scores"):
        wrapped([{"lr": 0.1, "hidden": 8}, {"lr": 0.2, "hidden": 8}], 1)
    # single bucket passes straight through
    passthrough = shape_bucketed_objective(
        lambda cfgs, b: [float(c["lr"]) for c in cfgs])
    assert passthrough([{"lr": 0.1, "hidden": 8}, {"lr": 0.2, "hidden": 8}],
                       1) == [0.1, 0.2]


def test_stack_configs():
    stacked = stack_configs([{"lr": 0.1, "wd": 1.0}, {"lr": 0.2, "wd": 2.0}])
    np.testing.assert_allclose(stacked["lr"], [0.1, 0.2])
    np.testing.assert_allclose(stacked["wd"], [1.0, 2.0])
    with pytest.raises(ValueError, match="keys"):
        stack_configs([{"lr": 0.1}, {"wd": 1.0}])
    with pytest.raises(ValueError, match="no configs"):
        stack_configs([])
