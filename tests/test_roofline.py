"""Tests for the trip-count-aware HLO cost model and roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha
from repro.launch import roofline


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_xla_cost_analysis_undercounts_scans():
    """Documents the motivating bug: XLA counts a scan body once."""

    def make(n):
        def f(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), None

            y, _ = jax.lax.scan(body, x, w)
            return y.sum()

        return f

    x = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    # n=4 and n=8 both compile to a while loop with an identical body; XLA
    # reports the same FLOPs for both — i.e. trip count is ignored.
    f4 = _compile(make(4), x, jax.ShapeDtypeStruct((4, 64, 64), jnp.float32))
    f8 = _compile(make(8), x, jax.ShapeDtypeStruct((8, 64, 64), jnp.float32))
    assert ha.xla_cost(f4)["flops"] == ha.xla_cost(f8)["flops"]


@pytest.mark.parametrize("n", [1, 4, 16])
def test_analyzer_counts_scan_flops_exactly(n):
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None

        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((n, 128, 128), jnp.float32)
    t = ha.analyze(_compile(f, x, w).as_text())
    assert t["flops"] == pytest.approx(2 * 256 * 128 * 128 * n, rel=1e-6)
    if n > 1:
        assert n in t["while_trips"]


def test_analyzer_nested_scans():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None

            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    t = ha.analyze(_compile(f, x, w).as_text())
    assert t["flops"] == pytest.approx(2 * 128 * 64 * 64 * 12, rel=1e-6)
    assert sorted(t["while_trips"]) == [3, 4]


def test_analyzer_bytes_are_positive_and_bounded():
    def f(a, b):
        return (a @ b).sum()

    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    t = ha.analyze(_compile(f, a, a).as_text())
    min_traffic = 2 * 512 * 512 * 4          # reading both operands once
    max_traffic = 40 * 512 * 512 * 4         # generous slack for temps
    assert min_traffic <= t["bytes"] <= max_traffic


def test_shape_bytes_parsing():
    assert ha.shape_bytes("f32[4,8]{1,0}") == 128
    assert ha.shape_bytes("bf16[10]") == 20
    assert ha.shape_bytes("(f32[2,2]{1,0}, s32[])") == 20
    assert ha.shape_bytes("pred[]") == 1


def test_roofline_terms_and_bound():
    class Cfg:
        num_experts = 0

        @staticmethod
        def active_param_count():
            return 1_000_000

        @staticmethod
        def param_count():
            return 1_000_000

    class Shp:
        kind = "train"
        global_batch = 8
        seq_len = 128

    totals = {
        "flops": 1e12,
        "bytes": 1e12,
        "collective_bytes": {},
        "collective_total_bytes": 1e9,
    }
    t = roofline.roofline_terms_from_hlo(Cfg, Shp, totals, multi_pod=False)
    assert t["chips"] == 256
    assert t["bound"] == "memory"
    assert t["compute_s"] == pytest.approx(1e12 / 197e12)
    assert t["memory_s"] == pytest.approx(1e12 / 819e9)
    assert t["collective_s"] == pytest.approx(1e9 / 50e9)
    mf = 6.0 * 1e6 * 8 * 128
    assert t["model_flops"] == pytest.approx(mf)
    assert 0 < t["roofline_fraction"] < 1


def test_collective_parsing_on_sharded_program():
    """An explicitly sharded matmul must show collectives in the analysis."""
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("model",))
x = jax.ShapeDtypeStruct((64, 256), jnp.float32, sharding=NamedSharding(mesh, P(None, "model")))
w = jax.ShapeDtypeStruct((256, 64), jnp.float32, sharding=NamedSharding(mesh, P("model", None)))
with mesh:
    c = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
t = ha.analyze(c.as_text())
assert t["collective_total_bytes"] > 0, t
print("COLL_OK", t["collective_total_bytes"])
"""
    import os

    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=dict(os.environ, PYTHONPATH="src"),
                       cwd=".", timeout=180)
    assert "COLL_OK" in r.stdout, r.stderr[-1500:]
