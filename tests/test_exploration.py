"""Tests for Taylor-softmax, WRE sampling, curriculum, partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't error, when absent
from hypothesis import given, settings, strategies as st

from repro.core import CurriculumConfig, taylor_softmax, weighted_sample_without_replacement
from repro.core.partition import (
    Partition,
    merge_class_selections,
    partition_by_class,
    proportional_budgets,
)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-5, 5), min_size=2, max_size=64))
def test_taylor_softmax_is_distribution(gs):
    p = np.asarray(taylor_softmax(jnp.asarray(gs, jnp.float32)))
    assert np.all(p > 0), "strictly positive even for negative gains"
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)


def test_taylor_softmax_monotone_in_gain():
    g = jnp.asarray([0.0, 1.0, 2.0, 3.0])
    p = np.asarray(taylor_softmax(g))
    assert np.all(np.diff(p) > 0)


def test_wre_sampling_without_replacement_and_bias():
    m = 200
    probs = np.full(m, 0.5 / (m - 1), np.float64)
    probs[0] = 0.5
    probs /= probs.sum()
    counts = np.zeros(m)
    trials = 400
    for t in range(trials):
        idx = np.asarray(
            weighted_sample_without_replacement(jax.random.PRNGKey(t), jnp.asarray(probs), 10)
        )
        assert len(set(idx.tolist())) == 10
        counts[idx] += 1
    # element 0 carries half the mass: it must appear in nearly every draw
    assert counts[0] / trials > 0.9
    assert counts[0] > 5 * counts[1:].mean()


@settings(max_examples=30, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 50), min_size=1, max_size=8),
    k_frac=st.floats(0.05, 0.9),
)
def test_proportional_budgets_sum_and_capacity(sizes, k_frac):
    parts = []
    lo = 0
    for i, s in enumerate(sizes):
        parts.append(Partition(i, np.arange(lo, lo + s)))
        lo += s
    total = sum(sizes)
    k = max(1, int(total * k_frac))
    budgets = proportional_budgets(parts, k)
    assert sum(budgets) == min(k, total)
    for b, s in zip(budgets, sizes):
        assert 0 <= b <= s


def test_partition_roundtrip():
    labels = np.asarray([2, 0, 1, 0, 2, 2, 1])
    parts = partition_by_class(labels)
    assert sorted(p.label for p in parts) == [0, 1, 2]
    sel = [np.arange(min(2, len(p.indices))) for p in parts]
    merged = merge_class_selections(parts, sel)
    assert len(set(merged.tolist())) == len(merged)
    for g in merged:
        assert 0 <= g < len(labels)


def test_curriculum_phases_and_reselection():
    cur = CurriculumConfig(total_epochs=12, kappa=1 / 6, R=2)
    assert cur.sge_epochs == 2
    assert cur.phase(0) == "sge" and cur.phase(1) == "sge"
    assert cur.phase(2) == "wre" and cur.phase(11) == "wre"
    assert cur.needs_new_subset(0)
    assert not cur.needs_new_subset(1)
    assert cur.needs_new_subset(2)  # phase boundary
    assert cur.needs_new_subset(4)
    assert not cur.needs_new_subset(5)


def test_curriculum_validation():
    with pytest.raises(ValueError):
        CurriculumConfig(total_epochs=10, kappa=1.5)
    with pytest.raises(ValueError):
        CurriculumConfig(total_epochs=10, R=0)
