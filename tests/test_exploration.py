"""Tests for Taylor-softmax, WRE sampling, curriculum, partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: skip the property tests only, keep the rest running
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = settings = st = None

from repro.core import CurriculumConfig, taylor_softmax, weighted_sample_without_replacement
from repro.core.partition import (
    Partition,
    merge_class_selections,
    partition_by_class,
    proportional_budgets,
)


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(-5, 5), min_size=2, max_size=64))
    def test_taylor_softmax_is_distribution(gs):
        p = np.asarray(taylor_softmax(jnp.asarray(gs, jnp.float32)))
        assert np.all(p > 0), "strictly positive even for negative gains"
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-5)


def test_taylor_softmax_monotone_in_gain():
    g = jnp.asarray([0.0, 1.0, 2.0, 3.0])
    p = np.asarray(taylor_softmax(g))
    assert np.all(np.diff(p) > 0)


def test_wre_sampling_without_replacement_and_bias():
    m = 200
    probs = np.full(m, 0.5 / (m - 1), np.float64)
    probs[0] = 0.5
    probs /= probs.sum()
    counts = np.zeros(m)
    trials = 400
    for t in range(trials):
        idx = np.asarray(
            weighted_sample_without_replacement(jax.random.PRNGKey(t), jnp.asarray(probs), 10)
        )
        assert len(set(idx.tolist())) == 10
        counts[idx] += 1
    # element 0 carries half the mass: it must appear in nearly every draw
    assert counts[0] / trials > 0.9
    assert counts[0] > 5 * counts[1:].mean()


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 50), min_size=1, max_size=8),
        k_frac=st.floats(0.05, 0.9),
    )
    def test_proportional_budgets_sum_and_capacity(sizes, k_frac):
        parts = []
        lo = 0
        for i, s in enumerate(sizes):
            parts.append(Partition(i, np.arange(lo, lo + s)))
            lo += s
        total = sum(sizes)
        k = max(1, int(total * k_frac))
        budgets = proportional_budgets(parts, k)
        assert sum(budgets) == min(k, total)
        for b, s in zip(budgets, sizes):
            assert 0 <= b <= s


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(
        sizes=st.lists(st.integers(0, 50), min_size=1, max_size=8),
        k=st.integers(1, 60),
    )
    def test_proportional_budgets_min_one_floor(sizes, k):
        """Whenever k can cover every non-empty partition, each non-empty
        partition must receive budget >= 1 — pure proportional rounding
        starves small partitions next to a dominant one."""
        parts = []
        lo = 0
        for i, s in enumerate(sizes):
            parts.append(Partition(i, np.arange(lo, lo + s)))
            lo += s
        total = sum(sizes)
        if total == 0:
            return
        k = min(k, total)
        budgets = proportional_budgets(parts, k)
        assert sum(budgets) == k
        n_nonempty = sum(1 for s in sizes if s > 0)
        for b, s in zip(budgets, sizes):
            assert 0 <= b <= s
            if k >= n_nonempty and s > 0:
                assert b >= 1, (sizes, k, budgets)


def test_proportional_budgets_dominant_partition_regression():
    """The exact starvation case the floor fixes: three singletons next to
    a 97-row block at k=4 rounded to [0,0,0,4]; every non-empty partition
    must now get its seat."""
    sizes = [1, 1, 1, 97]
    parts, lo = [], 0
    for i, s in enumerate(sizes):
        parts.append(Partition(i, np.arange(lo, lo + s)))
        lo += s
    budgets = proportional_budgets(parts, 4)
    assert budgets == [1, 1, 1, 1]
    # one seat short of full coverage: proportional rounding unchanged
    # (the floor only applies when k can cover every non-empty partition)
    assert sum(proportional_budgets(parts, 3)) == 3


if st is not None:
    @settings(max_examples=30, deadline=None)
    @given(
        sizes=st.lists(st.integers(1, 20), min_size=1, max_size=6),
        seed=st.integers(0, 100),
    )
    def test_merge_class_selections_two_level_roundtrip(sizes, seed):
        """Local->global index lifting must compose through a nested
        two-level decomposition: partition the ground set into
        non-contiguous blocks (incl. singletons), select locally, merge,
        re-partition the union, select again, merge again — every index
        stays a valid, unique ground-set row."""
        rng = np.random.default_rng(seed)
        m = sum(sizes)
        perm = rng.permutation(m)
        parts, lo = [], 0
        for i, s in enumerate(sizes):
            # non-contiguous by construction: indices come from a permutation
            parts.append(Partition(i, np.sort(perm[lo:lo + s]).astype(np.int64)))
            lo += s
        # level 0: pick up to 3 local winners per partition
        sel0 = [rng.permutation(len(p.indices))[: min(3, len(p.indices))]
                for p in parts]
        union = merge_class_selections(parts, sel0)
        assert len(set(union.tolist())) == len(union)
        assert all(0 <= g < m for g in union)
        # level 1: re-partition the union rows and select again
        half = max(1, len(union) // 2)
        parts1 = [Partition(0, np.arange(half)),
                  Partition(1, np.arange(half, len(union)))]
        parts1 = [p for p in parts1 if len(p.indices)]
        sel1 = [rng.permutation(len(p.indices))[: min(2, len(p.indices))]
                for p in parts1]
        local1 = merge_class_selections(parts1, sel1)
        final = union[local1]
        assert len(set(final.tolist())) == len(final)
        assert set(final.tolist()) <= set(union.tolist())


def test_partition_roundtrip():
    labels = np.asarray([2, 0, 1, 0, 2, 2, 1])
    parts = partition_by_class(labels)
    assert sorted(p.label for p in parts) == [0, 1, 2]
    sel = [np.arange(min(2, len(p.indices))) for p in parts]
    merged = merge_class_selections(parts, sel)
    assert len(set(merged.tolist())) == len(merged)
    for g in merged:
        assert 0 <= g < len(labels)


def test_curriculum_phases_and_reselection():
    cur = CurriculumConfig(total_epochs=12, kappa=1 / 6, R=2)
    assert cur.sge_epochs == 2
    assert cur.phase(0) == "sge" and cur.phase(1) == "sge"
    assert cur.phase(2) == "wre" and cur.phase(11) == "wre"
    assert cur.needs_new_subset(0)
    assert not cur.needs_new_subset(1)
    assert cur.needs_new_subset(2)  # phase boundary
    assert cur.needs_new_subset(4)
    assert not cur.needs_new_subset(5)


def test_curriculum_validation():
    with pytest.raises(ValueError):
        CurriculumConfig(total_epochs=10, kappa=1.5)
    with pytest.raises(ValueError):
        CurriculumConfig(total_epochs=10, R=0)


def test_wre_sampling_never_draws_zero_probability_indices():
    """Flooring p at 1e-30 let masked elements win top-k slots; the masked
    Gumbel race must keep every draw inside the nonzero support."""
    p = np.zeros(64, np.float32)
    p[:8] = 1.0 / 8
    for t in range(50):
        idx = np.asarray(
            weighted_sample_without_replacement(jax.random.PRNGKey(t), jnp.asarray(p), 8)
        )
        assert idx.max() < 8, idx
        assert len(set(idx.tolist())) == 8


def test_wre_sampling_raises_when_k_exceeds_support():
    p = jnp.asarray([0.7, 0.3, 0.0, 0.0, 0.0])
    with pytest.raises(ValueError, match="nonzero-probability"):
        weighted_sample_without_replacement(jax.random.PRNGKey(0), p, 3)
    # k == support is the boundary: all of the support, in some order
    idx = np.asarray(
        weighted_sample_without_replacement(jax.random.PRNGKey(0), p, 2)
    )
    assert sorted(idx.tolist()) == [0, 1]


def test_wre_sampling_valid_draws_bit_identical_to_pre_guard_formula():
    """The guard must not perturb well-formed draws: for all-positive p the
    masked logits equal the old log(max(p, 1e-30)) bit-for-bit."""
    rng = np.random.default_rng(5)
    p = rng.random(200).astype(np.float32)
    p /= p.sum()
    pj = jnp.asarray(p)
    for t in range(5):
        key = jax.random.PRNGKey(t)
        old = jax.lax.top_k(
            jnp.log(jnp.maximum(pj, 1e-30)) + jax.random.gumbel(key, pj.shape), 10
        )[1].astype(jnp.int32)
        new = weighted_sample_without_replacement(key, pj, 10)
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_wre_sampling_traceable_under_jit():
    """Inside a trace the host-side support guard must stay out of the way
    (no ConcretizationTypeError) while the -inf mask still applies."""
    p = jnp.asarray([0.0, 0.25, 0.25, 0.5])

    @jax.jit
    def draw(key, probs):
        return weighted_sample_without_replacement(key, probs, 2)

    idx = np.asarray(draw(jax.random.PRNGKey(1), p))
    assert 0 not in idx.tolist()
