"""Property + example tests for the set functions and greedy engines.

``hypothesis`` is optional: when absent only the property tests skip (they
guard individually), and the example-based tests still run in bare
containers — mirroring ``test_exploration.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dep: skip the property tests only, keep the rest running
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = settings = st = None

from repro.core import (
    disparity_min,
    disparity_sum,
    facility_location,
    gram_matrix,
    graph_cut,
    greedy,
    greedy_importance,
    make_graph_cut,
    stochastic_greedy,
)
from repro.core.greedy import stochastic_candidate_count


def _kernel(n: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, 8)).astype(np.float32)
    return gram_matrix(jnp.asarray(z))


FNS = {
    "facility_location": facility_location,
    "graph_cut": graph_cut,
    "disparity_sum": disparity_sum,
}


if st is not None:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000), n=st.integers(4, 16))
    def test_incremental_gains_match_evaluate(seed, n):
        """gains(state) must equal f(S u j) - f(S) computed from scratch."""
        K = _kernel(n, seed)
        rng = np.random.default_rng(seed)
        for name, fn in FNS.items():
            mask = np.zeros(n, bool)
            state = fn.init(K)
            for j in rng.permutation(n)[: n // 2]:
                gains = np.asarray(fn.gains(state, K))
                before = float(fn.evaluate(jnp.asarray(mask), K))
                mask[j] = True
                after = float(fn.evaluate(jnp.asarray(mask), K))
                np.testing.assert_allclose(gains[j], after - before, rtol=1e-4, atol=1e-4,
                                           err_msg=f"{name} at j={j}")
                state = fn.update(state, K, jnp.asarray(j))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_submodularity_diminishing_returns(seed):
        """f(A u x) - f(A) >= f(B u x) - f(B) for A subset B (submodular fns)."""
        n = 10
        K = _kernel(n, seed)
        rng = np.random.default_rng(seed)
        for fn in (facility_location, graph_cut):
            perm = rng.permutation(n)
            a_idx, b_extra, x = perm[:3], perm[3:6], int(perm[6])
            sa = fn.init(K)
            for j in a_idx:
                sa = fn.update(sa, K, jnp.asarray(j))
            sb = sa
            for j in b_extra:
                sb = fn.update(sb, K, jnp.asarray(j))
            ga = float(fn.gains(sa, K)[x])
            gb = float(fn.gains(sb, K)[x])
            assert ga >= gb - 1e-4, (fn.name, ga, gb)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_monotonicity(seed):
        n = 8
        K = _kernel(n, seed)
        for fn in (facility_location, graph_cut):
            mask = np.zeros(n, bool)
            prev = float(fn.evaluate(jnp.asarray(mask), K))
            for j in np.random.default_rng(seed).permutation(n):
                mask[j] = True
                cur = float(fn.evaluate(jnp.asarray(mask), K))
                assert cur >= prev - 1e-4, fn.name
                prev = cur


def test_greedy_approximation_vs_bruteforce():
    """Greedy must reach >= (1-1/e) of the optimal FL value on tiny instances."""
    import itertools

    n, k = 10, 3
    K = _kernel(n, 0)
    res = greedy(facility_location, K, k)
    mask = np.zeros(n, bool)
    mask[np.asarray(res.indices)] = True
    greedy_val = float(facility_location.evaluate(jnp.asarray(mask), K))
    best = -np.inf
    for combo in itertools.combinations(range(n), k):
        m = np.zeros(n, bool)
        m[list(combo)] = True
        best = max(best, float(facility_location.evaluate(jnp.asarray(m), K)))
    assert greedy_val >= (1 - 1 / np.e) * best - 1e-5
    assert greedy_val >= 0.99 * best  # FL greedy is near-exact in practice


def test_greedy_no_duplicates_and_gains_decreasing():
    n, k = 40, 12
    K = _kernel(n, 3)
    res = greedy(facility_location, K, k)
    idx = np.asarray(res.indices)
    assert len(set(idx.tolist())) == k
    gains = np.asarray(res.gains)
    assert np.all(np.diff(gains) <= 1e-4)  # diminishing returns along the run


def test_stochastic_greedy_distinct_subsets_and_quality():
    n, k = 60, 10
    K = _kernel(n, 5)
    s = stochastic_candidate_count(n, k, 0.01)
    runs = [
        tuple(np.asarray(stochastic_greedy(facility_location, K, k, jax.random.PRNGKey(i), s=s).indices).tolist())
        for i in range(4)
    ]
    assert len(set(runs)) > 1, "stochastic greedy must vary across seeds"
    # quality close to exact greedy
    exact = greedy(facility_location, K, k)
    m = np.zeros(n, bool)
    m[np.asarray(exact.indices)] = True
    v_exact = float(facility_location.evaluate(jnp.asarray(m), K))
    for r in runs:
        m = np.zeros(n, bool)
        m[list(r)] = True
        v = float(facility_location.evaluate(jnp.asarray(m), K))
        assert v >= 0.85 * v_exact


def test_greedy_importance_covers_all_elements():
    n = 30
    K = _kernel(n, 7)
    g = np.asarray(greedy_importance(disparity_min, K))
    assert g.shape == (n,)
    assert np.isfinite(g).all()


def test_graph_cut_lambda_monotone_for_small_lambda():
    n = 12
    K = _kernel(n, 9)
    fn = make_graph_cut(0.4)
    mask = np.zeros(n, bool)
    prev = float(fn.evaluate(jnp.asarray(mask), K))
    for j in range(n):
        mask[j] = True
        cur = float(fn.evaluate(jnp.asarray(mask), K))
        assert cur >= prev - 1e-4
        prev = cur
