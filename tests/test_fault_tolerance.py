"""Fault-tolerant execution layer (ISSUE 7): crash-safe checkpoints,
deterministic resume everywhere, and the fault-injection harness.

The load-bearing claims pinned here:
  * a SIGKILLed fused run resumed from its latest valid checkpoint produces
    BIT-IDENTICAL final params and history (modulo wall stamps) to the
    uninterrupted run;
  * torn / corrupted / half-lost checkpoints are detected and skipped, never
    restored;
  * the checkpoint GC can never delete a step whose async write is in
    flight, and an async write failure re-raises on ``wait()``;
  * ``restart_state`` agrees with the data pipeline's seeding, so the resume
    cursor replays the exact batch stream;
  * a hyperband sweep killed mid-rung resumes at its rung boundary with an
    identical trial stream and ``best_config``;
  * a failed single-flight artifact build releases the flight lock, counts
    itself, and leaves the server healthy; transient failures retry under
    ``RetryPolicy`` with deterministic backoff.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    CheckpointCorruptionError,
    CheckpointManager,
)
from repro.data.pipeline import Pipeline
from repro.distributed.fault_tolerance import StragglerMonitor, restart_state
from repro.selection import MiloSession, MiloSessionConfig, build_selector
from repro.serve import (
    DONE,
    ERROR,
    ArtifactStore,
    MiloServer,
    RetryPolicy,
    TransientServeError,
    artifact_request_config,
)
from repro.testing.faults import (
    CORRUPTION_MODES,
    TransientFault,
    corrupt_checkpoint,
    fail_nth_calls,
    flaky,
)
from repro.tuning.tuner import TPESearch, hyperband

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(offset: float = 0.0):
    return {"a": jnp.arange(12.0).reshape(3, 4) + offset,
            "b": {"c": jnp.ones((64,), jnp.float32) * (1 + offset)}}


# ---------------------------------------------------------------------------
# checkpoint hardening: validation, torn-checkpoint skipping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", CORRUPTION_MODES)
def test_corrupted_checkpoint_detected_and_skipped(tmp_path, mode):
    """Every corruption mode fails validation; ``latest_valid_step`` falls
    back to the newest intact checkpoint and ``restore`` refuses the bad one."""
    mgr = CheckpointManager(str(tmp_path), keep_last=5)
    for step in (1, 2, 3):
        mgr.save(step, _tree(step))
    damaged = corrupt_checkpoint(str(tmp_path), 3, mode=mode)
    assert os.path.basename(os.path.dirname(damaged)) == "step_3"

    assert mgr.all_steps() == [1, 2, 3]        # candidates still listed
    assert not mgr.is_valid_step(3)
    assert mgr.is_valid_step(2)
    assert mgr.latest_valid_step() == 2
    with pytest.raises(CheckpointCorruptionError):
        mgr.restore(3, _tree())
    # the intact neighbor restores bit-exactly
    out = mgr.restore(2, _tree())
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.arange(12.0).reshape(3, 4) + 2)


def test_latest_valid_step_none_when_all_damaged(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    corrupt_checkpoint(str(tmp_path), 1, mode="truncate_manifest")
    assert mgr.latest_valid_step() is None


def test_async_save_failure_reraises_on_wait(tmp_path):
    """An async write error is a failed save: it must surface on the
    training thread at the next ``wait()``, not vanish in the worker."""
    mgr = CheckpointManager(str(tmp_path))

    def boom(step, host_tree, extra=None):
        raise OSError("disk gone")

    mgr._write = boom
    mgr.save_async(7, _tree())
    with pytest.raises(OSError, match="disk gone"):
        mgr.wait()
    # the error is consumed: the manager keeps working afterwards
    mgr.wait()


def test_gc_never_deletes_inflight_async_step(tmp_path):
    """Regression for the GC/async race: with keep_last=1, a sync save's GC
    runs while an async save is still writing — the in-flight step must
    survive both that GC and its own post-write GC."""
    mgr = CheckpointManager(str(tmp_path), keep_last=1)
    gate = threading.Event()
    orig_write = mgr._write

    def gated_write(step, host_tree, extra=None):
        if step == 5:
            assert gate.wait(30)
        return orig_write(step, host_tree, extra)

    mgr._write = gated_write
    mgr.save_async(5, _tree())       # blocked mid-write, registered in-flight
    mgr.save(6, _tree())             # concurrent sync save triggers GC
    with mgr._lock:
        assert 5 in mgr._inflight
    gate.set()
    mgr.wait()
    # without in-flight tracking, step 5's own GC (keep_last=1, steps [5, 6])
    # would have deleted the directory it just renamed
    assert mgr.is_valid_step(5) and mgr.is_valid_step(6)


def test_manifest_carries_extra_and_checksums(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(4, _tree(), extra={"device_count": 8, "batch_size": 32})
    man = mgr.validate_step(4)
    assert man["format"] == 2
    assert man["extra"] == {"device_count": 8, "batch_size": 32}
    assert man["checksums"]          # every data file is hashed


# ---------------------------------------------------------------------------
# straggler monitor: exact warmup statistics
# ---------------------------------------------------------------------------

def test_straggler_warmup_mean_is_true_mean():
    """Warmup uses an unbiased incremental mean: the old ``(mean + dt) / 2``
    halved every earlier observation's weight each step."""
    mon = StragglerMonitor(warmup_steps=4)
    for i, dt in enumerate([0.1, 0.2, 0.3, 0.4]):
        assert mon.observe(i, dt) is False     # warmup never flags
    assert mon.mean_step_time == pytest.approx(0.25)
    # the biased estimate would be 0.284375, dominated by late samples
    assert mon.mean_step_time != pytest.approx(0.284375)


def test_straggler_flags_known_outlier_after_warmup():
    mon = StragglerMonitor(warmup_steps=3, z_threshold=3.0)
    for i, dt in enumerate([0.1, 0.101, 0.102]):
        mon.observe(i, dt)
    assert mon.observe(3, 0.103) is False      # in-band
    assert mon.observe(4, 1.5) is True         # 100x outlier
    assert mon.flagged == [(4, 1.5)]


def test_trainer_straggler_report_rollup():
    """The run-level roll-up aggregates flagged steps without touching the
    history stream (its length must stay schedule-deterministic)."""
    from repro.train.trainer import Trainer, TrainerConfig

    tr = Trainer.__new__(Trainer)
    tr.monitor = StragglerMonitor()
    assert tr.straggler_report() is None
    tr.monitor.flagged = [(7, 1.5), (9, 2.0)]
    tr.monitor._mean = 0.1
    rep = tr.straggler_report()
    assert rep["flagged"] == [[7, 1.5], [9, 2.0]]
    assert rep["mean_step_time"] == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# restart cursor vs data pipeline seeding
# ---------------------------------------------------------------------------

def test_restart_state_matches_pipeline_replay():
    """The cursor's (epoch, step_in_epoch, data_seed) must replay the exact
    batch indices the uninterrupted run would have consumed."""
    n, k, batch, seed = 128, 64, 16, 11
    sel = build_selector("adaptive_random", n=n, k=k, R=1, seed=3)
    feats = np.zeros((n, 4), np.float32)
    pipe = Pipeline(None, sel, batch, seed=seed, arrays={"x": feats})
    spe = pipe.steps_per_epoch()
    global_step = spe + 2                      # mid-epoch 1
    cur = restart_state(seed, global_step, spe)
    assert cur["epoch"] == 1 and cur["step_in_epoch"] == 2
    # the documented contract: data_seed IS the pipeline's permutation seed
    assert cur["data_seed"] == seed * 1_000_003 + cur["epoch"]

    full_idx, full_w = pipe.device_epoch(1)
    res_idx, res_w = pipe.device_epoch(cur["epoch"],
                                       start_step=cur["step_in_epoch"])
    np.testing.assert_array_equal(np.asarray(res_idx),
                                  np.asarray(full_idx)[2:])
    np.testing.assert_array_equal(np.asarray(res_w), np.asarray(full_w)[2:])


def test_restart_state_rejects_degenerate_epoch_length():
    with pytest.raises(ValueError):
        restart_state(0, 10, 0)


# ---------------------------------------------------------------------------
# kill-and-resume: SIGKILL mid-epoch, bit-identical final params + history
# ---------------------------------------------------------------------------

FAULT_SCRIPT = r"""
import json, sys
mode, ckpt_dir, out = sys.argv[1], sys.argv[2], sys.argv[3]
import numpy as np, jax, jax.numpy as jnp
from typing import NamedTuple
from repro.data.pipeline import Pipeline
from repro.models.classifier import init_mlp, nesterov_update, weighted_nll
from repro.selection import build_selector
from repro.train.trainer import Trainer, TrainerConfig

N, D, C, K, BATCH = 256, 8, 4, 96, 16      # 6 steps per epoch
rng = np.random.default_rng(0)
feats = rng.normal(size=(N, D)).astype(np.float32)
labs = rng.integers(0, C, size=N).astype(np.int64)

class State(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array

def train_step(state, batch):
    loss, g = jax.value_and_grad(weighted_nll)(
        state.params, batch["x"], batch["y"], batch["weights"])
    p, m = nesterov_update(state.params, state.mom, g, 0.05)
    return State(p, m, state.step + 1), {"loss": loss}

sel = build_selector("adaptive_random", n=N, k=K, R=1, seed=3)
pipe = Pipeline(None, sel, BATCH, seed=1, arrays={"x": feats, "y": labs})
tr = Trainer(jax.jit(train_step), pipe,
             TrainerConfig(epochs=3, checkpoint_dir=ckpt_dir,
                           checkpoint_every_steps=5, async_checkpoint=True,
                           log_every_steps=1),
             fused=True, superstep=32)
if mode == "kill":
    from repro.testing.faults import KillAtStep
    tr.monitor = KillAtStep(8)   # dies at boundary step 10: mid-epoch 1
params = init_mlp(jax.random.PRNGKey(0), D, C)
state = State(params, jax.tree.map(jnp.zeros_like, params),
              jnp.zeros((), jnp.int32))
state = tr.fit(state, resume=True)
flat = {f"p{i}": np.asarray(l) for i, l in enumerate(jax.tree.leaves(state.params))}
np.savez(out + ".npz", step=int(state.step), **flat)
hist = [{k: v for k, v in h.items() if k not in ("wall", "straggler")}
        for h in tr.history if "loss" in h]
json.dump(hist, open(out + ".hist.json", "w"))
print("RUN_COMPLETE", int(state.step))
"""


def _run_child(script, argv, *, expect_sigkill=False, timeout=300):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", script, *argv],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT,
        timeout=timeout,
    )
    if expect_sigkill:
        assert r.returncode == -signal.SIGKILL, (
            r.returncode, r.stdout[-1000:], r.stderr[-2000:])
    else:
        assert r.returncode == 0, r.stderr[-2000:]
    return r


def test_kill_and_resume_bit_identical(tmp_path):
    """SIGKILL a fused run mid-epoch (async checkpointing on), restart it,
    and require the resumed run's final params to be BIT-identical to an
    uninterrupted run's — and its history to be the exact tail of the
    uninterrupted history (modulo wall stamps)."""
    ref_out = str(tmp_path / "ref")
    _run_child(FAULT_SCRIPT, ["ref", str(tmp_path / "ref_ckpt"), ref_out])

    ckpt = str(tmp_path / "ckpt")
    r = _run_child(FAULT_SCRIPT, ["kill", ckpt, str(tmp_path / "dead")],
                   expect_sigkill=True)
    assert "RUN_COMPLETE" not in r.stdout      # it really died mid-run

    res_out = str(tmp_path / "res")
    _run_child(FAULT_SCRIPT, ["run", ckpt, res_out])

    with np.load(ref_out + ".npz") as ref, np.load(res_out + ".npz") as res:
        assert int(ref["step"]) == int(res["step"]) == 18
        for k in ref.files:
            np.testing.assert_array_equal(ref[k], res[k])
    ref_h = json.load(open(ref_out + ".hist.json"))
    res_h = json.load(open(res_out + ".hist.json"))
    # the resumed run replays exactly the post-checkpoint steps
    assert 0 < len(res_h) < len(ref_h)
    assert res_h == ref_h[len(ref_h) - len(res_h):]
    print("BIT_IDENTICAL_FINAL_PARAMS_OK")


def test_resume_surfaces_elastic_plan_on_device_count_change(tmp_path):
    """A checkpoint stamped with a different device count triggers an
    elastic plan (grad-accum preserving the global batch) on resume."""
    from typing import NamedTuple

    from repro.models.classifier import init_mlp, nesterov_update, weighted_nll
    from repro.train.trainer import Trainer, TrainerConfig

    N, D, C, K, BATCH = 128, 8, 4, 64, 16
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(N, D)).astype(np.float32)
    labs = rng.integers(0, C, size=N).astype(np.int64)

    class State(NamedTuple):
        params: dict
        mom: dict
        step: jax.Array

    def train_step(state, batch):
        loss, g = jax.value_and_grad(weighted_nll)(
            state.params, batch["x"], batch["y"], batch["weights"])
        p, m = nesterov_update(state.params, state.mom, g, 0.05)
        return State(p, m, state.step + 1), {"loss": loss}

    sel = build_selector("adaptive_random", n=N, k=K, R=1, seed=3)
    pipe = Pipeline(None, sel, BATCH, seed=1, arrays={"x": feats, "y": labs})
    tr = Trainer(jax.jit(train_step), pipe,
                 TrainerConfig(epochs=2, checkpoint_dir=str(tmp_path)),
                 fused=True)
    params = init_mlp(jax.random.PRNGKey(0), D, C)
    state = State(params, jax.tree.map(jnp.zeros_like, params),
                  jnp.zeros((), jnp.int32))
    # a checkpoint written by a (fictional) 4-device run of the same job
    tr.ckpt.save(4, state, extra={"device_count": 4, "batch_size": BATCH,
                                  "data_seed": 1})
    tr.fit(state, resume=True)
    assert tr.elastic is not None
    assert tr.elastic.grad_accum == 4          # 16 / (1 device * mb 4)
    elastic_recs = [h for h in tr.history if h.get("elastic")]
    assert len(elastic_recs) == 1 and elastic_recs[0]["step"] == 4


# ---------------------------------------------------------------------------
# hyperband: killed mid-rung, resumes to the identical sweep
# ---------------------------------------------------------------------------

HB_SCRIPT = r"""
import json, sys
mode, ck, out = sys.argv[1], sys.argv[2], sys.argv[3]
from repro.tuning.tuner import TPESearch, hyperband

space = {"lr": ("log", 1e-4, 1e-1), "hidden": ("choice", [16, 32, 64])}

def obj(cfg, budget):
    return -abs(cfg["lr"] - 0.01) * 100 + budget * 0.001 + cfg["hidden"] * 1e-5

if mode == "kill":
    from repro.testing.faults import kill_process
    base, calls = obj, [0]
    def obj(cfg, budget):
        calls[0] += 1
        if calls[0] == 11:      # mid rung 1 of the first bracket
            kill_process()
        return base(cfg, budget)

res = hyperband(obj, TPESearch(space, seed=3), max_budget=9, eta=3,
                checkpoint=(None if ck == "none" else ck))
json.dump({"best_config": res.best_config, "best_score": res.best_score,
           "trials": res.trials, "total_epochs": res.total_epochs},
          open(out, "w"))
print("HB_COMPLETE")
"""


def test_hyperband_killed_mid_rung_resumes_identically(tmp_path):
    ref_out = str(tmp_path / "ref.json")
    _run_child(HB_SCRIPT, ["run", "none", ref_out], timeout=120)

    ck = str(tmp_path / "hb_state.json")
    _run_child(HB_SCRIPT, ["kill", ck, str(tmp_path / "dead.json")],
               expect_sigkill=True, timeout=120)
    assert os.path.exists(ck)                  # rung boundary state survived

    res_out = str(tmp_path / "res.json")
    _run_child(HB_SCRIPT, ["run", ck, res_out], timeout=120)

    ref = json.load(open(ref_out))
    res = json.load(open(res_out))
    assert res == ref                          # identical trial stream + best


def test_hyperband_should_stop_then_resume_in_process(tmp_path):
    """A deadline-stopped sweep leaves a resumable checkpoint; relaunching
    with a fresh search object completes it identically, and a finished
    checkpoint short-circuits."""
    space = {"lr": ("log", 1e-4, 1e-1), "hidden": ("choice", [16, 32])}

    def obj(cfg, budget):
        return -abs(cfg["lr"] - 0.01) * 100 + budget * 0.001

    ref = hyperband(obj, TPESearch(space, seed=5), max_budget=9, eta=3)
    ck = str(tmp_path / "hb.json")
    polls = [0]

    def stop_after_two_rungs():
        polls[0] += 1
        return polls[0] > 2

    part = hyperband(obj, TPESearch(space, seed=5), max_budget=9, eta=3,
                     checkpoint=ck, should_stop=stop_after_two_rungs)
    assert part.stopped
    res = hyperband(obj, TPESearch(space, seed=5), max_budget=9, eta=3,
                    checkpoint=ck)
    assert not res.stopped
    assert res.best_config == ref.best_config
    assert res.trials == ref.trials
    # done checkpoint short-circuits without re-evaluating anything
    calls = fail_nth_calls(obj, fail_on=range(1, 10_000))
    done = hyperband(calls, TPESearch(space, seed=5), max_budget=9, eta=3,
                     checkpoint=ck)
    assert calls.calls == 0 and done.best_config == ref.best_config


def test_hyperband_checkpoint_identity_mismatch_raises(tmp_path):
    space = {"lr": ("log", 1e-3, 1e-1)}
    obj = lambda cfg, budget: cfg["lr"]
    ck = str(tmp_path / "hb.json")
    hyperband(obj, TPESearch(space, seed=0), max_budget=9, eta=3, checkpoint=ck)
    with pytest.raises(ValueError, match="different sweep"):
        hyperband(obj, TPESearch(space, seed=0), max_budget=27, eta=3,
                  checkpoint=ck)


# ---------------------------------------------------------------------------
# serving: failed builds, flight-lock release, retry policy
# ---------------------------------------------------------------------------

N_SRV, D_SRV, C_SRV = 240, 8, 3


def _dataset(seed: int = 0):
    rng = np.random.default_rng(seed)
    labs = rng.integers(0, C_SRV, N_SRV).astype(np.int64)
    feats = (rng.normal(size=(N_SRV, D_SRV)) + 0.8 * labs[:, None]).astype(
        np.float32)
    return feats, labs


def _config(**kw) -> MiloSessionConfig:
    base = dict(subset_fraction=0.2, n_sge_subsets=2, gram_free=True,
                total_epochs=4, sub_steps=2)
    base.update(kw)
    return MiloSessionConfig(**base)


def test_store_failed_build_releases_flight_lock(tmp_path):
    """An exception inside the single-flight build must release the per-key
    flight lock (no hung waiters) and install nothing; the next caller
    rebuilds successfully."""
    feats, labs = _dataset()
    cfg = _config()
    store = ArtifactStore(str(tmp_path / "store"))
    req = artifact_request_config(cfg)
    session = MiloSession(cfg)
    fp = "f" * 16
    key = store.key_for(fp, req)
    build = flaky(
        lambda: session.build_metadata(feats, labs, fingerprint=fp),
        failures=1)
    results, errors = [], []

    def worker():
        try:
            _, _, source = store.get_or_build(key, req, build)
            results.append(source)
        except TransientFault as e:
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not any(t.is_alive() for t in threads), "waiters hung on the flight lock"
    assert len(errors) == 1                      # exactly the injected failure
    assert sorted(results) == ["built", "memory", "memory"]
    assert store.build_failures == 1 and store.builds == 1
    # the store serves the next identical request from memory
    _, _, source = store.get_or_build(key, req, build)
    assert source == "memory"


def test_server_retries_transient_build_failure(tmp_path, monkeypatch):
    """A transient artifact-build failure is retried under RetryPolicy; the
    request succeeds on attempt 2 and every counter tells the story."""
    feats, labs = _dataset()
    orig = MiloSession.build_metadata
    calls = [0]

    def flaky_build(self, *a, **kw):
        calls[0] += 1
        if calls[0] == 1:
            raise TransientFault("injected build failure")
        return orig(self, *a, **kw)

    monkeypatch.setattr(MiloSession, "build_metadata", flaky_build)
    with MiloServer(_config(), store_root=str(tmp_path / "store"),
                    num_workers=1,
                    retry_policy=RetryPolicy(base_delay=0.01,
                                             retry_on=(TransientFault,))
                    ) as server:
        rid = server.submit("preprocess", features=feats, labels=labs)
        out = server.result(rid, timeout=120)
        assert out["source"] == "built"
        snap = server.poll(rid)
        assert snap["status"] == DONE and snap["attempts"] == 2
        assert snap["error"] is None             # a retried success is a success
        st = server.stats()
        assert st["retries"] == 1 and st["failures"] == 0
        assert st["store"]["build_failures"] == 1


def test_server_permanent_error_fails_fast_and_stays_healthy(tmp_path,
                                                             monkeypatch):
    """A permanent (non-transient) failure is NOT retried: the request lands
    in ERROR with its exception, and the server keeps serving."""
    feats, labs = _dataset()
    orig = MiloSession.build_metadata
    calls = [0]

    def once_broken(self, *a, **kw):
        calls[0] += 1
        if calls[0] == 1:
            raise ValueError("permanently malformed request")
        return orig(self, *a, **kw)

    monkeypatch.setattr(MiloSession, "build_metadata", once_broken)
    with MiloServer(_config(), store_root=str(tmp_path / "store"),
                    num_workers=1) as server:
        rid = server.submit("preprocess", features=feats, labels=labs)
        with pytest.raises(ValueError, match="permanently malformed"):
            server.result(rid, timeout=120)
        snap = server.poll(rid)
        assert snap["status"] == ERROR and snap["attempts"] == 1
        # server healthy: the next identical request builds and completes
        rid2 = server.submit("preprocess", features=feats, labels=labs)
        out = server.result(rid2, timeout=120)
        assert out["source"] == "built"
        st = server.stats()
        assert st["failures"] == 1 and st["retries"] == 0
        assert st["store"]["build_failures"] == 1 and st["store"]["builds"] == 1


def test_retry_policy_deterministic_backoff():
    p = RetryPolicy(base_delay=0.1, max_delay=1.0, jitter=0.25)
    d1, d2 = p.delay("r000001", 1), p.delay("r000001", 2)
    # deterministic: same (request, attempt) -> same delay, every time
    assert d1 == p.delay("r000001", 1)
    # exponential base, bounded jitter
    assert 0.1 <= d1 <= 0.1 * 1.25
    assert 0.2 <= d2 <= 0.2 * 1.25
    assert p.delay("r000001", 10) <= 1.0 * 1.25  # max_delay caps the base
    # different requests de-synchronize (the anti-thundering-herd property)
    assert p.delay("r000002", 1) != d1
    # classification: types in retry_on and duck-typed `transient` both count
    assert p.is_transient(TransientServeError("x"))
    assert p.is_transient(TransientFault("x"))   # duck-typed .transient marker
    assert not p.is_transient(ValueError("x"))


def test_retry_policy_rejects_zero_attempts():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
