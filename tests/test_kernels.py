"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fl_gains import ops as fl_ops
from repro.kernels.fl_gains.ref import (
    fl_gains_gram_free_delta_ref,
    fl_gains_gram_free_ref,
    fl_gains_ref,
)
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import gqa_attention_ref
from repro.kernels.similarity import ops as sim_ops
from repro.kernels.similarity.ref import similarity_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=2e-4)


@pytest.mark.parametrize("mq,mk,d", [(64, 64, 16), (256, 256, 64), (300, 517, 48), (8, 1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_similarity_kernel_sweep(mq, mk, d, dtype):
    zq = jnp.asarray(RNG.normal(size=(mq, d)), dtype)
    zk = jnp.asarray(RNG.normal(size=(mk, d)), dtype)
    out = sim_ops.similarity(zq, zk, interpret=True)
    ref = similarity_ref(zq, zk)
    np.testing.assert_allclose(out, ref, **_tol(dtype))
    assert out.dtype == jnp.float32
    assert float(jnp.min(out)) >= -1e-3 and float(jnp.max(out)) <= 1.0 + 1e-3


@pytest.mark.parametrize("n,ncand", [(128, 128), (700, 321), (1024, 64), (65, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fl_gains_kernel_sweep(n, ncand, dtype):
    K = jnp.asarray(RNG.uniform(size=(n, ncand)), dtype)
    c = jnp.asarray(RNG.uniform(size=(n,)), dtype)
    out = fl_ops.fl_gains(K, c, interpret=True)
    ref = fl_gains_ref(K, c)
    np.testing.assert_allclose(out, ref, **_tol(dtype))
    assert np.all(np.asarray(out) >= -1e-3), "gains are nonnegative"


@pytest.mark.parametrize("n,ncand,d", [(128, 128, 32), (300, 130, 48), (64, 512, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fl_gains_gram_free_kernel_sweep(n, ncand, d, dtype):
    """Fused-similarity gains (no materialized Gram) vs the jnp oracle."""
    z = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    z = z / jnp.maximum(jnp.linalg.norm(z.astype(jnp.float32), axis=-1,
                                        keepdims=True), 1e-8).astype(dtype)
    zc = z[:ncand] if ncand <= n else jnp.concatenate([z] * (ncand // n + 1))[:ncand]
    c = jnp.asarray(RNG.uniform(size=(n,)), dtype)
    out = fl_ops.fl_gains_gram_free(z, zc, c, block_i=128, block_j=128,
                                    interpret=True)
    ref = fl_gains_gram_free_ref(z, zc, c)
    np.testing.assert_allclose(out, ref, **_tol(dtype))
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("b,ncand,d", [(32, 256, 16), (100, 130, 48), (1, 64, 8)])
def test_fl_gains_gram_free_delta_kernel_sweep(b, ncand, d):
    """Fused lazy-gain delta kernel vs oracle, incl. the inf-padding contract
    (rows with c_old = c_new = +inf contribute exact zeros) and the algebraic
    identity delta == restricted_gains(c_new) - restricted_gains(c_old)."""
    z = jnp.asarray(RNG.normal(size=(ncand, d)).astype(np.float32))
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)
    zr = z[:b] if b <= ncand else jnp.concatenate([z] * (b // ncand + 1))[:b]
    c_old = jnp.asarray(RNG.uniform(size=(b,)).astype(np.float32))
    c_new = jnp.minimum(c_old + RNG.uniform(size=(b,)).astype(np.float32), 1.0)
    # mark a few rows as padding (both covers infinite)
    pad = jnp.arange(b) % 5 == 3
    c_old = jnp.where(pad, jnp.inf, c_old)
    c_new = jnp.where(pad, jnp.inf, c_new)
    out = fl_ops.fl_gains_gram_free_delta(zr, z, c_old, c_new,
                                          block_i=64, block_j=64,
                                          interpret=True)
    ref = fl_gains_gram_free_delta_ref(zr, z, c_old, c_new)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    split = (fl_gains_gram_free_ref(zr, z, c_new)
             - fl_gains_gram_free_ref(zr, z, c_old))
    np.testing.assert_allclose(np.asarray(out), np.asarray(split),
                               rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(out) <= 1e-5), "cover only grows: delta <= 0"


@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,d",
    [
        (1, 4, 4, 64, 64, 32),      # MHA
        (2, 8, 2, 128, 128, 32),    # GQA
        (2, 8, 2, 200, 200, 32),    # ragged seq (padding path)
        (1, 4, 1, 64, 256, 64),     # cross-length causal (prefix)
        (4, 8, 4, 1, 333, 32),      # decode: 1 query vs long KV
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel_sweep(b, hq, hkv, sq, sk, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True, interpret=True)
    ref = gqa_attention_ref(q, k, v, causal=True).astype(dtype)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), **_tol(dtype)
    )


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.normal(size=(1, 2, 100, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 150, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 150, 16)), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=False, interpret=True)
    ref = gqa_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=2e-4)


def test_similarity_matches_core_gram():
    """The Pallas path must agree with core.similarity.gram_matrix."""
    from repro.core.similarity import gram_matrix

    z = jnp.asarray(RNG.normal(size=(120, 24)), jnp.float32)
    a = sim_ops.similarity(z, z, interpret=True)
    b = gram_matrix(z)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_fl_gains_drives_greedy_equivalently():
    """Greedy with the Pallas gains == greedy with the analytic gains."""
    from repro.core.similarity import gram_matrix

    z = jnp.asarray(RNG.normal(size=(96, 16)), jnp.float32)
    K = gram_matrix(z)
    c = jnp.zeros((96,))
    sel = []
    for _ in range(5):
        gains = fl_ops.fl_gains(K, c, interpret=True)
        gains = gains.at[jnp.asarray(sel, jnp.int32)].set(-1e30) if sel else gains
        j = int(jnp.argmax(gains))
        sel.append(j)
        c = jnp.maximum(c, K[:, j])
    from repro.core import facility_location, greedy

    ref = np.asarray(greedy(facility_location, K, 5).indices).tolist()
    assert sel == ref


def test_pallas_facility_location_setfunction_in_greedy():
    """The Pallas-gains SetFunction drives the jit'd greedy engine to the
    identical selection trajectory as the analytic one."""
    from repro.core import greedy
    from repro.core.similarity import gram_matrix
    from repro.core.submodular import facility_location, make_facility_location_pallas

    z = jnp.asarray(RNG.normal(size=(64, 12)), jnp.float32)
    K = gram_matrix(z)
    fn_p = make_facility_location_pallas(interpret=True, block_i=64, block_j=64)
    a = np.asarray(greedy(facility_location, K, 6).indices)
    b = np.asarray(greedy(fn_p, K, 6).indices)
    np.testing.assert_array_equal(a, b)
