"""Dry-run machinery smoke test on a tiny forced-device mesh (subprocess so
the 8-device runtime never leaks into the main test session)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp
from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.launch import specs, hlo_analysis
from repro.optim.optimizers import adamw
from repro.train import train_state as ts

from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
opt = adamw()
# reduced config but the REAL dry-run path: sharded abstract inputs,
# lower + compile + analyze, train and decode kinds
cfg = dataclasses.replace(registry.smoke("yi-6b"), remat=True,
                          attention_impl="chunked", attn_block=32)
for shape in (ShapeConfig("t", 64, 8, "train"), ShapeConfig("d", 64, 8, "decode")):
    with mesh:
        if shape.kind == "train":
            fn = ts.make_train_step(cfg, opt, lambda s: 1e-3)
            args = specs.input_specs(cfg, mesh, shape, opt)
            compiled = jax.jit(fn).lower(*args).compile()
        else:
            fn = ts.make_serve_step(cfg)
            params, caches, batch = specs.input_specs(cfg, mesh, shape, opt)
            compiled = jax.jit(fn).lower(params, caches, batch).compile()
    t = hlo_analysis.analyze(compiled.as_text())
    assert t["flops"] > 0 and t["bytes"] > 0, (shape.kind, t)
    print(shape.kind, "OK", int(t["flops"]))
print("DRYRUN_SMOKE_OK")
"""


def test_dryrun_machinery_on_tiny_mesh():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH="src"),
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=560,
    )
    assert "DRYRUN_SMOKE_OK" in r.stdout, (r.stdout[-800:], r.stderr[-2500:])
