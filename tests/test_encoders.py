"""Feature-encoder tests: ViT, text encoder, proxy (the paper's three
encoder paths)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.datasets import GaussianMixtureDataset
from repro.encoders.proxy import ProxyEncoder
from repro.encoders.text import TextEncoderConfig, init_text_encoder, text_encode
from repro.encoders.vit import ViTConfig, init_vit, vit_encode


def test_vit_encoder_shapes_and_determinism():
    cfg = ViTConfig(image_size=32, patch_size=8, d_model=64, num_layers=2,
                    num_heads=4, d_ff=128)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 32, 3))
    z = vit_encode(params, imgs, cfg)
    assert z.shape == (3, 64)
    assert bool(jnp.all(jnp.isfinite(z)))
    z2 = vit_encode(params, imgs, cfg)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(z2))


def test_text_encoder_mean_pooling_respects_mask():
    cfg = TextEncoderConfig(vocab_size=100, max_len=16, d_model=32,
                            num_layers=2, num_heads=4, d_ff=64)
    params = init_text_encoder(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 100)
    mask = jnp.asarray([[1] * 10, [1] * 4 + [0] * 6], jnp.float32)
    z = text_encode(params, toks, cfg, mask)
    assert z.shape == (2, 32)
    # masked-out tail must not affect the embedding
    toks2 = toks.at[1, 4:].set(0)
    z2 = text_encode(params, toks2, cfg, mask)
    np.testing.assert_allclose(np.asarray(z[1]), np.asarray(z2[1]), atol=1e-5)


def test_proxy_encoder_learns_and_features_separate_classes():
    ds = GaussianMixtureDataset(n=400, n_classes=4, dim=12, seed=0)
    enc = ProxyEncoder(d_in=12, n_classes=4, d_hidden=32, epochs=80).fit(ds.x, ds.y)
    acc = enc.linear_probe_accuracy(ds.x, ds.y)
    assert acc > 0.8, acc
    feats = enc.encode(ds.x)
    assert feats.shape == (400, 32)
    # within-class cosine similarity should exceed cross-class
    f = feats / np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-6)
    sims = f @ f.T
    same = (ds.y[:, None] == ds.y[None, :])
    np.fill_diagonal(same, False)
    assert sims[same].mean() > sims[~same].mean() + 0.1
