"""Selection-as-a-service contracts (ISSUE 6): artifact store single-flight
builds and reuse guards, LRU + pin eviction with bit-identical disk reloads,
shared device-resident buffers across concurrent trainers, and the
``MiloServer`` request lifecycle (submit/poll/result/cancel, deadlines,
structured request log)."""
import dataclasses
import shutil
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metadata import MetadataMismatchError
from repro.selection import MiloSession, MiloSessionConfig
from repro.serve import (
    CANCELLED,
    DONE,
    ERROR,
    EXPIRED,
    ArtifactStore,
    BufferRegistry,
    MiloClient,
    MiloServer,
    artifact_request_config,
)

N, D, CLASSES = 240, 8, 3


def _dataset(seed: int = 0):
    rng = np.random.default_rng(seed)
    labs = rng.integers(0, CLASSES, N).astype(np.int64)
    feats = (rng.normal(size=(N, D)) + 0.8 * labs[:, None]).astype(np.float32)
    vx = (rng.normal(size=(48, D))).astype(np.float32)
    vy = rng.integers(0, CLASSES, 48).astype(np.int64)
    return feats, labs, vx, vy


def _config(**kw) -> MiloSessionConfig:
    base = dict(subset_fraction=0.2, n_sge_subsets=2, gram_free=True,
                total_epochs=4, eval_every_epochs=2, sub_steps=2,
                fused_training=True)
    base.update(kw)
    return MiloSessionConfig(**base)


def _build_fn(cfg: MiloSessionConfig, feats, labs, fp):
    session = MiloSession(cfg)
    return lambda: session.build_metadata(feats, labs, fingerprint=fp)


# ---------------------------------------------------------------------------
# artifact store
# ---------------------------------------------------------------------------

def test_store_single_flight_concurrent_builds(tmp_path):
    """N concurrent requests for one missing key trigger exactly ONE
    preprocessing run; every waiter gets the same decoded object."""
    feats, labs, _, _ = _dataset()
    cfg = _config()
    store = ArtifactStore(str(tmp_path / "store"))
    req = artifact_request_config(cfg)
    session = MiloSession(cfg)
    fp = "f" * 16
    key = store.key_for(fp, req)
    calls, results, errors = [], [], []

    def build():
        calls.append(1)
        time.sleep(0.05)  # widen the race window
        return session.build_metadata(feats, labs, fingerprint=fp)

    def worker():
        try:
            md, entry, source = store.get_or_build(key, req, build)
            results.append((md, source))
        except BaseException as e:  # pragma: no cover - fail loudly below
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(calls) == 1 and store.builds == 1
    assert len(results) == 6
    mds = {id(md) for md, _ in results}
    assert len(mds) == 1, "all waiters must share the one built artifact"
    assert sorted(s for _, s in results) == ["built"] + ["memory"] * 5


def test_store_foreign_artifact_raises_mismatch(tmp_path):
    """A file parked at a key's path whose stored config disagrees with the
    request is refused (MetadataMismatchError), never silently served."""
    feats, labs, _, _ = _dataset()
    cfg_a, cfg_b = _config(subset_fraction=0.2), _config(subset_fraction=0.1)
    store = ArtifactStore(str(tmp_path / "store"))
    fp = "a" * 16
    req_a = artifact_request_config(cfg_a)
    key_a = store.key_for(fp, req_a)
    store.get_or_build(key_a, req_a, _build_fn(cfg_a, feats, labs, fp))

    req_b = artifact_request_config(cfg_b)
    key_b = store.key_for(fp, req_b)
    assert key_a != key_b
    # adversarial setup: artifact A masquerading under B's key on disk
    shutil.copy(store.path_for(key_a), store.path_for(key_b))
    fresh = ArtifactStore(store.root)  # cold memory tier -> must hit disk
    with pytest.raises(MetadataMismatchError, match="subset_fraction"):
        fresh.get_or_build(key_b, req_b, _build_fn(cfg_b, feats, labs, fp))


def test_store_wrong_fingerprint_raises_mismatch(tmp_path):
    """Same config but different data: the recorded fingerprint guard."""
    feats, labs, _, _ = _dataset()
    cfg = _config()
    store = ArtifactStore(str(tmp_path / "store"))
    req = artifact_request_config(cfg)
    key1 = store.key_for("1" * 16, req)
    store.get_or_build(key1, req, _build_fn(cfg, feats, labs, "1" * 16))
    key2 = store.key_for("2" * 16, req)
    shutil.copy(store.path_for(key1), store.path_for(key2))
    fresh = ArtifactStore(store.root)
    with pytest.raises(MetadataMismatchError, match="fingerprint"):
        fresh.get_or_build(key2, req, _build_fn(cfg, feats, labs, "2" * 16))


def test_store_evict_reload_bit_identical_plans(tmp_path):
    """LRU eviction drops only the memory tier: the next request reloads
    from disk and the selection plans it produces are BIT-identical to the
    original build's."""
    cfg = _config()
    store = ArtifactStore(str(tmp_path / "store"), capacity=1)
    req = artifact_request_config(cfg)
    sessions, keys, built = {}, {}, {}
    for seed in (0, 1):
        feats, labs, _, _ = _dataset(seed)
        fp = f"{seed}" * 16
        key = store.key_for(fp, req)
        md, _, source = store.get_or_build(
            key, req, _build_fn(cfg, feats, labs, fp))
        assert source == "built"
        keys[seed], built[seed] = key, md
        sess = MiloSession(cfg)
        sess.adopt_metadata(md)
        sessions[seed] = sess
    # capacity=1: building seed 1 evicted seed 0 from memory, not disk
    assert store.evictions == 1
    assert not store.resident(keys[0]) and store.resident(keys[1])

    md0, entry, source = store.get_or_build(
        keys[0], req, lambda: pytest.fail("reload must not rebuild"))
    assert source == "disk" and store.disk_loads == 1 and store.builds == 2
    assert entry.version == 1
    np.testing.assert_array_equal(md0.sge_subsets, built[0].sge_subsets)
    np.testing.assert_array_equal(md0.wre_probs, built[0].wre_probs)
    np.testing.assert_array_equal(md0.wre_importance, built[0].wre_importance)

    reloaded = MiloSession(cfg)
    reloaded.adopt_metadata(md0)
    for epoch in (0, 3):
        a = sessions[0].selector(n=N).plan(epoch)
        b = reloaded.selector(n=N).plan(epoch)
        np.testing.assert_array_equal(a.indices, b.indices)
        np.testing.assert_array_equal(a.weights, b.weights)
        assert a.phase == b.phase


def test_store_pinned_entries_survive_eviction(tmp_path):
    cfg = _config()
    store = ArtifactStore(str(tmp_path / "store"), capacity=1)
    req = artifact_request_config(cfg)
    feats, labs, _, _ = _dataset()
    key1 = store.key_for("p" * 16, req)
    store.get_or_build(key1, req, _build_fn(cfg, feats, labs, "p" * 16),
                       pin=True)
    key2 = store.key_for("q" * 16, req)
    store.get_or_build(key2, req, _build_fn(cfg, feats, labs, "q" * 16))
    assert store.resident(key1), "pinned entry must never be evicted"
    store.unpin(key1)
    key3 = store.key_for("r" * 16, req)
    store.get_or_build(key3, req, _build_fn(cfg, feats, labs, "r" * 16))
    assert not store.resident(key1)


def test_store_force_bumps_version(tmp_path):
    cfg = _config()
    store = ArtifactStore(str(tmp_path / "store"))
    req = artifact_request_config(cfg)
    feats, labs, _, _ = _dataset()
    fp = "v" * 16
    key = store.key_for(fp, req)
    _, e1, _ = store.get_or_build(key, req, _build_fn(cfg, feats, labs, fp))
    _, e2, s2 = store.get_or_build(key, req, _build_fn(cfg, feats, labs, fp))
    assert (e1.version, e2.version, s2) == (1, 1, "memory")
    _, e3, s3 = store.get_or_build(key, req, _build_fn(cfg, feats, labs, fp),
                                   force=True)
    assert (e3.version, s3) == (2, "built")


# ---------------------------------------------------------------------------
# shared device buffers
# ---------------------------------------------------------------------------

def test_buffer_registry_identity_and_put_counting():
    reg = BufferRegistry()
    x = np.arange(24, dtype=np.float32).reshape(6, 4)
    b1 = reg.column(x)
    b2 = reg.column(x)                    # identity fast path
    b3 = reg.column(x.copy())             # equal content, different object
    assert b1 is b2 is b3
    assert reg.put_count == 1 and reg.hits == 2
    y = x + 1.0
    assert reg.column(y) is not b1 and reg.put_count == 2


def test_concurrent_trainers_share_one_device_buffer():
    """Two fused Trainers over the same dataset (server path: sessions with
    a shared BufferRegistry) hold the SAME device buffer object per column —
    one device_put total, counted by the registry."""
    feats, labs, vx, vy = _dataset()
    reg = BufferRegistry()
    reports = []
    for seed in (0, 1):
        sess = MiloSession(_config(), buffer_registry=reg)
        sess.preprocess(feats, labs)
        reports.append(sess.train(feats, labs, test_x=vx, test_y=vy, seed=seed))
    assert all(r.steps > 0 for r in reports)
    stats = reg.stats()
    assert stats["put_count"] == 2, "one placement per column (x, y), ever"
    assert stats["resident_columns"] == 2
    assert stats["hits"] >= 2, "second trainer reused both columns"
    # the registry's resident buffers ARE shared by identity
    a = reg.get({"x": feats, "y": labs})
    b = reg.get({"x": feats, "y": labs})
    assert a["x"] is b["x"] and a["y"] is b["y"]
    assert reg.put_count == 2


# ---------------------------------------------------------------------------
# server lifecycle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def warm_server(tmp_path_factory):
    feats, labs, vx, vy = _dataset()
    server = MiloServer(
        _config(), store_root=str(tmp_path_factory.mktemp("artifacts")),
        num_workers=2,
    ).start()
    server.warm(feats, labs)
    yield server, (feats, labs, vx, vy)
    server.shutdown()


def test_server_concurrent_identical_submits_build_once(warm_server):
    """The serving half of single-flight: concurrent identical tune submits
    resolve to one artifact (no rebuild — the warm() build is the only one)
    and every request succeeds against the shared cache."""
    server, (feats, labs, vx, vy) = warm_server
    space = {"lr": ("log", 1e-3, 0.3)}
    builds_before = server.store.builds
    # per-tenant SEARCH seeds go through the tune payload; a config-level
    # seed override would (correctly) change the prep seed and thus the
    # artifact key — tenants may not share artifacts across prep seeds
    rids = [
        server.submit("tune", features=feats, labels=labs, val_x=vx,
                      val_y=vy, space=space, max_budget=3, tenant=f"t{i}",
                      seed=50 + i)
        for i in range(3)
    ]
    results = [server.result(rid, timeout=300) for rid in rids]
    assert server.store.builds == builds_before, "no request may rebuild"
    for rid, res in zip(rids, results):
        row = server.poll(rid)
        assert row["status"] == DONE
        assert row["artifact_source"] == "memory"
        assert res.best_config is not None and not res.stopped


def test_server_train_and_log(warm_server):
    server, (feats, labs, vx, vy) = warm_server
    client = MiloClient(server, tenant="trainer")
    report = client.train(feats, labs, test_x=vx, test_y=vy)
    assert report.steps > 0
    rows = server.request_log()
    assert rows, "every completed request logs one structured row"
    last = rows[-1]
    assert {"request_id", "kind", "tenant", "status", "artifact_key",
            "artifact_version", "artifact_source", "submitted", "started",
            "finished"} <= set(last)
    assert last["kind"] == "train" and last["tenant"] == "trainer"
    assert last["status"] == DONE and last["finished"] >= last["started"]


def test_server_cancel_queued_request(warm_server):
    server, (feats, labs, vx, vy) = warm_server
    space = {"lr": ("log", 1e-3, 0.3)}
    # saturate both workers so the victim stays queued long enough to cancel
    blockers = [
        server.submit("tune", features=feats, labels=labs, val_x=vx,
                      val_y=vy, space=space, max_budget=9)
        for _ in range(2)
    ]
    victim = server.submit("train", features=feats, labels=labs,
                           test_x=vx, test_y=vy)
    assert server.cancel(victim)
    with pytest.raises(TimeoutError, match="cancelled"):
        server.result(victim, timeout=300)
    assert server.poll(victim)["status"] == CANCELLED
    for rid in blockers:
        server.result(rid, timeout=300)
    assert not server.cancel(victim), "terminal requests cannot be cancelled"


def test_server_deadline_expires_queued_request(warm_server):
    server, (feats, labs, vx, vy) = warm_server
    space = {"lr": ("log", 1e-3, 0.3)}
    blockers = [
        server.submit("tune", features=feats, labels=labs, val_x=vx,
                      val_y=vy, space=space, max_budget=9)
        for _ in range(2)
    ]
    doomed = server.submit("train", features=feats, labels=labs,
                           test_x=vx, test_y=vy, deadline=0.0)
    with pytest.raises(TimeoutError, match="expired"):
        server.result(doomed, timeout=300)
    assert server.poll(doomed)["status"] == EXPIRED
    for rid in blockers:
        server.result(rid, timeout=300)


def test_server_tune_should_stop_at_rung_boundary(warm_server):
    """A cancelled running tune stops at the next hyperband rung: the
    underlying hyperband result records stopped=True."""
    server, (feats, labs, vx, vy) = warm_server
    from repro.tuning.tuner import RandomSearch, hyperband

    calls = []

    def objective(cfg, budget):
        calls.append(1)
        return 0.5

    res = hyperband(objective, RandomSearch({"lr": ("log", 1e-3, 0.3)}),
                    max_budget=9, should_stop=lambda: len(calls) > 0)
    assert res.stopped and len(res.trials) == len(calls)
    # and the server surfaces a stopped tune as EXPIRED/CANCELLED, keeping
    # the partial result on the request record
    rid = server.submit("tune", features=feats, labels=labs, val_x=vx,
                        val_y=vy, space={"lr": ("log", 1e-3, 0.3)},
                        max_budget=9, deadline=1e-3)
    with pytest.raises(TimeoutError):
        server.result(rid, timeout=300)
    req = server._request(rid)
    assert req.status in (EXPIRED, CANCELLED)
    assert req.result is None or req.result.stopped


def test_server_error_requests_reraise(warm_server):
    server, (feats, labs, vx, vy) = warm_server
    rid = server.submit("tune", features=feats, labels=labs, val_x=vx,
                        val_y=vy, space={"bogus": ("log", 1e-3, 1.0)})
    with pytest.raises(ValueError, match="bogus"):
        server.result(rid, timeout=300)
    assert server.poll(rid)["status"] == ERROR


def test_server_rejects_unknown_kind(warm_server):
    server, (feats, labs, _, _) = warm_server
    with pytest.raises(ValueError, match="unknown request kind"):
        server.submit("frobnicate", features=feats, labels=labs)


def test_adopt_metadata_guards_config(tmp_path):
    feats, labs, _, _ = _dataset()
    md = MiloSession(_config()).build_metadata(feats, labs)
    other = MiloSession(_config(subset_fraction=0.1))
    with pytest.raises(MetadataMismatchError, match="subset_fraction"):
        other.adopt_metadata(md)
    wrong_seed = MiloSession(_config(prep_seed=99))
    with pytest.raises(MetadataMismatchError, match="prep_seed"):
        wrong_seed.adopt_metadata(md)


# ---------------------------------------------------------------------------
# LM engine relocation shim
# ---------------------------------------------------------------------------

def test_lm_engine_shim_reexports():
    """serve.engine stays importable after the move to serve.lm_engine."""
    from repro.serve import engine as shim
    from repro.serve import lm_engine

    assert shim.ServeEngine is lm_engine.ServeEngine
    assert shim.Request is lm_engine.Request
    assert "prefilled" in (lm_engine.__doc__ or "")
