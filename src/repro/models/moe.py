"""Top-k routed Mixture-of-Experts with capacity-based GShard dispatch.

Token groups of ``group_size`` are routed independently; each expert takes at
most ``capacity = group_size/E * k * capacity_factor`` tokens per group
(overflow drops, standard Switch/GShard semantics).  Dispatch/combine are
one-hot einsums: with the expert dim sharded over the ``model`` mesh axis
GSPMD lowers them to all-to-alls (EP), and the group dim is sharded over
``data`` so the dispatch tensor never materializes globally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import init_dense, init_mlp


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype=jnp.bfloat16) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    def expert_stack(k, din, dout):
        keys = jax.random.split(k, n_experts)
        return jnp.stack([init_dense(kk, din, dout, dtype) for kk in keys])

    return {
        "router": init_dense(kr, d_model, n_experts, jnp.float32),
        "w_gate": expert_stack(kg, d_model, d_ff),   # (E, D, F)
        "w_up": expert_stack(ku, d_model, d_ff),     # (E, D, F)
        "w_down": expert_stack(kd, d_ff, d_model),   # (E, F, D)
    }


def moe_dropless(params: dict, x: jax.Array, *, top_k: int) -> jax.Array:
    """Dense dropless MoE: every expert computed for every token, combined by
    the (renormalized) top-k router weights.  E× FLOPs — used for decode
    steps where the token count is tiny and capacity dropping would make
    decode diverge from prefill."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)
    gate = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32) * topv[..., None], axis=-2)  # (b,s,e)
    h = jnp.einsum("bsd,edf->bsef", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    y = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u, params["w_down"])
    return jnp.einsum("bsed,bse->bsd", y, gate.astype(x.dtype))


def moe(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
    dropless: bool = False,
) -> jax.Array:
    """Apply MoE to (B, S, D); returns (B, S, D)."""
    if dropless:
        return moe_dropless(params, x, top_k=top_k)
    b, s, d = x.shape
    e = params["router"].shape[-1]
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    gs = min(group_size, t)
    # pad to a multiple of the group size (padded tokens route but are dropped
    # on reshape-back)
    pad = (-t) % gs
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    g = tokens.shape[0] // gs
    xg = constrain(tokens.reshape(g, gs, d), "batch", None, None)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)                   # (g, gs, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    cap = max(1, int(gs / e * top_k * capacity_factor))
    # position of each (token, choice) in its expert's buffer.  §Perf iter-4:
    # the dispatch one-hots are exact 0/1 values — the activation dtype
    # (bf16 in production) holds them losslessly and halves the dispatch
    # traffic; the cumsum that needs exact wide integers stays f32.
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)        # (g, gs, k, e)
    # flatten the k choices in priority order before cumsum so earlier choices
    # claim capacity first
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, top_k * gs, e)
    pos = jnp.cumsum(flat, axis=1) - flat                      # (g, k*gs, e)
    pos = pos.reshape(g, top_k, gs, e).transpose(0, 2, 1, 3)   # (g, gs, k, e)
    keep = ((pos < cap) * onehot).astype(x.dtype)              # drop overflow
    # dispatch: (g, gs, e, cap)
    pos_idx = jnp.sum(pos * onehot, axis=-1)                   # (g, gs, k)
    cap_onehot = jax.nn.one_hot(pos_idx, cap, dtype=x.dtype)   # (g, gs, k, cap)
    dispatch = jnp.einsum("gske,gskc->gsec", keep, cap_onehot)
    combine = jnp.einsum("gske,gskc,gsk->gsec", keep, cap_onehot,
                         topv.astype(x.dtype))

    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)   # (g, e, cap, d)
    xe = constrain(xe, "batch", "model", None, None)  # EP: all-to-all to experts
    h = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"])            # (g, e, cap, d)
    ye = constrain(ye, "batch", "model", None, None)
    yg = constrain(jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye), "batch", None, None)

    y = yg.reshape(-1, d)[:t]
    return y.reshape(b, s, d)
