"""Primitive layers: norms, dense, embedding, rotary embedding.

Functional style: ``init_*`` returns a params pytree (nested dicts of
jnp arrays); ``apply`` functions are pure.  Weight layouts are chosen so the
sharding rules in ``repro.distributed.sharding`` can map named logical axes
(embed/ffn/heads/vocab/experts) straight onto mesh axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics and storage-dtype I/O.

    §Perf iter-5: custom VJP saves only the bf16 input and recomputes the f32
    statistics in backward — the default VJP keeps (B, S, D) f32 normalized
    intermediates alive across the residual stream (the largest single HBM
    contributor on jamba/llama-scale models, ~20% of all traffic).
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


def _rms_norm_fwd(x, scale, eps):
    return rms_norm(x, scale, eps), (x, scale)


def _rms_norm_bwd(eps, res, g):
    x, scale = res
    x32 = x.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    xhat = x32 * inv
    gs = g32 * scale.astype(jnp.float32)
    dx = inv * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(
        (g32 * xhat).reshape(-1, x.shape[-1]), axis=0
    ).astype(scale.dtype)
    return dx.astype(x.dtype), dscale


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def init_dense(key: jax.Array, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, w)


def init_embedding(key: jax.Array, vocab: int, d_model: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)




@jax.custom_vjp
def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Tied unembedding: (..., D) @ (V, D)^T -> (..., V) logits.

    §Perf iter-3: logits stay in the activation dtype (bf16) with f32 MXU
    accumulation — the (B, S, V) logits tensor is one of the largest
    activations in the graph; the CE loss upcasts per-element at use.

    §Perf iter-4: custom VJP keeps the *cotangents* in the storage dtype too
    (f32 accumulation inside the dots only) — the default VJP materializes
    (B·S, D) and (B·S, V) f32 tensors that dominated jamba's HBM traffic
    (~28% of all bytes).
    """
    acc = jnp.einsum("...d,vd->...v", x, table, preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def _unembed_fwd(x, table):
    return unembed(x, table), (x, table)


def _unembed_bwd(res, g):
    x, table = res
    g = g.astype(x.dtype)
    dx = jnp.einsum("...v,vd->...d", g, table,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    dtable = jnp.einsum("...v,...d->vd", g, x,
                        preferred_element_type=jnp.float32).astype(table.dtype)
    return dx, dtable


unembed.defvjp(_unembed_fwd, _unembed_bwd)


# --- rotary -----------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.

    Args:
      x: (..., S, H, D) with D even.
      positions: (..., S) int32 absolute positions (broadcastable).
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(dense(x, w_gate)) * dense(x, w_up)
    # §Perf iter-6: storage-dtype dot output (see attention.py note)
    return jnp.einsum("...f,fd->...d", h.astype(x.dtype), w_down,
                      preferred_element_type=x.dtype)


def init_mlp(key: jax.Array, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d_model, d_ff, dtype),
        "w_up": init_dense(k2, d_model, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d_model, dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
