"""State-space / recurrent blocks: Mamba (SSD form), mLSTM, sLSTM.

TPU adaptation (DESIGN.md §2): the selective-scan recurrences are computed in
the Mamba-2 *SSD* chunked form — per-head scalar decay, intra-chunk (L, L)
decay matmuls on the MXU, inter-chunk state carried through a ``lax.scan`` —
instead of the channel-diagonal Mamba-1 CUDA scan (which would materialize a
(B, S, d_inner, N) tensor; hopeless on any hardware without a fused kernel).
mLSTM's matrix memory C_t = f_t C + i_t v kᵀ is the same algebra with N = P,
so it shares the chunked engine.  sLSTM is inherently sequential (scalar
memory mixing) and runs as a ``lax.scan`` over time.

Recurrence (per head h, chunk length L):
    h_t = a_t h_{t-1} + (dt_t b_t) x_tᵀ        a_t = exp(-softplus(A) dt_t)
    y_t = c_tᵀ h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_mamba(key, d_model: int, *, expand: int = 2, head_dim: int = 64, d_state: int = 128, dtype=jnp.bfloat16) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    k_in, k_bc, k_dt, k_out, k_a = jax.random.split(key, 5)
    return {
        "w_in": init_dense(k_in, d_model, 2 * d_inner, dtype),       # x and gate z
        "w_bc": init_dense(k_bc, d_model, 2 * d_state, dtype),       # B and C
        "w_dt": init_dense(k_dt, d_model, n_heads, dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),                 # A = -softplus-ish
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "w_out": init_dense(k_out, d_inner, d_model, dtype),
        "norm": jnp.ones((d_inner,), jnp.float32),
    }


def _ssd_chunk_scan(x, a, b, c, *, chunk: int, return_state: bool = False):
    """Chunked linear recurrence.

    Args:
      x: (B, S, H, P) values;  a: (B, S, H) decay in (0,1];
      b: (B, S, N) input proj; c: (B, S, N) output proj (shared across heads).
    Returns y: (B, S, H, P), and the final state (B, H, N, P) if requested.

    Note on padding + final state: padded positions use a=1, b=0, so they do
    not perturb the carried state.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    # (nc, B, L, ...) chunk-major for scan
    xc = x.reshape(B, nc, chunk, H, P).swapaxes(0, 1)
    ac = a.reshape(B, nc, chunk, H).swapaxes(0, 1)
    bc_ = b.reshape(B, nc, chunk, N).swapaxes(0, 1)
    cc = c.reshape(B, nc, chunk, N).swapaxes(0, 1)

    def step(h, xs):
        xb, ab, bb, cb = xs          # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        la = jnp.log(jnp.maximum(ab, 1e-20))          # (B,L,H)
        cum = jnp.cumsum(la, axis=1)                  # log prod a_{1..t}
        # intra-chunk: decay(s->t) = exp(cum_t - cum_s) for s <= t
        dt_mat = cum[:, :, None, :] - cum[:, None, :, :]        # (B,L,L,H) t,s
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(dt_mat), 0.0)
        scores = jnp.einsum("btn,bsn->bts", cb, bb)             # (B,L,L)
        w = scores[..., None] * decay                           # (B,L,L,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xb)
        # contribution of the carried state (decayed to each position t)
        y_inter = jnp.einsum("btn,bhnp,bth->bthp", cb, h, jnp.exp(cum))
        # state update: h' = (prod a) h + sum_s (prod_{s< .. end}) b_s x_s
        tot = cum[:, -1, :]                                     # (B,H)
        rem = jnp.exp(tot[:, None, :] - cum)                    # decay from s to end
        h_new = jnp.exp(tot)[..., None, None] * h + jnp.einsum(
            "bsn,bshp,bsh->bhnp", bb, xb, rem
        )
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    # §Perf iter-4: checkpoint each chunk step — backward otherwise saves the
    # (chunks, B, L, L, H) decay/score residuals stacked across the scan
    # (~12% of jamba's HBM traffic); recomputing them per chunk is free
    # against the memory roof.
    h_fin, ys = jax.lax.scan(jax.checkpoint(step), h0,
                             (xc.astype(jnp.float32), ac.astype(jnp.float32),
                              bc_.astype(jnp.float32), cc.astype(jnp.float32)))
    y = ys.swapaxes(0, 1).reshape(B, nc * chunk, H, P)[:, :S]
    if return_state:
        return y, h_fin
    return y


def mamba(params: dict, x: jax.Array, *, chunk: int = 256,
          state: jax.Array | None = None, mode: str = "train",
          impl: str = "chunked", interpret: bool = True) -> tuple[jax.Array, jax.Array | None]:
    """Mamba/SSD mixer.  x: (B, S, D).

    ``mode='decode'``: S==1, sequential state update against ``state``
    (B, H, N, P); returns (y, new_state).  Other modes return (y, final_state
    is None) — training does not thread state across calls.
    """
    B, S, D = x.shape
    d_inner2 = params["w_in"].shape[-1]
    d_inner = d_inner2 // 2
    n_heads = params["w_dt"].shape[-1]
    P = d_inner // n_heads
    N = params["w_bc"].shape[-1] // 2

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("bsd,dn->bsn", x, params["w_bc"]).astype(jnp.float32)
    b_proj, c_proj = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["w_dt"]).astype(jnp.float32) + params["dt_bias"]
    )
    a = jnp.exp(-jax.nn.softplus(params["a_log"])[None, None, :] * dt)    # (B,S,H)
    xh = xi.reshape(B, S, n_heads, P).astype(jnp.float32) * dt[..., None]

    if mode == "decode":
        assert state is not None and S == 1
        h_new = a[:, 0, :, None, None] * state + jnp.einsum(
            "bn,bhp->bhnp", b_proj[:, 0], xh[:, 0]
        )
        y = jnp.einsum("bn,bhnp->bhp", c_proj[:, 0], h_new)[:, None]       # (B,1,H,P)
        new_state = h_new
    elif impl == "pallas":
        from repro.kernels.ssd_chunk import ops as ssd_ops

        y, h_fin = ssd_ops.ssd_scan(xh, a, b_proj, c_proj, chunk=chunk,
                                    use_pallas=True, interpret=interpret)
        new_state = h_fin if mode == "prefill" else None
    elif mode == "prefill":
        y, new_state = _ssd_chunk_scan(xh, a, b_proj, c_proj, chunk=chunk, return_state=True)
    else:
        y = _ssd_chunk_scan(xh, a, b_proj, c_proj, chunk=chunk)
        new_state = None

    y = y.reshape(B, S, d_inner)
    # gated RMS norm (Mamba-2 style)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["w_out"],
                     preferred_element_type=x.dtype)  # §Perf iter-6
    return out, new_state


# --- xLSTM ------------------------------------------------------------------

def init_mlstm(key, d_model: int, *, expand: int = 2, head_dim: int = 64, dtype=jnp.bfloat16) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    kq, kk, kv, kf, ki, ko, kz = jax.random.split(key, 7)
    return {
        "wq": init_dense(kq, d_model, d_inner, dtype),
        "wk": init_dense(kk, d_model, d_inner, dtype),
        "wv": init_dense(kv, d_model, d_inner, dtype),
        "w_fgate": init_dense(kf, d_model, n_heads, jnp.float32),
        "w_igate": init_dense(ki, d_model, n_heads, jnp.float32),
        "w_z": init_dense(kz, d_model, d_inner, dtype),   # output gate source
        "w_out": init_dense(ko, d_inner, d_model, dtype),
        "norm": jnp.ones((d_inner,), jnp.float32),
    }


def mlstm(params: dict, x: jax.Array, *, chunk: int = 256,
          state: jax.Array | None = None, mode: str = "train") -> tuple[jax.Array, jax.Array | None]:
    """mLSTM matrix-memory block via the shared SSD engine (N == P == head_dim).

    C_t = f_t C_{t-1} + i_t v_t k_tᵀ;  y_t = C_t q_t  — i.e. the linear
    recurrence with a = sigmoid(fgate), x-values = i_t * v_t, b = k, c = q.
    """
    B, S, D = x.shape
    d_inner = params["wq"].shape[-1]
    n_heads = params["w_fgate"].shape[-1]
    P = d_inner // n_heads
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, n_heads, P)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(B, S, n_heads, P) / (P ** 0.5)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(B, S, n_heads, P)
    f = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["w_fgate"]))
    i = jnp.exp(-jax.nn.softplus(-jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), params["w_igate"])))

    vals = v.astype(jnp.float32) * i[..., None]
    if mode == "decode":
        assert state is not None and S == 1
        # per-head state (B, H, P, P): b=k, c=q per head
        h_new = f[:, 0, :, None, None] * state + jnp.einsum(
            "bhn,bhp->bhnp", k[:, 0].astype(jnp.float32), vals[:, 0]
        )
        y = jnp.einsum("bhn,bhnp->bhp", q[:, 0].astype(jnp.float32), h_new)[:, None]
        new_state = h_new
    else:
        # per-head keys/queries: reuse _ssd_chunk_scan per head via vmap on H
        def per_head(xh, ah, bh, ch):
            y, st = _ssd_chunk_scan(
                xh[..., None, :], ah[..., None], bh, ch, chunk=chunk, return_state=True
            )
            return y[..., 0, :], st[:, 0]  # (B,S,P), (B,N,P)

        y, st = jax.vmap(per_head, in_axes=(2, 2, 2, 2), out_axes=(2, 1))(
            vals, f, k.astype(jnp.float32), q.astype(jnp.float32)
        )
        new_state = st if mode == "prefill" else None
    y = y.reshape(B, S, d_inner)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm"]
    y = y * jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_z"]).astype(jnp.float32))
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["w_out"],
                      preferred_element_type=x.dtype), new_state  # §Perf iter-6


def init_slstm(key, d_model: int, *, n_heads: int = 4, dtype=jnp.bfloat16) -> dict:
    kz, ki, kf, ko, kr = jax.random.split(key, 5)
    return {
        "w_z": init_dense(kz, d_model, d_model, dtype),
        "w_i": init_dense(ki, d_model, d_model, jnp.float32),
        "w_f": init_dense(kf, d_model, d_model, jnp.float32),
        "w_o": init_dense(ko, d_model, d_model, jnp.float32),
        "w_out": init_dense(kr, d_model, d_model, dtype),
    }


def slstm(params: dict, x: jax.Array, *, state=None, mode: str = "train") -> tuple[jax.Array, tuple | None]:
    """sLSTM: sequential scalar-memory LSTM with exponential gating.

    State (c, n, m): cell, normalizer, log-max stabilizer — each (B, D).
    """
    B, S, D = x.shape
    z = jnp.tanh(jnp.einsum("bsd,de->bse", x, params["w_z"]).astype(jnp.float32))
    ig = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_i"])
    fg = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_f"])
    og = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["w_o"]))

    def step(carry, t):
        c, n, m = carry
        zt, it, ft, ot = t
        m_new = jnp.maximum(ft + m, it)           # log-space stabilization
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h = ot * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new), h

    if mode == "decode":
        assert state is not None and S == 1
        carry, h = step(state, (z[:, 0], ig[:, 0], fg[:, 0], og[:, 0]))
        y = h[:, None]
        new_state = carry
    else:
        init = (
            jnp.zeros((B, D), jnp.float32),
            jnp.zeros((B, D), jnp.float32),
            jnp.full((B, D), -1e30, jnp.float32),
        )
        carry, hs = jax.lax.scan(
            step, init, (z.swapaxes(0, 1), ig.swapaxes(0, 1), fg.swapaxes(0, 1), og.swapaxes(0, 1))
        )
        y = hs.swapaxes(0, 1)
        new_state = carry if mode == "prefill" else None
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["w_out"]), new_state


def mamba_state_shape(d_model: int, *, expand: int = 2, head_dim: int = 64, d_state: int = 128, batch: int = 1):
    d_inner = expand * d_model
    h = d_inner // head_dim
    return (batch, h, d_state, head_dim)


def mlstm_state_shape(d_model: int, *, expand: int = 2, head_dim: int = 64, batch: int = 1):
    d_inner = expand * d_model
    h = d_inner // head_dim
    return (batch, h, head_dim, head_dim)
