"""Full language model: embed -> scanned block groups -> norm -> logits.

Covers all assigned families behind one interface:
  * decoder-only dense / MoE / SSM / hybrid,
  * enc-dec (whisper): encoder stack over stubbed frame embeddings, decoder
    pattern interleaves self- and cross-attention,
  * VLM (llama-3.2-vision): cross-attention layers against stubbed patch
    embeddings.

Entry points:
  init_lm(key, cfg, dtype)                      -> params
  forward(params, cfg, tokens, ...)             -> logits           (train)
  loss_fn(params, cfg, batch)                   -> (loss, metrics)
  prefill(params, cfg, tokens, caches, ...)     -> (logits, caches)
  decode_step(params, cfg, token, caches, pos)  -> (logits, caches)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.blocks import apply_block, init_block, init_block_cache
from repro.models.layers import embed, init_dense, init_embedding, rms_norm, unembed


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_lm(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg)
    k_emb, k_groups, k_enc, k_ctx = jax.random.split(key, 4)

    def init_group(gkey):
        keys = jax.random.split(gkey, len(cfg.pattern))
        return {
            f"b{i}": init_block(keys[i], cfg, mixer, ffn, dtype)
            for i, (mixer, ffn) in enumerate(cfg.pattern)
        }

    params: dict[str, Any] = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "groups": jax.vmap(init_group)(jax.random.split(k_groups, cfg.n_groups)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.is_encdec:
        def init_enc_layer(lkey):
            return init_block(lkey, cfg, "attn_nc", "dense", dtype)

        params["encoder"] = {
            "layers": jax.vmap(init_enc_layer)(jax.random.split(k_enc, cfg.encoder_layers)),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


# --------------------------------------------------------------------------
# layer-stack execution
# --------------------------------------------------------------------------

def _run_stack(params, cfg: ModelConfig, x, positions, context, caches, mode, interpret):
    pattern = cfg.pattern

    def group_fn(x, gparams, gcaches):
        new_caches = []
        for i, (mixer, ffn) in enumerate(pattern):
            cache_i = () if gcaches is None else gcaches[i]
            x, nc = apply_block(
                gparams[f"b{i}"], x, cfg=cfg, mixer=mixer, ffn=ffn,
                positions=positions, context=context, cache=cache_i,
                mode=mode, interpret=interpret,
            )
            new_caches.append(nc)
        return x, tuple(new_caches)

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn)

    if caches is None:
        def body(x, gp):
            x, _ = group_fn(x, gp, None)
            return x, None

        x, _ = jax.lax.scan(body, x, params["groups"])
        return x, None

    def body(x, xs):
        gp, gc = xs
        return group_fn(x, gp, gc)

    x, new_caches = jax.lax.scan(body, x, (params["groups"], caches))
    return x, new_caches


def _run_encoder(params, cfg: ModelConfig, frames, interpret):
    """Encoder over precomputed frame embeddings (conv frontend stub)."""
    enc = params["encoder"]
    pos = jnp.arange(frames.shape[1])[None, :]

    def body(x, lp):
        x, _ = apply_block(
            lp, x, cfg=cfg, mixer="attn_nc", ffn="dense", positions=pos,
            context=None, cache=(), mode="train", interpret=interpret,
        )
        return x, None

    x, _ = jax.lax.scan(body, frames, enc["layers"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jax.Array,                    # (B, S) int32
    *,
    context: jax.Array | None = None,     # (B, Nctx, D) patch/frame embeddings
    mode: str = "train",
    caches=None,
    pos0: jax.Array | int = 0,
    interpret: bool = True,
) -> tuple[jax.Array, Any]:
    b, s = tokens.shape
    x = constrain(embed(tokens, params["embed"]), "batch", None, None)
    if cfg.is_encdec:
        assert context is not None, "enc-dec model needs frame embeddings"
        context = _run_encoder(params, cfg, context.astype(x.dtype), interpret)
    p0 = jnp.asarray(pos0)
    p0 = p0[:, None] if p0.ndim == 1 else p0  # per-slot decode positions (B,)
    positions = p0 + jnp.arange(s)[None, :]
    x, new_caches = _run_stack(params, cfg, x, positions, context, caches, mode, interpret)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(unembed(x, params["embed"]), "batch", None, "model")
    return logits, new_caches


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def loss_fn(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, dict]:
    """Next-token cross entropy.  batch: tokens (B,S), labels (B,S),
    optional loss_mask (B,S), optional example weights w (B,) (MILO WRE),
    optional context (B,Nctx,D)."""
    logits, _ = forward(
        params, cfg, batch["tokens"], context=batch.get("context"),
        mode="train", interpret=interpret,
    )
    labels = batch["labels"]
    # Vocab-sharding-friendly CE: the vocab axis of ``logits`` is sharded over
    # the model mesh axis (tied to the embedding table), so we avoid any
    # gather along vocab.  logsumexp reduces over the sharded axis (GSPMD
    # inserts a tiny (B,S) all-reduce) and the label logit comes from a
    # one-hot contraction (psum) instead of take_along_axis (all-gather).
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = (logits - m).astype(jnp.float32)  # upcast per element at use
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0].astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot,
                             preferred_element_type=jnp.float32)
    nll = lse - label_logit                                               # (B,S)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    w = batch.get("weights")
    if w is not None:
        mask = mask * w[:, None]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, cache_len: int):
    """Stacked (over groups) cache pytree matching the pattern."""
    dtype = _dtype(cfg)

    def one_group():
        return tuple(
            init_block_cache(cfg, mixer, batch, cache_len, dtype)
            for mixer, _ in cfg.pattern
        )

    g = one_group()
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape).copy(), g)


def prefill(params, cfg: ModelConfig, tokens, caches, *, context=None, interpret=True):
    return forward(params, cfg, tokens, context=context, mode="prefill",
                   caches=caches, interpret=interpret)


def decode_step(params, cfg: ModelConfig, token, caches, pos, *, context=None, interpret=True):
    """One decode step.  token: (B, 1); pos: scalar int32 current position."""
    logits, caches = forward(
        params, cfg, token, context=context, mode="decode", caches=caches,
        pos0=pos, interpret=interpret,
    )
    return logits, caches
