"""GQA attention with three interchangeable inner implementations.

  * ``naive``   — materialized scores; smoke tests and short sequences.
  * ``chunked`` — pure-JAX flash (lax.scan over KV blocks, online softmax);
                  the dry-run path: O(S·block) memory, lowers on any backend.
  * ``pallas``  — ``repro.kernels.flash_attention`` (TPU target; interpret=True
                  for CPU validation).

Modes: ``train`` (full causal self-attn), ``prefill`` (train + returns KV to
cache), ``decode`` (1 new token vs a fixed-size cache, in-place cache update).
KV heads are *not* repeated in HBM on the chunked/pallas paths.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, init_dense


class KVCache(NamedTuple):
    k: jax.Array        # (B, S_max, Hkv, D)
    v: jax.Array        # (B, S_max, Hkv, D)
    length: jax.Array   # () or (B,) int32 — valid positions (per-slot OK)


def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype=jnp.bfloat16) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, dtype).reshape(d_model, n_heads, head_dim),
        "wk": init_dense(kk, d_model, n_kv * head_dim, dtype).reshape(d_model, n_kv, head_dim),
        "wv": init_dense(kv, d_model, n_kv * head_dim, dtype).reshape(d_model, n_kv, head_dim),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype).reshape(n_heads, head_dim, d_model),
    }


def _naive_attn(q, k, v, *, causal: bool, k_len: jax.Array | None = None) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D) -> (B,Sq,H,D).

    ``k_len`` may be () or (B,) — per-slot cache lengths for batched decode.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits *= 1.0 / (d ** 0.5)
    kj = jnp.arange(sk)
    mask = jnp.ones((1, 1, 1, sq, sk), bool)
    if causal:
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        mask = mask & (kj[None, :] <= qi)[None, None, None]
    if k_len is not None:
        kl = jnp.asarray(k_len)
        if kl.ndim == 0:
            mask = mask & (kj < kl)[None, None, None, None, :]
        else:  # (B,)
            mask = mask & (kj[None, :] < kl[:, None])[:, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _chunked_attn(q, k, v, *, causal: bool, block: int = 512, k_len=None,
                  bf16_operands: bool = True) -> jax.Array:
    """Online-softmax flash attention in pure JAX (scan over KV blocks).

    §Perf: einsum *operands* stay in bf16 (halving the HBM traffic of the
    dominant attention reads) while accumulation is forced to f32 via
    ``preferred_element_type`` — the same contract the MXU gives the Pallas
    kernel.  Running (m, l, acc) statistics remain f32.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    pad = (-sk) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // block
    kb = k.reshape(b, nkb, block, hkv, d).swapaxes(0, 1)  # (nkb, B, blk, Hkv, D)
    vb = v.reshape(b, nkb, block, hkv, d).swapaxes(0, 1)
    op_dtype = q.dtype if (bf16_operands and q.dtype == jnp.bfloat16) else jnp.float32
    qg = (q / jnp.asarray(d ** 0.5, q.dtype)).reshape(b, sq, hkv, group, d).astype(op_dtype)
    offset = sk - sq
    valid_len = sk if k_len is None else k_len

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, ki = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk.astype(op_dtype),
                       preferred_element_type=jnp.float32)
        cols = ki * block + jnp.arange(block)
        vl = jnp.asarray(valid_len)
        if vl.ndim == 0:
            msk = (cols < vl)[None, None, None, None, :]
        else:  # per-slot (B,)
            msk = (cols[None, :] < vl[:, None])[:, None, None, None, :]
        if causal:
            rows = jnp.arange(sq)[:, None] + offset
            msk = msk & (cols[None, :] <= rows)[None, None, None]
        s = jnp.where(msk, s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(op_dtype), vblk.astype(op_dtype),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    # §Perf iter-2: remat each KV-block step — without this, backward saves
    # the (nkb, B, Hkv, G, Sq, block) score/prob tensors stacked across the
    # scan (~35% of all HBM traffic at 4k train); recomputing them per block
    # trades ~15% extra attention FLOPs (far from the compute roof).
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0), (kb, vb, jnp.arange(nkb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def _pallas_attn(q, k, v, *, causal: bool, interpret: bool) -> jax.Array:
    from repro.kernels.flash_attention import ops as fa_ops

    out = fa_ops.flash_attention(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), causal=causal, interpret=interpret
    )
    return out.swapaxes(1, 2)


def attention(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    impl: str = "chunked",
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    kv_x: jax.Array | None = None,  # cross-attention keys/values source
    cache: KVCache | None = None,
    mode: str = "train",            # train | prefill | decode
    interpret: bool = True,
) -> tuple[jax.Array, KVCache | None]:
    """Full attention sublayer: qkv proj -> rope -> attn -> out proj.

    Returns (output, new_cache).  new_cache is None in ``train`` mode.
    """
    src = x if kv_x is None else kv_x
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wq"]), "batch", None, "model", None)
    k = constrain(jnp.einsum("bsd,dhk->bshk", src, params["wk"]), "batch", None, "model", None)
    v = constrain(jnp.einsum("bsd,dhk->bshk", src, params["wv"]), "batch", None, "model", None)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        if kv_x is None:  # self-attention: keys rotate with their own positions
            kv_pos = positions if mode != "decode" else positions
            k = apply_rope(k, kv_pos, rope_theta)

    new_cache = None
    k_len = None
    if mode == "decode":
        assert cache is not None
        # write the new kv at position cache.length (B,1,Hkv,D); per-slot
        # lengths (B,) use a vmapped per-row update (batched serving)
        idx = jnp.asarray(cache.length)
        if idx.ndim == 0:
            k_all = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, idx, 0, 0))
            v_all = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, idx, 0, 0))
        else:
            upd = jax.vmap(lambda cb, nb, ib: jax.lax.dynamic_update_slice(cb, nb, (ib, 0, 0)))
            k_all = upd(cache.k, k.astype(cache.k.dtype), idx)
            v_all = upd(cache.v, v.astype(cache.v.dtype), idx)
        new_cache = KVCache(k_all, v_all, cache.length + x.shape[1])
        k, v = k_all, v_all
        k_len = idx + x.shape[1]
        causal = False  # masking handled by k_len (decode attends all past)
    elif mode == "prefill":
        new_cache = KVCache(k, v, jnp.full((x.shape[0],), x.shape[1], jnp.int32))

    if impl == "naive" or (mode == "decode" and impl != "chunked"):
        out = _naive_attn(q, k, v, causal=causal, k_len=k_len)
    elif impl == "chunked":
        out = _chunked_attn(q, k, v, causal=causal, k_len=k_len)
    elif impl == "pallas":
        out = _pallas_attn(q, k, v, causal=causal, interpret=interpret)
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    # §Perf iter-6: pin the projection output to the storage dtype — XLA
    # otherwise hoists the bf16 convert past the dot (f32 dot result), and the
    # TP psum of this tensor is the dominant collective; bf16 halves it.
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"],
                   preferred_element_type=x.dtype)
    return y, new_cache
