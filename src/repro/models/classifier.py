"""Small MLP classifier shared by the session facade and the benchmarks.

One definition of the downstream model and its training math, so the
benchmark numbers and ``MiloSession.train`` can never diverge: 3-layer ReLU
MLP, per-sample weighted cross entropy ``sum(w * nll) / max(sum(w), 1)``
(uniform weights reduce to plain CE), accuracy, and the Nesterov-momentum
update.  Only the loop structure (epoch-based full-batch benchmark vs
in-jit scan with a traced cosine schedule) lives with the callers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense


def init_mlp(key, d_in: int, n_classes: int, hidden: int = 64) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": init_dense(k1, d_in, hidden, jnp.float32), "b1": jnp.zeros((hidden,)),
        "w2": init_dense(k2, hidden, hidden, jnp.float32), "b2": jnp.zeros((hidden,)),
        "w3": init_dense(k3, hidden, n_classes, jnp.float32), "b3": jnp.zeros((n_classes,)),
    }


def mlp_logits(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(dense(x, p["w1"]) + p["b1"])
    h = jax.nn.relu(dense(h, p["w2"]) + p["b2"])
    return dense(h, p["w3"]) + p["b3"]


def weighted_nll(p: dict, x: jax.Array, y: jax.Array, w: jax.Array) -> jax.Array:
    """Plan-weighted cross entropy (the loss every selection plan feeds)."""
    lp = jax.nn.log_softmax(mlp_logits(p, x))
    nll = -jnp.take_along_axis(lp, y[:, None], 1)[:, 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


@jax.jit
def accuracy(p: dict, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean(jnp.argmax(mlp_logits(p, x), -1) == y)


def nesterov_update(params: dict, mom: dict, grads: dict, lr, beta: float = 0.9):
    """One Nesterov-momentum SGD step; returns (params, mom)."""
    mom = jax.tree.map(lambda m, g: beta * m + g, mom, grads)
    params = jax.tree.map(
        lambda p, m, g: p - lr * (g + beta * m), params, mom, grads
    )
    return params, mom
