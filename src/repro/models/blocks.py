"""Composable transformer/SSM blocks and the scanned layer stack.

A *block* = pre-norm mixer (+ residual) then pre-norm FFN (+ residual).
A *group* = the config's pattern of blocks; the model runs ``n_groups``
identical-structure groups via ``lax.scan`` over stacked params (HLO size
stays O(pattern), crucial for the 100-layer dry-runs).

Caches: every block owns a cache slot (possibly ()); a group's cache is a
tuple aligned with the pattern, stacked over groups like the params, so
prefill/decode thread caches through the same scan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.attention import KVCache, attention, init_attention
from repro.models.layers import init_mlp, mlp, rms_norm
from repro.models.moe import init_moe, moe
from repro.models.ssm import (
    init_mamba,
    init_mlstm,
    init_slstm,
    mamba,
    mamba_state_shape,
    mlstm,
    mlstm_state_shape,
    slstm,
)

Cache = Any  # per-block cache pytree ( () if stateless )


def init_block(key: jax.Array, cfg: ModelConfig, mixer: str, ffn: str, dtype) -> dict:
    km, kf, kn1, kn2 = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer in ("attn", "attn_nc", "xattn"):
        p["mixer"] = init_attention(
            km, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
        )
    elif mixer == "mamba":
        p["mixer"] = init_mamba(
            km, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
            d_state=cfg.ssm_state_dim, dtype=dtype,
        )
    elif mixer == "mlstm":
        p["mixer"] = init_mlstm(
            km, cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim, dtype=dtype
        )
    elif mixer == "slstm":
        p["mixer"] = init_slstm(km, cfg.d_model, dtype=dtype)
    else:
        raise ValueError(mixer)
    if ffn == "dense":
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ffn"] = init_mlp(kf, cfg.d_model, cfg.d_ff, dtype)
    elif ffn == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ffn"] = init_moe(kf, cfg.d_model, cfg.d_ff, cfg.num_experts, dtype)
    return p


def init_block_cache(cfg: ModelConfig, mixer: str, batch: int, cache_len: int, dtype) -> Cache:
    """Zeroed cache for one block (length 0)."""
    if mixer == "attn":
        shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), jnp.zeros((batch,), jnp.int32))
    if mixer == "mamba":
        return jnp.zeros(
            mamba_state_shape(cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                              d_state=cfg.ssm_state_dim, batch=batch), jnp.float32)
    if mixer == "mlstm":
        return jnp.zeros(
            mlstm_state_shape(cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                              batch=batch), jnp.float32)
    if mixer == "slstm":
        z = jnp.zeros((batch, cfg.d_model), jnp.float32)
        return (z, z, jnp.full((batch, cfg.d_model), -1e30, jnp.float32))
    return ()  # xattn recomputes K/V from the (fixed) context; attn_nc stateless


def apply_block(
    bparams: dict,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    mixer: str,
    ffn: str,
    positions: jax.Array,
    context: jax.Array | None,
    cache: Cache,
    mode: str,
    interpret: bool = True,
) -> tuple[jax.Array, Cache]:
    h = rms_norm(x, bparams["norm1"], cfg.norm_eps)
    new_cache: Cache = ()
    if mixer in ("attn", "attn_nc", "xattn"):
        is_cross = mixer == "xattn"
        attn_mode = mode if (mixer == "attn") else "train"  # cross/enc: stateless
        y, kvc = attention(
            bparams["mixer"], h, positions,
            causal=(mixer == "attn"),
            impl=cfg.attention_impl,
            rope_theta=cfg.rope_theta,
            use_rope=cfg.use_rope and not is_cross,
            kv_x=context if is_cross else None,
            cache=cache if (mixer == "attn" and mode == "decode") else None,
            mode=attn_mode,
            interpret=interpret,
        )
        if mixer == "attn" and mode in ("prefill", "decode"):
            new_cache = kvc if mode == "decode" else _fit_cache(kvc, cache)
    elif mixer == "mamba":
        y, st = mamba(bparams["mixer"], h, chunk=cfg.ssm_chunk,
                      state=cache if mode == "decode" else None, mode=mode,
                      impl=cfg.ssm_impl if mode != "decode" else "chunked",
                      interpret=interpret)
        if mode in ("prefill", "decode"):
            new_cache = st
    elif mixer == "mlstm":
        y, st = mlstm(bparams["mixer"], h, chunk=cfg.ssm_chunk,
                      state=cache if mode == "decode" else None, mode=mode)
        if mode in ("prefill", "decode"):
            new_cache = st
    elif mixer == "slstm":
        y, st = slstm(bparams["mixer"], h, state=cache if mode == "decode" else None, mode=mode)
        if mode in ("prefill", "decode"):
            new_cache = st
    else:
        raise ValueError(mixer)
    x = constrain(x + y, "batch", None, None)

    if ffn in ("dense", "moe"):
        h = rms_norm(x, bparams["norm2"], cfg.norm_eps)
        if ffn == "dense":
            x = constrain(x + mlp(bparams["ffn"], h), "batch", None, None)
        else:
            x = x + moe(
                bparams["ffn"], h,
                top_k=cfg.experts_per_token,
                capacity_factor=cfg.capacity_factor,
                group_size=cfg.moe_group_size,
                dropless=(mode == "decode"),  # tiny token count: exact routing
            )
            x = constrain(x, "batch", None, None)
    return x, new_cache


def _fit_cache(kvc: KVCache, template: Cache) -> KVCache:
    """Pad prefill K/V out to the template's max cache length."""
    if not isinstance(template, KVCache):
        return kvc
    max_len = template.k.shape[1]
    cur = kvc.k.shape[1]
    if cur == max_len:
        return KVCache(kvc.k.astype(template.k.dtype), kvc.v.astype(template.v.dtype), kvc.length)
    pad = ((0, 0), (0, max_len - cur), (0, 0), (0, 0))
    return KVCache(
        jnp.pad(kvc.k.astype(template.k.dtype), pad),
        jnp.pad(kvc.v.astype(template.v.dtype), pad),
        kvc.length,
    )


