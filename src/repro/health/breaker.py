"""Per-key circuit breaker for deterministically-failing builds.

PR 7's retry layer assumes failures are *transient*: it re-runs the build
with deterministic backoff.  When the failure is deterministic (poisoned
features, an impossible config), every retry re-pays the full build cost
and every queued request behind it does too.  The breaker records
consecutive failures per artifact key and, once ``threshold`` is reached,
fails subsequent attempts fast with :class:`CircuitOpenError` until
``cooldown`` seconds pass — after which exactly one probe request is let
through (half-open): success closes the circuit, failure re-opens it.

The clock is injectable (``clock=time.monotonic`` by default) so state
transitions are exactly testable without sleeping.  All methods are
thread-safe; keys are anything hashable (``MiloServer`` uses its artifact
store keys).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Hashable


class CircuitOpenError(RuntimeError):
    """Fast-fail: the circuit for this key is open.

    Deliberately *not* transient (no ``.transient`` attribute): the retry
    layer must not retry through an open breaker — that would defeat it.
    """


class _KeyState:
    __slots__ = ("failures", "opened_at", "probing")

    def __init__(self):
        self.failures = 0
        self.opened_at: float | None = None
        self.probing = False


class CircuitBreaker:
    """Keyed closed → open → half-open breaker over consecutive failures."""

    def __init__(self, *, threshold: int = 3, cooldown: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        if threshold < 1:
            raise ValueError("threshold must be >= 1")
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self.clock = clock
        self._lock = threading.Lock()
        self._keys: dict[Hashable, _KeyState] = {}

    def _state(self, key: Hashable) -> _KeyState:
        st = self._keys.get(key)
        if st is None:
            st = self._keys[key] = _KeyState()
        return st

    def check(self, key: Hashable) -> None:
        """Gate an attempt: no-op when closed, raises when open.

        When the cooldown has elapsed the first caller through becomes the
        half-open probe; concurrent callers still fail fast until the
        probe reports success or failure.
        """
        with self._lock:
            st = self._keys.get(key)
            if st is None or st.opened_at is None:
                return
            elapsed = self.clock() - st.opened_at
            if elapsed < self.cooldown:
                raise CircuitOpenError(
                    f"circuit open for {key!r}: {st.failures} consecutive "
                    f"build failures; fast-failing for another "
                    f"{self.cooldown - elapsed:.1f}s")
            if st.probing:
                raise CircuitOpenError(
                    f"circuit half-open for {key!r}: probe already in flight")
            st.probing = True

    def record_success(self, key: Hashable) -> None:
        with self._lock:
            self._keys.pop(key, None)

    def record_failure(self, key: Hashable) -> None:
        with self._lock:
            st = self._state(key)
            st.failures += 1
            st.probing = False
            if st.failures >= self.threshold:
                st.opened_at = self.clock()   # (re-)open, restart cooldown

    def state(self, key: Hashable) -> str:
        """'closed' | 'open' | 'half_open' for diagnostics."""
        with self._lock:
            st = self._keys.get(key)
            if st is None or st.opened_at is None:
                return "closed"
            if self.clock() - st.opened_at < self.cooldown:
                return "open"
            return "half_open"

    def snapshot(self) -> dict[str, dict[str, Any]]:
        """JSON-safe per-key view for ``health()`` endpoints."""
        with self._lock:
            keys = list(self._keys.items())
        out: dict[str, dict[str, Any]] = {}
        for key, st in keys:
            out[str(key)] = {
                "state": self.state(key),
                "failures": st.failures,
            }
        return out
