"""Numerical-health guardrails for the selection→training→serving stack.

PR 7 made the stack survive *process* death; this layer makes it survive
*semantic* failure — the inputs and intermediate states that are wrong
rather than missing:

* :mod:`repro.health.firewall` — ``validate_features`` screens the ground
  set before any selection math (non-finite rows, zero-norm embeddings,
  duplicate/constant features, degenerate class geometry) and produces a
  :class:`DataHealthReport` that is stamped into ``MiloMetadata``
  provenance.  Policies: ``raise`` / ``repair`` / ``quarantine``.
* :mod:`repro.health.guard` — a divergence guard fused inside the training
  step (non-finite / loss-spike detection with zero extra host syncs on
  the healthy path) and the :class:`GuardPolicy` describing what to do
  about it: ``skip_step`` / ``rollback`` / ``abort``.
* :mod:`repro.health.fallback` — degraded-mode selection: a declared
  selector chain (e.g. ``milo`` → ``adaptive_random``) walked on
  degenerate math, with every hop recorded in plan provenance.
* :mod:`repro.health.breaker` — a per-key circuit breaker so a
  deterministically-failing artifact build fails fast instead of being
  re-hammered by the retry layer.

Everything here is deterministic: repairs are pure functions of the row
index, guard decisions are pure functions of the metrics, fallback chains
are declared up front, and the breaker clock is injectable.
"""
from repro.health.breaker import CircuitBreaker, CircuitOpenError
from repro.health.fallback import (
    FallbackExhaustedError,
    FallbackSelector,
    SelectionDegenerateError,
)
from repro.health.firewall import (
    FIREWALL_POLICIES,
    DataHealthError,
    DataHealthReport,
    validate_features,
)
from repro.health.guard import (
    GUARD_KEY,
    DivergenceError,
    GuardPolicy,
    guarded_step,
)

__all__ = [
    "CircuitBreaker",
    "CircuitOpenError",
    "DataHealthError",
    "DataHealthReport",
    "DivergenceError",
    "FIREWALL_POLICIES",
    "FallbackExhaustedError",
    "FallbackSelector",
    "GUARD_KEY",
    "GuardPolicy",
    "SelectionDegenerateError",
    "guarded_step",
    "validate_features",
]
