"""Degraded-mode selection: walk a declared fallback chain on failure.

MILO's selectors assume well-conditioned geometry the papers never had to
defend: a WRE draw needs ``k`` nonzero-probability rows, greedy gains need
non-degenerate similarity structure.  When that fails today the exception
kills the whole training run — even though a perfectly serviceable
degraded answer (``adaptive_random`` over the same budget) exists.

:class:`FallbackSelector` wraps an ordered chain of ``(name, factory)``
pairs implementing the ``Selector`` protocol.  Each ``plan(epoch)`` call
uses the first selector in the chain that (a) constructs, (b) returns a
plan without raising degenerate-math errors, and (c) returns finite
weights.  Every hop is recorded in ``events`` and stamped into the
returned plan's provenance (``fallback_from`` / ``fallback_selector``) so
a degraded run is auditable, never silent.

Only *degenerate-math* failures trigger fallback (``ValueError``,
``FloatingPointError``, ``ZeroDivisionError``, and the explicit
:class:`SelectionDegenerateError`).  ``MetadataMismatchError`` is excluded
even though it subclasses ``ValueError``: loading the wrong artifact is a
configuration bug that must surface, not a data condition to degrade
around.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.metadata import MetadataMismatchError

#: Exception types treated as "the math is degenerate, try the next tier".
DEGENERATE_EXCS = (ValueError, FloatingPointError, ZeroDivisionError)


class SelectionDegenerateError(ValueError):
    """Explicit signal that a selector hit degenerate geometry."""


class FallbackExhaustedError(RuntimeError):
    """Every selector in the fallback chain failed."""


class FallbackSelector:
    """``Selector`` that degrades down a declared chain instead of crashing.

    ``chain`` is an ordered sequence of ``(name, factory)`` pairs; each
    factory is a zero-arg callable returning a built selector.  Factories
    run lazily — the fallback tiers cost nothing unless reached.  Once the
    chain advances past a selector it never goes back (a degenerate
    primary stays degenerate for the run), which also keeps repeat runs
    bit-identical: the same failures happen at the same points.
    """

    def __init__(self, chain: Sequence[tuple[str, Callable[[], Any]]]):
        if not chain:
            raise ValueError("fallback chain must name at least one selector")
        self.chain = list(chain)
        self.events: list[dict[str, Any]] = []
        self._pos = 0
        self._sel: Any = None

    @property
    def active_name(self) -> str:
        return self.chain[self._pos][0]

    def _advance(self, stage: str, exc: BaseException) -> None:
        self.events.append({
            "selector": self.chain[self._pos][0],
            "stage": stage,
            "error": repr(exc),
        })
        self._pos += 1
        self._sel = None
        if self._pos >= len(self.chain):
            raise FallbackExhaustedError(
                "every selector in the fallback chain failed: "
                + "; ".join(f"{e['selector']}({e['stage']}): {e['error']}"
                            for e in self.events)) from exc

    def _current(self) -> Any:
        while self._sel is None:
            _, factory = self.chain[self._pos]
            try:
                self._sel = factory()
            except MetadataMismatchError:
                raise                      # config bug, never degrade around
            except DEGENERATE_EXCS as e:
                self._advance("build", e)
        return self._sel

    def plan(self, epoch: int):
        while True:
            sel = self._current()
            try:
                plan = sel.plan(epoch)
            except MetadataMismatchError:
                raise
            except DEGENERATE_EXCS as e:
                self._advance("plan", e)
                continue
            if not np.isfinite(np.asarray(plan.weights)).all():
                self._advance("plan", SelectionDegenerateError(
                    "plan weights are non-finite"))
                continue
            if self._pos > 0:
                plan = dataclasses.replace(plan, provenance={
                    **dict(plan.provenance),
                    "fallback_from": self.chain[0][0],
                    "fallback_selector": self.chain[self._pos][0],
                    "fallback_events": [dict(e) for e in self.events],
                })
            return plan

    def reset_cache(self) -> None:
        sel = self._sel
        if sel is not None and hasattr(sel, "reset_cache"):
            sel.reset_cache()
