"""Training divergence guard: fused non-finite / loss-spike detection.

The guard wraps a ``(state, batch) -> (state, metrics)`` train step so the
update is *conditionally applied on device*: when the step's loss is
non-finite (or exceeds a declared spike threshold) every state leaf keeps
its pre-step value and only the step counter advances.  Because the check
is a ``jnp.where`` over the already-computed update, it fuses into the
``lax.scan`` superstep body and costs **zero extra host syncs** on the
healthy path — the flag rides the metrics stack that training already
copies out at log boundaries.

The *in-scan* behaviour is always skip-semantics (a NaN update must never
be applied, or it poisons every subsequent step in the segment); the
:class:`GuardPolicy` ``action`` says what the host does when it observes
the flag:

``skip_step``
    Nothing more — the poisoned update was already a deterministic
    zero-update; training continues.  Zero added host syncs.
``rollback``
    The Trainer restores ``latest_valid_step`` via the PR 7 checkpointer
    and replays the segment (re-seeded, so the retry is reproducible);
    flags at or before the rolled-back step are tolerated on replay so a
    deterministic NaN cannot re-trigger forever.  Costs one scalar
    device read per segment.
``abort``
    Raise :class:`DivergenceError` at the first flagged segment.

Step-counter semantics: skipping must still advance ``state.step``.  If
the counter were reverted too, the lr schedule would stall and any
counter-driven fault injector (``nan_at_step``) would re-fire on every
subsequent invocation — a livelock.  ``NamedTuple`` and dataclass states
with a ``step`` field get this automatically; other state containers keep
skip-semantics for every leaf (documented limitation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

#: Metrics key carrying the per-step flag: 1.0 = step was skipped.
GUARD_KEY = "guard_bad"

GUARD_ACTIONS = ("skip_step", "rollback", "abort")


class DivergenceError(RuntimeError):
    """Training diverged and the guard policy said not to continue."""


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Divergence-guard configuration (hashable: part of engine cache keys).

    ``max_loss`` adds an absolute loss-spike threshold on top of the
    always-on non-finite check; ``loss_key`` names the metric guarded;
    ``max_rollbacks`` caps checkpoint restores per ``fit`` before the
    Trainer gives up with :class:`DivergenceError`.
    """

    action: str = "skip_step"
    max_loss: float | None = None
    loss_key: str = "loss"
    max_rollbacks: int = 4

    def __post_init__(self):
        if self.action not in GUARD_ACTIONS:
            raise ValueError(
                f"guard action must be one of {GUARD_ACTIONS}, "
                f"got {self.action!r}")


def _advance_counter(safe, new_state):
    """Carry the new step counter onto the reverted state when possible."""
    if hasattr(safe, "step"):
        if hasattr(safe, "_replace"):                    # NamedTuple states
            return safe._replace(step=new_state.step)
        if dataclasses.is_dataclass(safe):
            return dataclasses.replace(safe, step=new_state.step)
    return safe


def guarded_step(step_fn, policy: GuardPolicy):
    """Wrap ``step_fn`` with the fused divergence check.

    Returns a step with the same signature whose metrics gain
    ``GUARD_KEY`` (0.0 healthy / 1.0 skipped).  Traceable: safe to call
    inside ``lax.scan`` bodies and under ``jax.jit``.
    """

    def step(state, batch):
        new_state, metrics = step_fn(state, batch)
        loss = metrics[policy.loss_key]
        ok = jnp.isfinite(loss)
        if policy.max_loss is not None:
            ok = jnp.logical_and(ok, loss <= policy.max_loss)
        safe = jax.tree.map(
            lambda new, old: jnp.where(ok, new, old), new_state, state)
        safe = _advance_counter(safe, new_state)
        out = dict(metrics)
        out[GUARD_KEY] = 1.0 - ok.astype(jnp.float32)
        return safe, out

    return step
