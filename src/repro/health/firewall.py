"""Input firewall: screen the selection ground set before any math runs.

MILO's economics make a bad artifact *amortized damage*: the metadata is
computed once and reused by every downstream training and tuning trial, so
a NaN row or a zero-norm embedding that slips into preprocessing poisons
every consumer.  The similarity kernels are silently tolerant — a zero-norm
row survives ``normalize_rows`` as an exact zero vector and then scores a
constant 0.5 against everything under the rescaled cosine, a phantom
mid-similarity that distorts facility-location gains without ever raising.

``validate_features`` runs host-side on the raw ground set and detects:

* **non-finite rows** — any NaN/inf entry;
* **zero-norm rows** — L2 norm <= eps (the rows ``normalize_rows`` would
  flatten; see :func:`repro.core.similarity.zero_norm_rows`), excluding
  rows already flagged non-finite;
* **duplicate rows** — byte-identical repeats of an earlier row
  (facility location gains collapse to zero between duplicates);
* **constant features** — columns with a single value (dead dimensions);
* **class geometry** — empty classes (label gaps), singleton classes, and
  over-budget classes whose proportional budget equals the class size
  (a ``k >= n_c`` request: selection degenerates to "take everything").

Row anomalies (non-finite + zero-norm) are *actionable* via the policy
knob; structural anomalies (duplicates, constants, class geometry) are
recorded in the report but never mutate data — the selection engines
handle them deterministically and the report is the paper trail.

Policies
--------
``raise``
    Refuse the ground set: raise :class:`DataHealthError` listing every
    anomaly class with counts and example indices.
``repair``
    Deterministic in-place treatment: non-finite entries become 0.0; rows
    that are still zero-norm afterwards become the unit basis vector
    ``e_{i mod d}`` (a pure function of the row index — two repair passes
    over the same data are bit-identical).
``quarantine``
    Leave the data untouched but mark the bad rows for exclusion from the
    ground set; callers (``MiloPreprocessor.preprocess``) drop them from
    selection and record the indices in artifact provenance.

The report's :meth:`DataHealthReport.to_dict` form is JSON-safe and sized
for artifact headers: anomaly index lists are truncated to
``MAX_RECORDED_INDICES`` examples (full counts always kept), except the
``repaired_rows`` / ``quarantined_rows`` lists, which are stored in full
because they change what the artifact *is*.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.core.partition import (
    PartitionStrategy,
    partition_by_class,
    proportional_budgets,
)

#: Accepted values for the ``policy`` knob (``None`` = report-only).
FIREWALL_POLICIES = ("raise", "repair", "quarantine")

#: Cap on per-anomaly example indices recorded in ``to_dict`` provenance.
MAX_RECORDED_INDICES = 32


class DataHealthError(ValueError):
    """The ground set failed validation under ``policy='raise'``."""


def _as_int_list(idx: Sequence[int] | np.ndarray) -> list[int]:
    return [int(i) for i in idx]


@dataclasses.dataclass
class DataHealthReport:
    """Structured outcome of one ``validate_features`` pass."""

    n_rows: int
    n_features: int
    policy: str | None
    eps: float
    nonfinite_rows: list[int] = dataclasses.field(default_factory=list)
    zero_norm_rows: list[int] = dataclasses.field(default_factory=list)
    duplicate_rows: list[int] = dataclasses.field(default_factory=list)
    constant_features: list[int] = dataclasses.field(default_factory=list)
    empty_classes: list[int] = dataclasses.field(default_factory=list)
    singleton_classes: list[int] = dataclasses.field(default_factory=list)
    overbudget_classes: list[int] = dataclasses.field(default_factory=list)
    repaired_rows: list[int] = dataclasses.field(default_factory=list)
    quarantined_rows: list[int] = dataclasses.field(default_factory=list)

    @property
    def bad_rows(self) -> list[int]:
        """Rows the policy acts on: non-finite union zero-norm, sorted."""
        return sorted(set(self.nonfinite_rows) | set(self.zero_norm_rows))

    @property
    def clean(self) -> bool:
        """True when no anomaly of any class was detected."""
        return not (
            self.nonfinite_rows or self.zero_norm_rows or self.duplicate_rows
            or self.constant_features or self.empty_classes
            or self.singleton_classes or self.overbudget_classes
        )

    def summary(self) -> str:
        parts = []
        for name in ("nonfinite_rows", "zero_norm_rows", "duplicate_rows",
                     "constant_features", "empty_classes", "singleton_classes",
                     "overbudget_classes"):
            vals = getattr(self, name)
            if vals:
                shown = vals[:MAX_RECORDED_INDICES]
                parts.append(f"{name}={len(vals)} (e.g. {shown})")
        if not parts:
            return f"clean ground set ({self.n_rows}x{self.n_features})"
        return (f"ground set {self.n_rows}x{self.n_features} failed health "
                f"checks: " + "; ".join(parts))

    def to_dict(self) -> dict[str, Any]:
        """JSON-safe provenance form (truncated examples + full counts)."""
        out: dict[str, Any] = {
            "n_rows": int(self.n_rows),
            "n_features": int(self.n_features),
            "policy": self.policy,
            "eps": float(self.eps),
            "clean": self.clean,
        }
        for name in ("nonfinite_rows", "zero_norm_rows", "duplicate_rows",
                     "constant_features", "empty_classes", "singleton_classes",
                     "overbudget_classes"):
            vals = getattr(self, name)
            out[name] = {
                "count": len(vals),
                "indices": _as_int_list(vals[:MAX_RECORDED_INDICES]),
            }
        # full lists: these define which rows the artifact was built from
        out["repaired_rows"] = _as_int_list(self.repaired_rows)
        out["quarantined_rows"] = _as_int_list(self.quarantined_rows)
        return out


def _duplicate_rows(feats: np.ndarray) -> list[int]:
    """Indices of rows byte-identical to an earlier row (later copy wins)."""
    seen: dict[bytes, int] = {}
    dups: list[int] = []
    for i in range(feats.shape[0]):
        key = feats[i].tobytes()
        if key in seen:
            dups.append(i)
        else:
            seen[key] = i
    return dups


def _class_geometry(
    labs: np.ndarray,
    m: int,
    subset_fraction: float | None,
    strategy: PartitionStrategy | None = None,
) -> tuple[list[int], list[int], list[int]]:
    """(empty, singleton, overbudget) class labels for the ground set.

    ``strategy`` makes the overbudget check mirror the decomposition the
    preprocessor will actually apply (block strategies can split a class
    into several partitions, changing which budgets saturate); the empty /
    singleton checks stay label-based — they describe the data, not the
    decomposition.  Partition labels deduplicate through the set: a class
    split into multiple saturated blocks is reported once.
    """
    if labs.size == 0:
        return [], [], []
    counts = np.bincount(labs, minlength=int(labs.max()) + 1)
    empty = _as_int_list(np.where(counts == 0)[0])
    singleton = _as_int_list(np.where(counts == 1)[0])
    overbudget: list[int] = []
    if subset_fraction is not None and m > 0:
        k = max(1, round(subset_fraction * m))
        parts = (partition_by_class(labs) if strategy is None
                 else strategy.partition(labs, m))
        budgets = proportional_budgets(parts, k)
        overbudget = sorted({int(p.label) for p, b in zip(parts, budgets)
                             if b >= len(p.indices)})
    return empty, singleton, overbudget


def validate_features(
    features: np.ndarray,
    labels: np.ndarray | None = None,
    *,
    policy: str | None = "raise",
    subset_fraction: float | None = None,
    eps: float = 1e-8,
    strategy: PartitionStrategy | None = None,
) -> tuple[np.ndarray, DataHealthReport]:
    """Screen a ground set; return ``(features_out, report)``.

    ``features_out`` is the input array untouched except under
    ``policy='repair'``, where a copy with deterministic row repairs is
    returned.  Under ``policy='quarantine'`` the report's
    ``quarantined_rows`` names the rows the caller must exclude; under
    ``policy='raise'`` any bad row raises :class:`DataHealthError`.
    ``policy=None`` only reports.
    """
    if policy is not None and policy not in FIREWALL_POLICIES:
        raise ValueError(
            f"firewall policy must be one of {FIREWALL_POLICIES} or None, "
            f"got {policy!r}")
    feats = np.asarray(features)
    if feats.ndim != 2:
        raise ValueError(f"features must be 2-D (rows x dims), got shape "
                         f"{feats.shape}")
    m, d = feats.shape

    finite = np.isfinite(feats)
    nonfinite = np.where(~finite.all(axis=1))[0]
    masked = np.where(finite, feats, 0.0)
    norms = np.linalg.norm(masked.astype(np.float64), axis=1)
    zero_norm = np.setdiff1d(np.where(norms <= eps)[0], nonfinite)

    report = DataHealthReport(
        n_rows=m, n_features=d, policy=policy, eps=eps,
        nonfinite_rows=_as_int_list(nonfinite),
        zero_norm_rows=_as_int_list(zero_norm),
        duplicate_rows=_duplicate_rows(feats),
        constant_features=(
            _as_int_list(np.where((feats == feats[0:1]).all(axis=0))[0])
            if m > 1 else []),
    )
    if labels is not None:
        labs = np.asarray(labels, np.int64).ravel()
        if labs.shape[0] != m:
            raise ValueError(f"labels length {labs.shape[0]} != rows {m}")
        empty, singleton, overbudget = _class_geometry(
            labs, m, subset_fraction, strategy)
        report.empty_classes = empty
        report.singleton_classes = singleton
        report.overbudget_classes = overbudget

    bad = report.bad_rows
    if policy == "raise" and bad:
        raise DataHealthError(report.summary())
    if policy == "repair" and bad:
        out = np.array(masked, dtype=feats.dtype, copy=True)
        still_zero = np.linalg.norm(
            out.astype(np.float64), axis=1) <= eps
        for i in bad:
            if still_zero[i]:
                out[i] = 0.0
                out[i, i % d] = 1.0   # e_{i mod d}: pure function of the row
        report.repaired_rows = list(bad)
        return out, report
    if policy == "quarantine" and bad:
        report.quarantined_rows = list(bad)
    return feats, report
