"""Pure-jnp oracle for the facility-location marginal-gain kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fl_gains_ref(K: jax.Array, c: jax.Array) -> jax.Array:
    """Facility-location marginal gains for every candidate column.

    gain(j | S) = sum_i max(c_i, K_ij) - sum_i c_i = sum_i relu(K_ij - c_i)

    Args:
      K: (n, n_cand) similarity columns (ground set x candidates).
      c: (n,) running max-similarity cache for the current selection S.

    Returns:
      (n_cand,) float32 gains.
    """
    K = K.astype(jnp.float32)
    c = c.astype(jnp.float32)
    return jnp.sum(jax.nn.relu(K - c[:, None]), axis=0)


def fl_gains_gram_free_ref(z: jax.Array, zc: jax.Array, c: jax.Array) -> jax.Array:
    """Gram-free facility-location gains: the similarity column is computed
    on the fly from row-normalized features instead of read from a
    materialized (n, n) Gram matrix.

        K_ij = 0.5 + 0.5 * <z_i, zc_j>        (rescaled cosine, paper Eq. 10)
        gain(j | S) = sum_i relu(K_ij - c_i)

    Args:
      z:  (n, d) row-normalized ground-set features.
      zc: (n_cand, d) row-normalized candidate features.
      c:  (n,) running max-similarity cache for the current selection S.

    Returns:
      (n_cand,) float32 gains.
    """
    z = z.astype(jnp.float32)
    zc = zc.astype(jnp.float32)
    c = c.astype(jnp.float32)
    sim = 0.5 + 0.5 * (z @ zc.T)
    return jnp.sum(jax.nn.relu(sim - c[:, None]), axis=0)


def fl_gains_gram_free_delta_ref(
    z: jax.Array, zc: jax.Array, c_old: jax.Array, c_new: jax.Array
) -> jax.Array:
    """Gram-free facility-location gain *delta* over a row subset.

    The lazy greedy engine's correction term: for each candidate ``j``,

        delta(j) = sum_i [relu(K_ij - c_new_i) - relu(K_ij - c_old_i)]

    summed over the given ground rows only (``z`` holds just the rows whose
    cover moved since the gains were cached).  Rows with ``c_old = c_new =
    +inf`` contribute exact zeros — the padding contract for the engine's
    fixed-size touched-rows buffer.  ``zc`` may be any candidate block, not
    only the full ground set — the sharded engine corrects each device's
    local (n/ndev)-candidate slice with the same touched rows.

    Args:
      z:     (b, d) row-normalized features of the touched ground rows.
      zc:    (n_cand, d) row-normalized candidate features.
      c_old: (b,) cover of the touched rows before the last pick.
      c_new: (b,) cover of the touched rows after the last pick.

    Returns:
      (n_cand,) float32 gain corrections (non-positive: cover only grows).
    """
    z = z.astype(jnp.float32)
    zc = zc.astype(jnp.float32)
    sim = 0.5 + 0.5 * (z @ zc.T)
    new = jax.nn.relu(sim - c_new.astype(jnp.float32)[:, None])
    old = jax.nn.relu(sim - c_old.astype(jnp.float32)[:, None])
    return jnp.sum(new - old, axis=0)
