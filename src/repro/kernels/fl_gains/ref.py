"""Pure-jnp oracle for the facility-location marginal-gain kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fl_gains_ref(K: jax.Array, c: jax.Array) -> jax.Array:
    """Facility-location marginal gains for every candidate column.

    gain(j | S) = sum_i max(c_i, K_ij) - sum_i c_i = sum_i relu(K_ij - c_i)

    Args:
      K: (n, n_cand) similarity columns (ground set x candidates).
      c: (n,) running max-similarity cache for the current selection S.

    Returns:
      (n_cand,) float32 gains.
    """
    K = K.astype(jnp.float32)
    c = c.astype(jnp.float32)
    return jnp.sum(jax.nn.relu(K - c[:, None]), axis=0)
