"""Pallas TPU kernels: facility-location greedy gains (the selection hot loop).

Two entry points:

``fl_gains_pallas`` — for a candidate block J and running cache c, computes
``g_j = Σ_i relu(K_ij - c_i)`` with the ground-set axis i as the innermost
(revisited-output) reduction axis, streaming (bi, bj) similarity tiles
HBM→VMEM.  This is the O(n²)-per-step inner loop of facility-location greedy;
blocking keeps each step's working set at

    4 * (bi*bj + bi + bj) bytes ≈ 1.05 MB  (bi=bj=512, fp32)

well inside VMEM, with MXU-friendly 128-aligned tiles (the relu-sum lowers to
VPU reductions; the tile shape choice matters for layout, not the MXU).

``fl_gains_gram_free_pallas`` — the gram-free variant: the (bi, bj) similarity
tile is never read from HBM but fused on the fly on the MXU from row-normalized
feature tiles, ``K_tile = 0.5 + 0.5 · z_tile @ zc_tileᵀ``.  The (n, n) Gram
matrix is never materialized anywhere: HBM holds only the (n, d) features and
the (n,) cover vector, so per-class selection memory drops from O(n²) to
O(n·d + n) while each grid step keeps a

    4 * (bi*d + bj*d + bi*bj + bi + bj) bytes ≈ 2.6 MB  (bi=bj=512, d=128)

working set in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fl_gains_kernel(k_ref, c_ref, out_ref):
    i = pl.program_id(1)  # reduction (ground-set) axis — innermost
    k_blk = k_ref[...].astype(jnp.float32)   # (bi, bj)
    c_blk = c_ref[...].astype(jnp.float32)   # (bi, 1)
    part = jnp.sum(jnp.maximum(k_blk - c_blk, 0.0), axis=0, keepdims=True)  # (1, bj)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def fl_gains_pallas(
    K: jax.Array,
    c: jax.Array,
    *,
    block_i: int = 512,
    block_j: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Gains for all candidate columns of K given max-cache c.

    Args:
      K: (n, n_cand); c: (n,).  n % block_i == 0, n_cand % block_j == 0.
    """
    n, n_cand = K.shape
    bi = min(block_i, n)
    bj = min(block_j, n_cand)
    if n % bi or n_cand % bj:
        raise ValueError(f"shape ({n},{n_cand}) not divisible by ({bi},{bj})")
    grid = (n_cand // bj, n // bi)
    out = pl.pallas_call(
        _fl_gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bj), lambda j, i: (i, j)),
            pl.BlockSpec((bi, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_cand), jnp.float32),
        interpret=interpret,
    )(K, c[:, None])
    return out[0]


def _fl_gains_gram_free_kernel(z_ref, zc_ref, c_ref, out_ref):
    i = pl.program_id(1)  # reduction (ground-set) axis — innermost
    z_blk = z_ref[...].astype(jnp.float32)    # (bi, d)
    zc_blk = zc_ref[...].astype(jnp.float32)  # (bj, d)
    c_blk = c_ref[...].astype(jnp.float32)    # (bi, 1)
    # Fuse the similarity tile on the MXU — the Gram matrix never exists.
    sim = 0.5 + 0.5 * jax.lax.dot_general(
        z_blk, zc_blk,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bi, bj)
    part = jnp.sum(jnp.maximum(sim - c_blk, 0.0), axis=0, keepdims=True)  # (1, bj)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


def _fl_gains_gram_free_delta_kernel(z_ref, zc_ref, co_ref, cn_ref, out_ref):
    i = pl.program_id(1)  # reduction (touched-rows) axis — innermost
    z_blk = z_ref[...].astype(jnp.float32)    # (bi, d)
    zc_blk = zc_ref[...].astype(jnp.float32)  # (bj, d)
    co_blk = co_ref[...].astype(jnp.float32)  # (bi, 1)
    cn_blk = cn_ref[...].astype(jnp.float32)  # (bi, 1)
    sim = 0.5 + 0.5 * jax.lax.dot_general(
        z_blk, zc_blk,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bi, bj)
    part = jnp.sum(
        jnp.maximum(sim - cn_blk, 0.0) - jnp.maximum(sim - co_blk, 0.0),
        axis=0, keepdims=True,
    )  # (1, bj)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = part

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def fl_gains_gram_free_delta_pallas(
    z: jax.Array,
    zc: jax.Array,
    c_old: jax.Array,
    c_new: jax.Array,
    *,
    block_i: int = 512,
    block_j: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused lazy-greedy gain correction: both relu terms of the delta share
    one on-the-fly similarity tile (see ``ref.fl_gains_gram_free_delta_ref``).

    The i (touched-rows) axis is the reduction axis, so the kernel is shard
    agnostic on the candidate side: the sharded lazy engine calls it with
    ``zc`` = the device-local candidate block and b unchanged.

    Args:
      z: (b, d) touched ground rows; zc: (n_cand, d); c_old/c_new: (b,).
      b % block_i == 0, n_cand % block_j == 0.
    """
    b, d = z.shape
    n_cand = zc.shape[0]
    bi = min(block_i, b)
    bj = min(block_j, n_cand)
    if b % bi or n_cand % bj:
        raise ValueError(f"shape ({b},{n_cand}) not divisible by ({bi},{bj})")
    grid = (n_cand // bj, b // bi)
    out = pl.pallas_call(
        _fl_gains_gram_free_delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bj, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bi, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((bi, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_cand), jnp.float32),
        interpret=interpret,
    )(z, zc, c_old[:, None], c_new[:, None])
    return out[0]


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def fl_gains_gram_free_pallas(
    z: jax.Array,
    zc: jax.Array,
    c: jax.Array,
    *,
    block_i: int = 512,
    block_j: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Gram-free gains for all candidate rows of ``zc`` given max-cache ``c``.

    Args:
      z: (n, d) row-normalized ground features; zc: (n_cand, d); c: (n,).
      n % block_i == 0, n_cand % block_j == 0.
    """
    n, d = z.shape
    n_cand = zc.shape[0]
    bi = min(block_i, n)
    bj = min(block_j, n_cand)
    if n % bi or n_cand % bj:
        raise ValueError(f"shape ({n},{n_cand}) not divisible by ({bi},{bj})")
    grid = (n_cand // bj, n // bi)
    out = pl.pallas_call(
        _fl_gains_gram_free_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bj, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bi, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bj), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_cand), jnp.float32),
        interpret=interpret,
    )(z, zc, c[:, None])
    return out[0]
