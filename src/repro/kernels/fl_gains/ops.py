"""Public dispatch for the facility-location gains kernels (pads + routes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fl_gains.fl_gains import (
    fl_gains_gram_free_delta_pallas,
    fl_gains_gram_free_pallas,
    fl_gains_pallas,
)
from repro.kernels.fl_gains.ref import (
    fl_gains_gram_free_delta_ref,
    fl_gains_gram_free_ref,
    fl_gains_ref,
)


def fl_gains(
    K: jax.Array,
    c: jax.Array,
    *,
    block_i: int = 512,
    block_j: int = 512,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Facility-location marginal gains; auto-pads to the block grid.

    Padding is exact: padded ground rows use c = +big so relu(K - c) = 0;
    padded candidate columns are sliced off the result.
    """
    if not use_pallas:
        return fl_gains_ref(K, c)
    n, n_cand = K.shape
    bi = min(block_i, max(8, n))
    bj = min(block_j, max(128, n_cand))
    pad_i = (-n) % bi
    pad_j = (-n_cand) % bj
    if pad_i or pad_j:
        K = jnp.pad(K, ((0, pad_i), (0, pad_j)))
        c = jnp.pad(c, (0, pad_i), constant_values=jnp.inf)
    out = fl_gains_pallas(K, c, block_i=bi, block_j=bj, interpret=interpret)
    return out[:n_cand]


def fl_gains_gram_free(
    z: jax.Array,
    zc: jax.Array,
    c: jax.Array,
    *,
    block_i: int = 512,
    block_j: int = 512,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Gram-free facility-location marginal gains; auto-pads to the block grid.

    Padding is exact: padded ground rows get c = +big so their on-the-fly
    similarity (0.5 against a zero feature row) can never clear the relu;
    padded candidate rows are sliced off the result; the feature dimension is
    zero-padded to a lane-aligned multiple of 128 (zeros do not change dot
    products).
    """
    if not use_pallas:
        return fl_gains_gram_free_ref(z, zc, c)
    n, d = z.shape
    n_cand = zc.shape[0]
    bi = min(block_i, max(8, n))
    bj = min(block_j, max(128, n_cand))
    pad_i = (-n) % bi
    pad_j = (-n_cand) % bj
    pad_d = (-d) % 128
    if pad_i or pad_d:
        z = jnp.pad(z, ((0, pad_i), (0, pad_d)))
        c = jnp.pad(c, (0, pad_i), constant_values=jnp.inf)
    if pad_j or pad_d:
        zc = jnp.pad(zc, ((0, pad_j), (0, pad_d)))
    out = fl_gains_gram_free_pallas(z, zc, c, block_i=bi, block_j=bj,
                                    interpret=interpret)
    return out[:n_cand]


def fl_gains_gram_free_delta(
    z: jax.Array,
    zc: jax.Array,
    c_old: jax.Array,
    c_new: jax.Array,
    *,
    block_i: int = 512,
    block_j: int = 512,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Lazy-greedy gain correction over a touched-row subset; auto-pads.

    Padding is exact: padded touched rows get c_old = c_new = +big so both
    relu terms vanish identically; padded candidate rows are sliced off; the
    feature dimension is zero-padded to a lane-aligned multiple of 128.

    ``zc`` need not be the full ground set: the sharded lazy path
    (``core.sharded``) passes each device's local candidate block, so one
    call corrects an (n/ndev,)-slice of the cached gain vector per shard —
    the reduction over ``z`` rows is unchanged, keeping per-candidate sums
    bit-exact against the single-device call.
    """
    if not use_pallas:
        return fl_gains_gram_free_delta_ref(z, zc, c_old, c_new)
    b, d = z.shape
    n_cand = zc.shape[0]
    bi = min(block_i, max(8, b))
    bj = min(block_j, max(128, n_cand))
    pad_i = (-b) % bi
    pad_j = (-n_cand) % bj
    pad_d = (-d) % 128
    if pad_i or pad_d:
        z = jnp.pad(z, ((0, pad_i), (0, pad_d)))
        c_old = jnp.pad(c_old, (0, pad_i), constant_values=jnp.inf)
        c_new = jnp.pad(c_new, (0, pad_i), constant_values=jnp.inf)
    if pad_j or pad_d:
        zc = jnp.pad(zc, ((0, pad_j), (0, pad_d)))
    out = fl_gains_gram_free_delta_pallas(z, zc, c_old, c_new,
                                          block_i=bi, block_j=bj,
                                          interpret=interpret)
    return out[:n_cand]
