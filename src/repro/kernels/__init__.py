"""Pallas TPU kernels (each: <name>.py kernel + ops.py dispatch + ref.py oracle)."""
