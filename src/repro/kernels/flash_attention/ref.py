"""Pure-jnp oracle: causal GQA attention (the downstream LM hot-spot)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gqa_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Grouped-query attention, materialized-scores reference.

    Args:
      q: (B, Hq, Sq, D)
      k: (B, Hkv, Sk, D)
      v: (B, Hkv, Sk, D)   with Hq % Hkv == 0.
      causal: apply causal mask aligned to the *end* of the key axis
        (query i attends keys j with j <= i + (Sk - Sq)).

    Returns:
      (B, Hq, Sq, D) float32.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    q = q.astype(jnp.float32)
    k = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    v = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        kj = jnp.arange(sk)[None, :]
        logits = jnp.where(kj <= qi, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
