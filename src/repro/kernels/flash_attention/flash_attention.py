"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Grid: (batch*heads, q_blocks, kv_blocks) with the kv axis innermost so the
(m, l, acc) running statistics live in VMEM scratch across kv steps.  GQA is
handled in the key/value index_map (head h reads kv-head h // group) so K/V
are never repeated in HBM.  Causal block skipping is done by masking; fully
masked kv blocks for a given q block still stream but contribute zeros (the
structural-skip variant is a §Perf follow-up; the dominant cost term is
unchanged).

VMEM working set per step (fp32): q(bq,d) + k(bk,d) + v(bk,d) + acc(bq,d)
+ scores(bq,bk) + stats ≈ 4*(3*128*128 + 2*128*128 + ...) ≈ 0.5 MB at
bq=bk=128, d=128 — far under budget; bq/bk default to 128 for MXU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, causal, sk_minus_sq, sk_valid, block_q, block_k, nk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32) * scale
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = cols < sk_valid  # mask padded keys (exact-padding guarantee)
    if causal:
        qi = pl.program_id(1)
        rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + sk_minus_sq
        valid = valid & (cols <= rows)
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]          # (bq, 1)
    l_prev = l_ref[...]          # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)       # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "causal_offset", "sk_valid"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    causal_offset: int | None = None,  # real (sk - sq) when inputs are padded
    sk_valid: int | None = None,       # number of real (unpadded) keys
) -> jax.Array:
    """Causal GQA flash attention.

    Args: q (B, Hq, Sq, D); k, v (B, Hkv, Sk, D). Sq % block_q == 0,
    Sk % block_k == 0 (ops.py pads).  Returns (B, Hq, Sq, D) in q.dtype.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq ({sq},{sk}) not divisible by blocks ({bq},{bk})")
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        # flattened h = b_idx * hq + head; GQA: kv row = b_idx * hkv + head // group
        return ((h // hq) * hkv + (h % hq) // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            sk_minus_sq=sk - sq if causal_offset is None else causal_offset,
            sk_valid=sk if sk_valid is None else sk_valid,
            block_q=bq,
            block_k=bk,
            nk=nk,
        ),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1)),   # m: running max
            _vmem((bq, 1)),   # l: running denominator
            _vmem((bq, d)),   # acc: unnormalized output
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
