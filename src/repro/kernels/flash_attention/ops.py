"""Public dispatch for flash attention: pads seq to block grid and routes
Pallas (TPU) / interpret (CPU validation) / reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import gqa_attention_ref


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Causal GQA attention; pads ragged seq lengths (exact — padded keys are
    masked out by causality / get zero weight via -inf logits)."""
    if not use_pallas:
        return gqa_attention_ref(q, k, v, causal=causal).astype(q.dtype)
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(128, sk))
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # Padded keys appear *after* real keys; with causal masking aligned to
        # the end of the key axis they must be masked for the padded queries
        # too — causal offset handles real queries, and padded query rows are
        # sliced off below.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
        causal_offset=sk - sq,  # mask geometry of the *real* shapes
        sk_valid=sk,
    )
    return out[:, :, :sq, :]
