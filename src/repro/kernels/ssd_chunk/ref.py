"""Pure-jnp oracle for the SSD intra-chunk kernel (Mamba-2 form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(x, a, b, c, h_in):
    """One SSD chunk, all heads: recurrence h_t = a_t h + (b_t x_tᵀ), y = c_t h.

    Args:
      x: (L, H, P) values (dt pre-multiplied);
      a: (L, H) per-head decay in (0, 1];
      b: (L, N) input projection;  c: (L, N) output projection;
      h_in: (H, N, P) carried state.

    Returns:
      y: (L, H, P) outputs; h_out: (H, N, P) state after the chunk.
    """
    L, H, P = x.shape
    N = b.shape[-1]
    la = jnp.log(jnp.maximum(a.astype(jnp.float32), 1e-20))
    cum = jnp.cumsum(la, axis=0)                               # (L, H)
    dt_mat = cum[:, None, :] - cum[None, :, :]                 # (L, L, H) t,s
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[:, :, None], jnp.exp(dt_mat), 0.0)
    scores = jnp.einsum("tn,sn->ts", c.astype(jnp.float32), b.astype(jnp.float32))
    w = scores[:, :, None] * decay                             # (L, L, H)
    y_intra = jnp.einsum("tsh,shp->thp", w, x.astype(jnp.float32))
    y_inter = jnp.einsum("tn,hnp,th->thp", c.astype(jnp.float32),
                         h_in.astype(jnp.float32), jnp.exp(cum))
    tot = cum[-1]                                              # (H,)
    rem = jnp.exp(tot[None, :] - cum)                          # (L, H)
    h_out = jnp.exp(tot)[:, None, None] * h_in.astype(jnp.float32) + jnp.einsum(
        "sn,shp,sh->hnp", b.astype(jnp.float32), x.astype(jnp.float32), rem
    )
    return y_intra + y_inter, h_out
