"""Dispatch for the SSD chunk kernel: batch-of-chunks driver matching the
pure-JAX `_ssd_chunk_scan` contract (scan over chunks, kernel per chunk)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_pallas


def ssd_chunk(x, a, b, c, h_in, *, use_pallas: bool = True,
              block_h: int = 8, interpret: bool = False):
    """One chunk, batched: x (B,L,H,P), a (B,L,H), b/c (B,L,N), h (B,H,N,P)."""
    if not use_pallas:
        y, h = jax.vmap(ssd_chunk_ref)(x, a, b, c, h_in)
        return y, h
    return ssd_chunk_pallas(x, a, b, c, h_in, block_h=block_h, interpret=interpret)


def ssd_scan(x, a, b, c, *, chunk: int = 256, use_pallas: bool = True,
             block_h: int = 8, interpret: bool = False):
    """Full sequence via lax.scan over Pallas chunk steps.

    Same semantics as repro.models.ssm._ssd_chunk_scan (tests assert it).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(B, nc, chunk, H, P).swapaxes(0, 1)
    ac = a.reshape(B, nc, chunk, H).swapaxes(0, 1)
    bc = b.reshape(B, nc, chunk, N).swapaxes(0, 1)
    cc = c.reshape(B, nc, chunk, N).swapaxes(0, 1)

    def step(h, xs):
        xb, ab, bb, cb = xs
        y, h_new = ssd_chunk(xb, ab, bb, cb, h, use_pallas=use_pallas,
                             block_h=block_h, interpret=interpret)
        return h_new, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_fin, ys = jax.lax.scan(step, h0, (xc, ac, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, nc * chunk, H, P)[:, :S]
    return y, h_fin
