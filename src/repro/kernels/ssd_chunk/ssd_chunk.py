"""Pallas TPU kernel: SSD intra-chunk scan step (Mamba-2 / jamba hot-spot).

One grid step processes one (batch, head-block) tile of one chunk entirely
in VMEM:

  decay  = exp(cum_t - cum_s) ∘ tril          (L, L) per head
  y      = ((C Bᵀ) ∘ decay) X  +  (C h_in) ∘ exp(cum)
  h_out  = exp(cum_L) h_in + Bᵀ (X ∘ rem)

Grid: (batch, H / block_h); heads are tiled so the (L, L, block_h) decay
stack plus the (L, N) projections fit VMEM:

  VMEM ≈ 4B · (L² · bh + 2·L·N + L·bh·P + bh·N·P)
  L=256, bh=8, N=128, P=64:  ≈ 2.6 MB   — comfortably inside the ~16 MB/core.

The L×L matmuls hit the MXU (L multiple of 128); the chunked formulation is
exactly why SSD replaces the Mamba-1 channel scan on TPU (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, h_ref, y_ref, hout_ref, *, L):
    x = x_ref[0].astype(jnp.float32)        # (L, bh, P)
    a = a_ref[0].astype(jnp.float32)        # (L, bh)
    b = b_ref[0].astype(jnp.float32)        # (L, N)
    c = c_ref[0].astype(jnp.float32)        # (L, N)
    h_in = h_ref[0].astype(jnp.float32)     # (bh, N, P)

    la = jnp.log(jnp.maximum(a, 1e-20))
    cum = jnp.cumsum(la, axis=0)                                 # (L, bh)
    dt_mat = cum[:, None, :] - cum[None, :, :]                   # (L, L, bh)
    rows = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where((cols <= rows)[:, :, None], jnp.exp(dt_mat), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    w = scores[:, :, None] * decay                               # (L, L, bh)
    y_intra = jnp.einsum("tsh,shp->thp", w, x)
    y_inter = jnp.einsum("tn,hnp->thp", c, h_in) * jnp.exp(cum)[:, :, None]
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    tot = cum[-1]                                                # (bh,)
    rem = jnp.exp(tot[None, :] - cum)                            # (L, bh)
    h_out = jnp.exp(tot)[:, None, None] * h_in + jnp.einsum(
        "sn,shp->hnp", b, x * rem[:, :, None]
    )
    hout_ref[0] = h_out.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def ssd_chunk_pallas(
    x: jax.Array,      # (B, L, H, P)
    a: jax.Array,      # (B, L, H)
    b: jax.Array,      # (B, L, N)
    c: jax.Array,      # (B, L, N)
    h_in: jax.Array,   # (B, H, N, P)
    *,
    block_h: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, L, H, P = x.shape
    N = b.shape[-1]
    bh = min(block_h, H)
    if H % bh:
        raise ValueError(f"H={H} not divisible by block_h={bh}")
    grid = (B, H // bh)
    y, h_out = pl.pallas_call(
        functools.partial(_ssd_kernel, L=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, bh, P), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, L, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, L, N), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, L, N), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bh, N, P), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, bh, P), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, bh, N, P), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(x, a, b, c, h_in)
    return y, h_out
