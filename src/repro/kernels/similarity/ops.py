"""Public dispatch for the similarity kernel: pads to block multiples, picks
Pallas (TPU) vs interpret (CPU validation) vs pure-jnp reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.similarity.ref import similarity_ref
from repro.kernels.similarity.similarity import similarity_pallas


def _pad_rows(z: jax.Array, mult: int) -> tuple[jax.Array, int]:
    m = z.shape[0]
    pad = (-m) % mult
    if pad:
        z = jnp.concatenate([z, jnp.ones((pad, z.shape[1]), z.dtype)], axis=0)
    return z, m


def similarity(
    zq: jax.Array,
    zk: jax.Array,
    *,
    normalized: bool = False,
    block_q: int = 256,
    block_k: int = 256,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Rescaled cosine Gram matrix; auto-pads ragged shapes to block grid."""
    if not use_pallas:
        return similarity_ref(zq, zk, normalized=normalized)
    bq = min(block_q, max(8, zq.shape[0]))
    bk = min(block_k, max(128, zk.shape[0]))
    zq_p, mq = _pad_rows(zq, bq)
    zk_p, mk = _pad_rows(zk, bk)
    out = similarity_pallas(
        zq_p, zk_p, block_q=bq, block_k=bk, normalized=normalized, interpret=interpret
    )
    return out[:mq, :mk]
