"""Pallas TPU kernel: blocked rescaled-cosine Gram matrix.

Computes ``S = 0.5 + 0.5 * Zq_n @ Zk_nᵀ`` where ``Z*_n`` are L2-normalized
rows, tiled so each grid step keeps one (bq, d) query block, one (bk, d) key
block, and the (bq, bk) output block in VMEM.  Block sizes default to 256x256
— MXU-aligned (multiples of 128) and, at d <= 4096 fp32, well under the ~16MB
VMEM budget per core:

    VMEM bytes ≈ 4 * (bq*d + bk*d + bq*bk)   (fp32)
    bq=bk=256, d=1024  ->  ~2.4 MB.

Row normalization is fused into the kernel (one rsqrt per row per block) so
the un-normalized path needs no extra HBM pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sim_kernel(zq_ref, zk_ref, out_ref, *, normalized: bool):
    zq = zq_ref[...].astype(jnp.float32)  # (bq, d)
    zk = zk_ref[...].astype(jnp.float32)  # (bk, d)
    if not normalized:
        zq = zq * jax.lax.rsqrt(jnp.maximum(jnp.sum(zq * zq, -1, keepdims=True), 1e-16))
        zk = zk * jax.lax.rsqrt(jnp.maximum(jnp.sum(zk * zk, -1, keepdims=True), 1e-16))
    acc = jax.lax.dot_general(
        zq, zk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] = 0.5 + 0.5 * acc


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "normalized", "interpret")
)
def similarity_pallas(
    zq: jax.Array,
    zk: jax.Array,
    *,
    block_q: int = 256,
    block_k: int = 256,
    normalized: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Blocked Gram matrix via pallas_call. Shapes must divide the blocks."""
    mq, d = zq.shape
    mk, _ = zk.shape
    bq = min(block_q, mq)
    bk = min(block_k, mk)
    if mq % bq or mk % bk:
        raise ValueError(f"shape ({mq},{mk}) not divisible by blocks ({bq},{bk})")
    grid = (mq // bq, mk // bk)
    return pl.pallas_call(
        functools.partial(_sim_kernel, normalized=normalized),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mq, mk), jnp.float32),
        interpret=interpret,
    )(zq, zk)
