"""Pure-jnp oracle for the blocked cosine-similarity Gram kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity_ref(zq: jax.Array, zk: jax.Array, *, normalized: bool = False) -> jax.Array:
    """Rescaled cosine similarity: 0.5 + 0.5 * <q, k> / (|q||k|).

    Args:
      zq: (mq, d) query embeddings.
      zk: (mk, d) key embeddings.
      normalized: if True, rows are assumed already L2-normalized.

    Returns:
      (mq, mk) float32 similarity in [0, 1].
    """
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    if not normalized:
        zq = zq / jnp.maximum(jnp.linalg.norm(zq, axis=-1, keepdims=True), 1e-8)
        zk = zk / jnp.maximum(jnp.linalg.norm(zk, axis=-1, keepdims=True), 1e-8)
    return 0.5 + 0.5 * (zq @ zk.T)
