"""Datasets.

Real deployments plug file-backed corpora in through the same ``Dataset``
protocol; for CPU validation and the paper-reproduction benchmarks we ship
synthetic datasets whose *structure* matches the paper's setting:

  * ``GaussianMixtureDataset`` — c well-separated class clusters with dense
    cores and sparse tails (so representation vs diversity set functions
    behave as in the paper: graph-cut picks core/"easy", disparity picks
    tail/"hard" samples), plus a linear-probe-able label structure.
  * ``SyntheticTextDataset`` — token sequences from per-class Markov chains
    (a classification task an LSTM/transformer can actually learn), with
    encoder features = normalized bigram histograms (the "frozen pretrained
    encoder" stand-in: computed once, model-agnostic).
  * ``TokenLMDataset`` — next-token LM shards for the big-model substrate.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class GaussianMixtureDataset:
    """Classification with dense cores + sparse hard tails per class."""

    n: int = 2000
    n_classes: int = 10
    dim: int = 32
    tail_frac: float = 0.25     # fraction of "hard" tail samples per class
    sep: float = 6.0            # inter-class center separation
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        centers = rng.normal(size=(self.n_classes, self.dim)) * self.sep
        per = self.n // self.n_classes
        xs, ys, hard = [], [], []
        for c in range(self.n_classes):
            n_tail = int(per * self.tail_frac)
            n_core = per - n_tail
            core = centers[c] + rng.normal(size=(n_core, self.dim))
            # tail: drawn toward *other* classes (boundary / hard samples)
            other = centers[(c + 1 + rng.integers(0, self.n_classes - 1, n_tail)) % self.n_classes]
            tail = centers[c] * 0.55 + other * 0.45 + rng.normal(size=(n_tail, self.dim)) * 1.5
            xs.append(np.concatenate([core, tail]))
            ys.append(np.full(per, c))
            hard.append(np.concatenate([np.zeros(n_core, bool), np.ones(n_tail, bool)]))
        self.x = np.concatenate(xs).astype(np.float32)
        self.y = np.concatenate(ys).astype(np.int64)
        self.is_hard = np.concatenate(hard)
        self.n = len(self.x)

    def features(self) -> np.ndarray:
        """Frozen-encoder features (identity here: x already lives in a
        semantically meaningful space, like DINO embeddings do for images)."""
        return self.x

    def split(self, val_frac: float = 0.1, test_frac: float = 0.2, seed: int = 42):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(self.n)
        n_test = int(self.n * test_frac)
        n_val = int(self.n * val_frac)
        return (
            idx[n_test + n_val:],
            idx[n_test : n_test + n_val],
            idx[:n_test],
        )


@dataclasses.dataclass
class SyntheticTextDataset:
    """Per-class Markov-chain token sequences (TREC6-like 6-way task)."""

    n: int = 1200
    n_classes: int = 6
    vocab: int = 64
    seq_len: int = 32
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # class-specific transition matrices (sparse, peaked)
        self.trans = rng.dirichlet(np.full(self.vocab, 0.05), size=(self.n_classes, self.vocab))
        per = self.n // self.n_classes
        toks, ys = [], []
        for c in range(self.n_classes):
            for _ in range(per):
                seq = [int(rng.integers(self.vocab))]
                for _ in range(self.seq_len - 1):
                    seq.append(int(rng.choice(self.vocab, p=self.trans[c, seq[-1]])))
                toks.append(seq)
                ys.append(c)
        self.tokens = np.asarray(toks, np.int32)
        self.y = np.asarray(ys, np.int64)
        self.n = len(self.tokens)

    def features(self) -> np.ndarray:
        """Frozen-encoder stand-in: L2-normalized bigram histograms."""
        f = np.zeros((self.n, self.vocab * 8), np.float32)
        for i, seq in enumerate(self.tokens):
            for a, b in zip(seq[:-1], seq[1:]):
                f[i, (a * 131 + b) % f.shape[1]] += 1.0
        f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-6)
        return f

    def split(self, val_frac: float = 0.1, test_frac: float = 0.2, seed: int = 42):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(self.n)
        n_test = int(self.n * test_frac)
        n_val = int(self.n * val_frac)
        return idx[n_test + n_val:], idx[n_test : n_test + n_val], idx[:n_test]


@dataclasses.dataclass
class TokenLMDataset:
    """Synthetic next-token corpus for the LM substrate examples."""

    n_docs: int = 512
    seq_len: int = 128
    vocab: int = 256
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # structured: arithmetic-progression motifs the model can learn
        base = rng.integers(0, self.vocab, size=(self.n_docs, 1))
        step = rng.integers(1, 7, size=(self.n_docs, 1))
        pos = np.arange(self.seq_len + 1)[None, :]
        self.tokens = ((base + step * pos) % self.vocab).astype(np.int32)
        noise = rng.random((self.n_docs, self.seq_len + 1)) < 0.05
        self.tokens[noise] = rng.integers(0, self.vocab, size=int(noise.sum()))
        self.n = self.n_docs

    def batch(self, idx: np.ndarray) -> dict:
        t = self.tokens[idx]
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}

    def features(self) -> np.ndarray:
        f = np.zeros((self.n, 64), np.float32)
        for i, seq in enumerate(self.tokens):
            np.add.at(f[i], seq % 64, 1.0)
        f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-6)
        return f
