"""Sharded input pipeline with first-class subset selection.

The pipeline owns the *index stream*: each epoch it asks its ``selector``
(MILO, a baseline, or full-data) for the sample indices to visit, shuffles
deterministically in (seed, epoch), tiles into global batches, and yields
host arrays ready for ``jax.device_put`` onto the (pod, data)-sharded batch
axis.  Everything is a pure function of (seed, epoch, step) — the property
fault-tolerant restart relies on (distributed/fault_tolerance.py).

Background prefetch: a one-slot daemon thread overlaps host batch assembly
with device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, Protocol

import numpy as np


class Selector(Protocol):
    def indices_for_epoch(self, epoch: int) -> np.ndarray: ...


@dataclasses.dataclass
class FullSelector:
    """No selection: the whole dataset every epoch."""

    n: int

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return np.arange(self.n, dtype=np.int64)


@dataclasses.dataclass
class Pipeline:
    make_batch: Callable[[np.ndarray], dict]   # indices -> host batch dict
    selector: Any
    batch_size: int
    seed: int = 0
    drop_remainder: bool = True
    prefetch: bool = True

    def epoch_indices(self, epoch: int) -> np.ndarray:
        idx = np.asarray(self.selector.indices_for_epoch(epoch), np.int64)
        rng = np.random.default_rng(self.seed * 1_000_003 + epoch)
        return rng.permutation(idx)

    def steps_per_epoch(self, epoch: int = 0) -> int:
        n = len(self.epoch_indices(epoch))
        return n // self.batch_size if self.drop_remainder else -(-n // self.batch_size)

    def epoch(self, epoch: int, *, start_step: int = 0) -> Iterator[dict]:
        """Yield batches; ``start_step`` skips ahead for restart replay."""
        idx = self.epoch_indices(epoch)
        n_steps = self.steps_per_epoch(epoch)

        def gen():
            for s in range(start_step, n_steps):
                lo = s * self.batch_size
                sel = idx[lo : lo + self.batch_size]
                if len(sel) < self.batch_size:
                    if self.drop_remainder:
                        return
                    sel = np.pad(sel, (0, self.batch_size - len(sel)), mode="wrap")
                yield self.make_batch(sel)

        if not self.prefetch:
            yield from gen()
            return
        q: queue.Queue = queue.Queue(maxsize=2)
        _SENTINEL = object()

        def worker():
            try:
                for b in gen():
                    q.put(b)
            finally:
                q.put(_SENTINEL)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            b = q.get()
            if b is _SENTINEL:
                break
            yield b
