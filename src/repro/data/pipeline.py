"""Sharded input pipeline with first-class subset selection.

The pipeline owns the *index stream*: each epoch it asks its selector for a
``repro.selection.SelectionPlan`` (sample indices + per-sample loss weights +
curriculum phase), shuffles deterministically in (seed, epoch), tiles into
global batches, and yields host arrays ready for ``jax.device_put`` onto the
(pod, data)-sharded batch axis.  Plan weights ride along in each batch under
``weights`` so the loss can consume them (see ``models/lm.loss_fn`` and the
session classifier).  Legacy selectors exposing only ``indices_for_epoch``
are still accepted (uniform weights).  Everything is a pure function of
(seed, epoch, step) — the property fault-tolerant restart relies on
(distributed/fault_tolerance.py).

Background prefetch: a one-slot daemon thread overlaps host batch assembly
with device compute; worker exceptions propagate to the consumer instead of
silently truncating the epoch, and abandoning an epoch early (break /
``close()`` on the iterator) signals the worker to stop instead of leaving
it blocked forever on a full queue with batch arrays pinned.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, Protocol

import numpy as np


class Selector(Protocol):
    """Deprecated structural protocol — prefer ``repro.selection.Selector``."""

    def indices_for_epoch(self, epoch: int) -> np.ndarray: ...


@dataclasses.dataclass
class FullSelector:
    """No selection: the whole dataset every epoch (legacy protocol; new code
    should use ``build_selector("full", n=...)``)."""

    n: int

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return np.arange(self.n, dtype=np.int64)


class _WorkerError:
    """Wrapper carrying a prefetch-worker exception across the queue."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass
class Pipeline:
    make_batch: Callable[[np.ndarray], dict]   # indices -> host batch dict
    selector: Any
    batch_size: int
    seed: int = 0
    drop_remainder: bool = True
    prefetch: bool = True
    weight_key: str | None = "weights"         # None disables weight injection

    def __post_init__(self):
        self._plan_cache: tuple[int, Any] | None = None
        self._plan_selector: Any = None

    def invalidate_plan_cache(self) -> None:
        """Drop the memoized epoch plan (e.g. after a selector cache reset)."""
        self._plan_cache = None

    def plan_for_epoch(self, epoch: int):
        """The selector's (cached) SelectionPlan for this epoch."""
        if self._plan_cache is not None and self._plan_cache[0] == epoch:
            return self._plan_cache[1]
        if self._plan_selector is None:
            # deferred: data sits below selection in the layering, so the
            # adapter import happens at first use, not module import
            from repro.selection.base import ensure_selector

            self._plan_selector = ensure_selector(self.selector)
        plan = self._plan_selector.plan(epoch)
        self._plan_cache = (epoch, plan)
        return plan

    def _permuted(self, epoch: int) -> tuple[np.ndarray, np.ndarray]:
        """(indices, weights) in this epoch's deterministic visit order."""
        plan = self.plan_for_epoch(epoch)
        rng = np.random.default_rng(self.seed * 1_000_003 + epoch)
        perm = rng.permutation(len(plan.indices))
        return plan.indices[perm], plan.weights[perm]

    def epoch_indices(self, epoch: int) -> np.ndarray:
        return self._permuted(epoch)[0]

    def steps_per_epoch(self, epoch: int = 0) -> int:
        n = len(self.plan_for_epoch(epoch).indices)
        return n // self.batch_size if self.drop_remainder else -(-n // self.batch_size)

    def epoch(self, epoch: int, *, start_step: int = 0) -> Iterator[dict]:
        """Yield batches; ``start_step`` skips ahead for restart replay."""
        idx, weights = self._permuted(epoch)
        n_steps = self.steps_per_epoch(epoch)

        def gen():
            for s in range(start_step, n_steps):
                lo = s * self.batch_size
                sel = idx[lo : lo + self.batch_size]
                w = weights[lo : lo + self.batch_size]
                if len(sel) < self.batch_size:
                    if self.drop_remainder:
                        return
                    pad = self.batch_size - len(sel)
                    sel = np.pad(sel, (0, pad), mode="wrap")
                    w = np.pad(w, (0, pad), mode="wrap")
                b = self.make_batch(sel)
                if self.weight_key and self.weight_key not in b:
                    b[self.weight_key] = w.copy()
                yield b

        if not self.prefetch:
            yield from gen()
            return
        q: queue.Queue = queue.Queue(maxsize=2)
        _SENTINEL = object()
        stop = threading.Event()

        def put(item) -> bool:
            """Enqueue unless the consumer has gone away; the timeout bounds
            how long an abandoned worker can stay blocked on a full queue."""
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for b in gen():
                    if not put(b):
                        return
            except BaseException as e:  # noqa: BLE001 — re-raised in consumer
                put(_WorkerError(e))
            else:
                put(_SENTINEL)

        t = threading.Thread(target=worker, daemon=True,
                             name="pipeline-prefetch")
        t.start()
        try:
            while True:
                b = q.get()
                if b is _SENTINEL:
                    break
                if isinstance(b, _WorkerError):
                    raise b.exc
                yield b
        finally:
            # runs on normal exhaustion AND when the consumer breaks out
            # early (generator close): release the worker and reap it so no
            # thread is left pinning batch arrays behind a full queue
            stop.set()
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            t.join(timeout=5.0)
