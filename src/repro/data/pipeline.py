"""Sharded input pipeline with first-class subset selection.

The pipeline owns the *index stream*: each epoch it asks its selector for a
``repro.selection.SelectionPlan`` (sample indices + per-sample loss weights +
curriculum phase), shuffles deterministically in (seed, epoch), tiles into
global batches, and yields host arrays ready for ``jax.device_put`` onto the
(pod, data)-sharded batch axis.  Plan weights ride along in each batch under
``weights`` so the loss can consume them (see ``models/lm.loss_fn`` and the
session classifier).  Legacy selectors exposing only ``indices_for_epoch``
are still accepted (uniform weights).  Everything is a pure function of
(seed, epoch, step) — the property fault-tolerant restart relies on
(distributed/fault_tolerance.py).

Background prefetch: a one-slot daemon thread overlaps host batch assembly
with device compute; worker exceptions propagate to the consumer instead of
silently truncating the epoch, and abandoning an epoch early (break /
``close()`` on the iterator) signals the worker to stop instead of leaving
it blocked forever on a full queue with batch arrays pinned.

Device-resident fast path: when the dataset is a plain column store
(``arrays={"x": feats, "y": labs}``), ``device_epoch`` hands the consumer
the epoch's *entire* permuted (indices, weights) stream as two device
arrays — one ``device_put`` per epoch instead of one host batch per step —
and the fused training engine (``train.engine``) gathers each batch on
device.  No host batch is ever assembled and the prefetch thread is
bypassed entirely on this path; the index stream is the same pure function
of (seed, epoch, step), so loop and fused runs consume identical batches.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, Protocol

import numpy as np


class Selector(Protocol):
    """Deprecated structural protocol — prefer ``repro.selection.Selector``."""

    def indices_for_epoch(self, epoch: int) -> np.ndarray: ...


@dataclasses.dataclass
class FullSelector:
    """No selection: the whole dataset every epoch (legacy protocol; new code
    should use ``build_selector("full", n=...)``)."""

    n: int

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return np.arange(self.n, dtype=np.int64)


class _WorkerError:
    """Wrapper carrying a prefetch-worker exception across the queue."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass
class Pipeline:
    make_batch: Callable[[np.ndarray], dict] | None  # indices -> host batch
    selector: Any
    batch_size: int
    seed: int = 0
    drop_remainder: bool = True
    prefetch: bool = True
    weight_key: str | None = "weights"         # None disables weight injection
    # Column store enabling the device-resident path: same-length arrays the
    # batches are gathered from (``batch[k] = arrays[k][idx]``).  Providing
    # it asserts ``make_batch`` is exactly that gather (``make_batch=None``
    # derives it); custom batch assembly must leave this unset — consumers
    # fall back to the host step loop.
    arrays: dict[str, np.ndarray] | None = None
    # Externally owned device placements of the SAME columns (e.g. from a
    # ``repro.serve.BufferRegistry``): consumers on the device-resident path
    # (``Trainer``) use these instead of device_put-ing their own copy, so N
    # concurrent trainers over one dataset share one buffer per column.  The
    # host ``arrays`` stay authoritative for shapes/validation; ``resident``
    # must cover exactly the same keys.
    resident: dict[str, Any] | None = None

    def __post_init__(self):
        self._plan_cache: tuple[int, Any] | None = None
        self._plan_selector: Any = None
        if self.arrays is not None:
            lengths = {k: len(v) for k, v in self.arrays.items()}
            if len(set(lengths.values())) > 1:
                raise ValueError(
                    f"arrays columns disagree on length: {lengths}"
                )
            if self.weight_key and self.weight_key in self.arrays:
                raise ValueError(
                    f"arrays column {self.weight_key!r} collides with "
                    "weight_key: plan weights would silently shadow it"
                )
        if self.resident is not None:
            if self.arrays is None:
                raise ValueError("resident buffers require the arrays "
                                 "column store they mirror")
            if set(self.resident) != set(self.arrays):
                raise ValueError(
                    f"resident buffers cover {sorted(self.resident)} but the "
                    f"column store holds {sorted(self.arrays)}; they must "
                    "mirror the same columns"
                )
            for k, buf in self.resident.items():
                if tuple(buf.shape) != tuple(np.shape(self.arrays[k])):
                    raise ValueError(
                        f"resident buffer {k!r} has shape {tuple(buf.shape)} "
                        f"but the host column is "
                        f"{tuple(np.shape(self.arrays[k]))}"
                    )
        if self.make_batch is None:
            if self.arrays is None:
                raise ValueError("make_batch=None requires arrays")
            cols = self.arrays

            def gather(idx: np.ndarray) -> dict:
                return {k: v[idx] for k, v in cols.items()}

            self.make_batch = gather

    @property
    def supports_device_epoch(self) -> bool:
        """True when the device-resident fast path is available."""
        return self.arrays is not None

    def invalidate_plan_cache(self) -> None:
        """Drop the memoized epoch plan (e.g. after a selector cache reset)."""
        self._plan_cache = None

    def plan_for_epoch(self, epoch: int):
        """The selector's (cached) SelectionPlan for this epoch."""
        if self._plan_cache is not None and self._plan_cache[0] == epoch:
            return self._plan_cache[1]
        if self._plan_selector is None:
            # deferred: data sits below selection in the layering, so the
            # adapter import happens at first use, not module import
            from repro.selection.base import ensure_selector

            self._plan_selector = ensure_selector(self.selector)
        plan = self._plan_selector.plan(epoch)
        self._plan_cache = (epoch, plan)
        return plan

    def _permuted(self, epoch: int) -> tuple[np.ndarray, np.ndarray]:
        """(indices, weights) in this epoch's deterministic visit order."""
        plan = self.plan_for_epoch(epoch)
        rng = np.random.default_rng(self.seed * 1_000_003 + epoch)
        perm = rng.permutation(len(plan.indices))
        return plan.indices[perm], plan.weights[perm]

    def epoch_indices(self, epoch: int) -> np.ndarray:
        return self._permuted(epoch)[0]

    def steps_per_epoch(self, epoch: int = 0) -> int:
        n = len(self.plan_for_epoch(epoch).indices)
        return n // self.batch_size if self.drop_remainder else -(-n // self.batch_size)

    def device_epoch(self, epoch: int, *, start_step: int = 0):
        """The epoch's remaining (indices, weights) as ``(n_steps, batch)``
        device arrays — the device-resident fast path (``train.engine``).

        One ``device_put`` covers the whole epoch; no host batch is
        assembled and the prefetch thread never starts.  Step ``s`` of the
        result is exactly the (index, weight) content of the ``s +
        start_step``-th batch ``epoch()`` would yield — same permutation,
        same drop/wrap-pad remainder handling — so restart replay stays a
        pure function of (seed, epoch, step) on either path.
        """
        import jax.numpy as jnp  # deferred: data sits below jax consumers

        if self.arrays is None:
            raise ValueError(
                "device_epoch needs the arrays column store; this pipeline "
                "assembles custom host batches — use epoch()"
            )
        idx, weights = self._permuted(epoch)
        n_steps = self.steps_per_epoch(epoch)
        take = n_steps * self.batch_size
        if take > len(idx):
            # not drop_remainder: wrap-pad the final short batch from its own
            # elements, exactly as epoch() does
            lo = (n_steps - 1) * self.batch_size
            pad = (0, take - len(idx))
            idx = np.concatenate([idx[:lo], np.pad(idx[lo:], pad, mode="wrap")])
            weights = np.concatenate(
                [weights[:lo], np.pad(weights[lo:], pad, mode="wrap")]
            )
        idx = idx[:take].reshape(n_steps, self.batch_size)[start_step:]
        weights = weights[:take].reshape(n_steps, self.batch_size)[start_step:]
        return jnp.asarray(idx, jnp.int32), jnp.asarray(weights, jnp.float32)

    def epoch(self, epoch: int, *, start_step: int = 0) -> Iterator[dict]:
        """Yield batches; ``start_step`` skips ahead for restart replay."""
        idx, weights = self._permuted(epoch)
        n_steps = self.steps_per_epoch(epoch)

        def gen():
            for s in range(start_step, n_steps):
                lo = s * self.batch_size
                sel = idx[lo : lo + self.batch_size]
                w = weights[lo : lo + self.batch_size]
                if len(sel) < self.batch_size:
                    if self.drop_remainder:
                        return
                    pad = self.batch_size - len(sel)
                    sel = np.pad(sel, (0, pad), mode="wrap")
                    w = np.pad(w, (0, pad), mode="wrap")
                b = self.make_batch(sel)
                if self.weight_key and self.weight_key not in b:
                    b[self.weight_key] = w.copy()
                yield b

        if not self.prefetch:
            yield from gen()
            return
        q: queue.Queue = queue.Queue(maxsize=2)
        _SENTINEL = object()
        stop = threading.Event()

        def put(item) -> bool:
            """Enqueue unless the consumer has gone away; the timeout bounds
            how long an abandoned worker can stay blocked on a full queue."""
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for b in gen():
                    if not put(b):
                        return
            except BaseException as e:  # noqa: BLE001 — re-raised in consumer
                put(_WorkerError(e))
            else:
                put(_SENTINEL)

        t = threading.Thread(target=worker, daemon=True,
                             name="pipeline-prefetch")
        t.start()
        try:
            while True:
                b = q.get()
                if b is _SENTINEL:
                    break
                if isinstance(b, _WorkerError):
                    raise b.exc
                yield b
        finally:
            # runs on normal exhaustion AND when the consumer breaks out
            # early (generator close): release the worker and reap it so no
            # thread is left pinning batch arrays behind a full queue
            stop.set()
            while True:
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            t.join(timeout=5.0)
