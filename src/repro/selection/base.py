"""``Selector`` ABC — the single selection protocol.

Every strategy (MILO, the paper baselines, full-data) implements
``plan(epoch) -> SelectionPlan``.  The old ``indices_for_epoch`` entry point
survives as a thin deprecation shim on the ABC, and ``ensure_selector``
adapts legacy objects that only speak the old protocol so existing call
sites keep working during the migration.
"""
from __future__ import annotations

import abc
import warnings
from typing import Any

import numpy as np

from repro.selection.plan import SelectionPlan, uniform_plan


class Selector(abc.ABC):
    """Per-epoch subset server.  Implementations must be deterministic in
    (their configured seed, epoch) so fault-tolerant restarts replay the
    identical data order."""

    @abc.abstractmethod
    def plan(self, epoch: int) -> SelectionPlan:
        """The subset (indices + weights + phase + provenance) for ``epoch``."""

    def reset_cache(self) -> None:
        """Drop any memoized plans (used by benchmarks after jit warm-up)."""

    # -- deprecation shim ---------------------------------------------------
    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        """Deprecated: use ``plan(epoch).indices``."""
        warnings.warn(
            "indices_for_epoch is deprecated; use plan(epoch).indices",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.plan(epoch).indices


class LegacySelectorAdapter(Selector):
    """Wraps an object exposing only ``indices_for_epoch`` into the plan
    protocol with uniform weights.

    Phase tags are inferred from the wrapped object so downstream consumers
    (warm-up gating, trainer history) behave the same as with first-class
    selectors: a ``curriculum`` attribute yields its sge/wre phase (legacy
    ``MiloSelector``), an ``R`` re-selection interval tags ``adaptive``, and
    everything else is ``fixed``."""

    def __init__(self, legacy: Any):
        if not hasattr(legacy, "indices_for_epoch"):
            raise TypeError(
                f"{type(legacy).__name__} implements neither plan() nor "
                "indices_for_epoch()"
            )
        self.legacy = legacy

    def _phase(self, epoch: int) -> str:
        curriculum = getattr(self.legacy, "curriculum", None)
        if curriculum is not None and hasattr(curriculum, "phase"):
            return curriculum.phase(epoch)
        if getattr(self.legacy, "R", None):
            return "adaptive"
        return "fixed"

    def plan(self, epoch: int) -> SelectionPlan:
        idx = np.asarray(self.legacy.indices_for_epoch(epoch), np.int64)
        return uniform_plan(
            idx, self._phase(epoch), epoch, adapter=type(self.legacy).__name__
        )

    def reset_cache(self) -> None:
        if hasattr(self.legacy, "_cache_epoch"):
            self.legacy._cache_epoch = -1


def ensure_selector(obj: Any) -> Selector:
    """Coerce ``obj`` to the plan protocol (identity for new-style selectors)."""
    if isinstance(obj, Selector) or hasattr(obj, "plan"):
        return obj
    return LegacySelectorAdapter(obj)
