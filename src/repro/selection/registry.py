"""String-keyed selector registry.

Each strategy registers a name, a config dataclass, and a factory; callers
construct any selector uniformly::

    sel = build_selector("milo", metadata=md, total_epochs=40)

which is what lets ``MiloSession``, the benchmarks, and launch scripts swap
strategies from a single config string instead of ad-hoc constructor paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

from repro.selection.base import Selector


@dataclasses.dataclass(frozen=True)
class SelectorEntry:
    name: str
    config_cls: type
    factory: Callable[[Any], Selector]
    paper: str = ""      # name of the strategy in the MILO paper's experiments
    doc: str = ""


_REGISTRY: dict[str, SelectorEntry] = {}


def register(name: str, config_cls: type, *, paper: str = "", doc: str = ""):
    """Class decorator: ``@register("milo", MiloConfig, paper="MILO")``.

    The decorated class must accept the config dataclass instance as its
    single constructor argument.
    """

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"selector {name!r} already registered")
        _REGISTRY[name] = SelectorEntry(
            name=name,
            config_cls=config_cls,
            factory=cls,
            paper=paper,
            doc=doc or ((cls.__doc__ or "").strip().splitlines() or [""])[0],
        )
        cls.registry_name = name
        return cls

    return deco


def selector_entry(name: str) -> SelectorEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown selector {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available_selectors() -> list[str]:
    return sorted(_REGISTRY)


def iter_entries() -> Iterator[SelectorEntry]:
    for name in available_selectors():
        yield _REGISTRY[name]


def build_selector(name: str, **cfg: Any) -> Selector:
    """Construct a registered selector from keyword config.

    ``cfg`` is validated against the strategy's config dataclass, so typos
    and missing required fields fail loudly at build time.
    """
    entry = selector_entry(name)
    try:
        config = entry.config_cls(**cfg)
    except TypeError as e:
        fields = [f.name for f in dataclasses.fields(entry.config_cls)]
        raise TypeError(
            f"bad config for selector {name!r}: {e}; expected fields {fields}"
        ) from None
    return entry.factory(config)
