"""The twelve registered selection strategies (MILO + the paper's §4 baselines).

Each strategy is a ``Selector`` built from a config dataclass through the
registry, and returns weighted ``SelectionPlan``s:

  ============== ============================== =========================
  registry name  paper strategy                 plan weights
  ============== ============================== =========================
  milo           MILO (SGE→WRE curriculum)      uniform
  milo_fixed     MILO (Fixed)                   uniform
  milo_hier      MILO (hierarchical refine)     uniform
  milo_targeted  query FL (SMI-style targeted)  uniform
  random         RANDOM                         uniform
  adaptive_random ADAPTIVE-RANDOM               uniform
  el2n           EL2N [Paul'21]                 uniform
  selfsup_prune  prototypes [Sorscher'22]       uniform
  craig_pb       CRAIG-PB [Mirzasoleiman'20]    cluster masses (γ)
  gradmatch_pb   GRAD-MATCH-PB [Killamsetty'21] OMP coefficients
  glister        GLISTER [Killamsetty'21]       uniform
  full           FULL (no selection)            uniform
  ============== ============================== =========================

Selection *logic* is reused from ``repro.core.milo`` and
``repro.baselines.selectors``; this module adds the weighted-plan surface,
phase tags, provenance, and uniform construction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.baselines import selectors as legacy
from repro.core.curriculum import CurriculumConfig
from repro.core.metadata import MiloMetadata
from repro.core.milo import MiloSelector as _LegacyMiloSelector
from repro.core.milo import hierarchical_select, targeted_select
from repro.selection.base import Selector
from repro.selection.plan import SelectionPlan, uniform_plan
from repro.selection.registry import register


# --------------------------------------------------------------------------
# MILO (the paper's method)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MiloConfig:
    metadata: MiloMetadata | None = None
    metadata_path: str | None = None
    total_epochs: int = 40
    kappa: float = 1.0 / 6.0
    R: int = 1
    seed: int = 0
    # optional artifact verification for the metadata_path route (same
    # semantics as MiloMetadata.load) so non-session callers get the same
    # mismatch guard the facade enforces
    expected_config: dict | None = None
    expected_hash: str | None = None

    def resolve_metadata(self) -> MiloMetadata:
        if self.metadata is not None:
            return self.metadata
        if self.metadata_path is not None:
            return MiloMetadata.load(
                self.metadata_path,
                expected_config=self.expected_config,
                expected_hash=self.expected_hash,
            )
        raise ValueError("milo selector needs `metadata` or `metadata_path`")


@register("milo", MiloConfig, paper="MILO",
          doc="easy-to-hard curriculum over precomputed SGE bank + WRE draws")
class MiloPlanSelector(Selector):
    """MILO curriculum: SGE-bank lookups early, WRE Gumbel draws after —
    per-epoch cost O(k), independent of the model (paper Alg. 1)."""

    def __init__(self, cfg: MiloConfig):
        self.cfg = cfg
        self.metadata = cfg.resolve_metadata()
        self.curriculum = CurriculumConfig(
            total_epochs=cfg.total_epochs, kappa=cfg.kappa, R=cfg.R
        )
        self._inner = _LegacyMiloSelector(self.metadata, self.curriculum, seed=cfg.seed)
        # constant for the selector's lifetime; plan() sits inside the
        # benchmarks' timed region where re-hashing every epoch would inflate
        # MILO's measured O(k) selection cost
        self._config_hash = self.metadata.config_hash()

    @property
    def k(self) -> int:
        return self.metadata.k

    def plan(self, epoch: int) -> SelectionPlan:
        idx = self._inner.indices_for_epoch(epoch)
        phase = self.curriculum.phase(epoch)
        if phase == "sge":
            window = (epoch // self.curriculum.R) % self.metadata.sge_subsets.shape[0]
        else:
            window = (epoch - self.curriculum.sge_epochs) // self.curriculum.R
        return uniform_plan(
            idx, phase, epoch,
            selector="milo", seed=self.cfg.seed, window=int(window),
            config_hash=self._config_hash,
        )

    def reset_cache(self) -> None:
        self._inner._cache_epoch = -1


# --------------------------------------------------------------------------
# model-independent baselines
# --------------------------------------------------------------------------

@dataclasses.dataclass
class FullConfig:
    n: int


@register("full", FullConfig, paper="FULL", doc="no selection — every sample, every epoch")
class FullPlanSelector(Selector):
    """The whole dataset every epoch (skyline / no-selection baseline)."""

    def __init__(self, cfg: FullConfig):
        self.cfg = cfg

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            np.arange(self.cfg.n, dtype=np.int64), "fixed", epoch, selector="full"
        )


@dataclasses.dataclass
class RandomConfig:
    n: int
    k: int
    seed: int = 0


@register("random", RandomConfig, paper="RANDOM", doc="one fixed random subset")
class RandomPlanSelector(Selector):
    """Fixed random subset drawn once at construction."""

    def __init__(self, cfg: RandomConfig):
        self.cfg = cfg
        self._inner = legacy.RandomSelector(cfg.n, cfg.k, seed=cfg.seed)

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._inner.indices_for_epoch(epoch), "fixed", epoch,
            selector="random", seed=self.cfg.seed,
        )


@dataclasses.dataclass
class AdaptiveRandomConfig:
    n: int
    k: int
    R: int = 1
    seed: int = 0


@register("adaptive_random", AdaptiveRandomConfig, paper="ADAPTIVE-RANDOM",
          doc="fresh random subset every R epochs")
class AdaptiveRandomPlanSelector(Selector):
    """Fresh random subset every R epochs, deterministic in (seed, window)."""

    def __init__(self, cfg: AdaptiveRandomConfig):
        self.cfg = cfg
        self._inner = legacy.AdaptiveRandomSelector(cfg.n, cfg.k, R=cfg.R, seed=cfg.seed)

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._inner.indices_for_epoch(epoch), "adaptive", epoch,
            selector="adaptive_random", seed=self.cfg.seed, window=epoch // self.cfg.R,
        )


@dataclasses.dataclass
class MiloFixedConfig:
    features: np.ndarray
    k: int
    # select over features directly (O(n·d) memory) instead of the (n,n) Gram
    gram_free: bool = False
    # shard the feature rows over all local devices (trajectory-identical;
    # implies the gram-free route — see core.sharded)
    shard_selection: bool = False


@register("milo_fixed", MiloFixedConfig, paper="MILO (Fixed)",
          doc="fixed disparity-min subset over frozen-encoder features")
class MiloFixedPlanSelector(Selector):
    """One fixed subset maximizing disparity-min (no curriculum)."""

    def __init__(self, cfg: MiloFixedConfig):
        self.cfg = cfg
        self._inner = legacy.MiloFixedSelector(
            cfg.features, cfg.k, gram_free=cfg.gram_free,
            shard_selection=cfg.shard_selection,
        )

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._inner.indices_for_epoch(epoch), "fixed", epoch, selector="milo_fixed"
        )


@dataclasses.dataclass
class MiloHierConfig:
    features: np.ndarray
    k: int
    # None → unsupervised partitioning (random_blocks / single block)
    labels: np.ndarray | None = None
    # "by_class" | "random_blocks" | "balanced_blocks"
    partition: str = "random_blocks"
    partition_block: int = 4096
    partition_seed: int = 0
    # level-0 oversampling: each partition keeps min(n_c, refine_factor·k_c)
    refine_factor: int = 2
    fn_name: str = "facility_location"
    gram_free: bool = True


@register("milo_hier", MiloHierConfig, paper="MILO (hierarchical)",
          doc="two-level partition→greedy→refine subset; partition-sized memory")
class MiloHierPlanSelector(Selector):
    """One fixed subset from the hierarchical partition-then-refine pipeline
    (sub-linear peak memory: per-partition greedy + level-1 refine)."""

    def __init__(self, cfg: MiloHierConfig):
        self.cfg = cfg
        self._idx, self.info = hierarchical_select(
            cfg.features, cfg.k, labels=cfg.labels, partition=cfg.partition,
            block_size=cfg.partition_block, seed=cfg.partition_seed,
            refine_factor=cfg.refine_factor, fn_name=cfg.fn_name,
            gram_free=cfg.gram_free, return_info=True,
        )

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._idx, "fixed", epoch, selector="milo_hier",
            partition=self.cfg.partition,
            refine_factor=self.cfg.refine_factor,
        )


@dataclasses.dataclass
class MiloTargetedConfig:
    features: np.ndarray
    queries: np.ndarray
    k: int
    labels: np.ndarray | None = None
    partition: str = "by_class"
    partition_block: int = 4096
    partition_seed: int = 0
    refine_factor: int = 4


@register("milo_targeted", MiloTargetedConfig, paper="query FL (SMI)",
          doc="query-conditioned targeted selection over partition winners")
class MiloTargetedPlanSelector(Selector):
    """Fixed query-covering subset: query facility location both levels, so
    the plan covers the query slice rather than the whole ground set."""

    def __init__(self, cfg: MiloTargetedConfig):
        self.cfg = cfg
        self._idx, self.info = targeted_select(
            cfg.features, cfg.queries, cfg.k, labels=cfg.labels,
            partition=cfg.partition, block_size=cfg.partition_block,
            seed=cfg.partition_seed, refine_factor=cfg.refine_factor,
            return_info=True,
        )

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._idx, "fixed", epoch, selector="milo_targeted",
            partition=self.cfg.partition,
            refine_factor=self.cfg.refine_factor,
        )


@dataclasses.dataclass
class EL2NConfig:
    scores: np.ndarray
    k: int
    keep: str = "hard"


@register("el2n", EL2NConfig, paper="EL2N [Paul'21]",
          doc="keep hardest/easiest k by EL2N score")
class EL2NPlanSelector(Selector):
    """Data-diet pruning by precomputed EL2N scores."""

    def __init__(self, cfg: EL2NConfig):
        self.cfg = cfg
        self._inner = legacy.EL2NSelector(cfg.scores, cfg.k, keep=cfg.keep)

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._inner.indices_for_epoch(epoch), "fixed", epoch,
            selector="el2n", keep=self.cfg.keep,
        )


@dataclasses.dataclass
class SelfSupPruneConfig:
    features: np.ndarray
    k: int
    n_prototypes: int = 10
    seed: int = 0


@register("selfsup_prune", SelfSupPruneConfig, paper="prototypes [Sorscher'22]",
          doc="k-means prototype-distance pruning")
class SelfSupPrunePlanSelector(Selector):
    """Self-supervised prototype-distance pruning (keep farthest k)."""

    def __init__(self, cfg: SelfSupPruneConfig):
        self.cfg = cfg
        self._inner = legacy.SelfSupPruneSelector(
            cfg.features, cfg.k, n_prototypes=cfg.n_prototypes, seed=cfg.seed
        )

    def plan(self, epoch: int) -> SelectionPlan:
        return uniform_plan(
            self._inner.indices_for_epoch(epoch), "fixed", epoch,
            selector="selfsup_prune", seed=self.cfg.seed,
        )


# --------------------------------------------------------------------------
# model-dependent baselines (selection cost on the training critical path)
# --------------------------------------------------------------------------

class _WindowedSelector(Selector):
    """Base for R-windowed model-dependent strategies: recompute the
    (indices, weights) pair once per R-epoch window, tag plans ``adaptive``,
    and accumulate ``selection_time`` — the cost MILO amortizes away."""

    name = ""

    def __init__(self, R: int):
        self.R = R
        self.selection_time = 0.0
        self._window: int | None = None
        self._idx: np.ndarray | None = None
        self._weights: np.ndarray | None = None

    def _select(self) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def plan(self, epoch: int) -> SelectionPlan:
        window = epoch // self.R
        if window != self._window or self._idx is None:
            t0 = time.perf_counter()
            self._idx, self._weights = self._select()
            self.selection_time += time.perf_counter() - t0
            self._window = window
        return SelectionPlan(
            self._idx, self._weights, "adaptive", epoch,
            {"selector": self.name, "window": window,
             "selection_time": self.selection_time},
        )

    def reset_cache(self) -> None:
        self._window = None


@dataclasses.dataclass
class CraigPBConfig:
    grad_fn: Callable[[], np.ndarray]
    k: int
    R: int = 10


@register("craig_pb", CraigPBConfig, paper="CRAIG-PB [Mirzasoleiman'20]",
          doc="facility-location medoids of gradient similarity; γ weights")
class CraigPBPlanSelector(_WindowedSelector):
    """Per-batch CRAIG with cluster-mass loss weights."""

    name = "craig_pb"

    def __init__(self, cfg: CraigPBConfig):
        super().__init__(cfg.R)
        self.cfg = cfg

    def _select(self):
        return legacy.craig_pb_select(self.cfg.grad_fn(), self.cfg.k)


@dataclasses.dataclass
class GradMatchPBConfig:
    grad_fn: Callable[[], np.ndarray]
    k: int
    R: int = 10
    lam: float = 0.5


@register("gradmatch_pb", GradMatchPBConfig, paper="GRAD-MATCH-PB [Killamsetty'21]",
          doc="OMP matching of the mean gradient; OMP-coefficient weights")
class GradMatchPBPlanSelector(_WindowedSelector):
    """Per-batch GRAD-MATCH with OMP-coefficient loss weights."""

    name = "gradmatch_pb"

    def __init__(self, cfg: GradMatchPBConfig):
        super().__init__(cfg.R)
        self.cfg = cfg

    def _select(self):
        return legacy.gradmatch_omp_select(self.cfg.grad_fn(), self.cfg.k, self.cfg.lam)


@dataclasses.dataclass
class GlisterConfig:
    grad_fn: Callable[[], np.ndarray]
    val_grad_fn: Callable[[], np.ndarray]
    k: int
    R: int = 10
    eta: float = 0.1


@register("glister", GlisterConfig, paper="GLISTER [Killamsetty'21]",
          doc="greedy validation-gain selection")
class GlisterPlanSelector(_WindowedSelector):
    """GLISTER's greedy validation-gain selection (uniform weights)."""

    name = "glister"

    def __init__(self, cfg: GlisterConfig):
        super().__init__(cfg.R)
        self.cfg = cfg

    def _select(self):
        idx = legacy.glister_select(
            self.cfg.grad_fn(), self.cfg.val_grad_fn(), self.cfg.k, self.cfg.eta
        )
        return idx, np.ones(len(idx), np.float32)
