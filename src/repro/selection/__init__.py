"""repro.selection — the single front door for subset selection.

* ``SelectionPlan`` / ``Selector`` — the weighted per-epoch protocol
  (indices + loss weights + phase + provenance) replacing bare
  ``indices_for_epoch`` index arrays.
* ``build_selector(name, **cfg)`` — registry factory covering MILO,
  MILO-Fixed, MILO-Hier, MILO-Targeted, Random, AdaptiveRandom, EL2N,
  SelfSupPrune, CRAIG-PB, GRAD-MATCH-PB, GLISTER, and Full.
* ``MiloSession`` — one-call facade: ``preprocess() / train() / tune()``.
"""
from repro.selection.plan import PHASES, SelectionPlan, uniform_plan
from repro.selection.base import LegacySelectorAdapter, Selector, ensure_selector
from repro.selection.registry import (
    SelectorEntry,
    available_selectors,
    build_selector,
    iter_entries,
    register,
    selector_entry,
)
from repro.selection.selectors import (
    AdaptiveRandomConfig,
    CraigPBConfig,
    EL2NConfig,
    FullConfig,
    GlisterConfig,
    GradMatchPBConfig,
    MiloConfig,
    MiloFixedConfig,
    MiloHierConfig,
    MiloTargetedConfig,
    RandomConfig,
    SelfSupPruneConfig,
)
from repro.selection.session import (
    MiloSession,
    MiloSessionConfig,
    TrainReport,
)

__all__ = [
    "PHASES",
    "SelectionPlan",
    "Selector",
    "SelectorEntry",
    "LegacySelectorAdapter",
    "ensure_selector",
    "uniform_plan",
    "register",
    "build_selector",
    "available_selectors",
    "iter_entries",
    "selector_entry",
    "MiloSession",
    "MiloSessionConfig",
    "TrainReport",
    "MiloConfig",
    "MiloFixedConfig",
    "MiloHierConfig",
    "MiloTargetedConfig",
    "FullConfig",
    "RandomConfig",
    "AdaptiveRandomConfig",
    "EL2NConfig",
    "SelfSupPruneConfig",
    "CraigPBConfig",
    "GradMatchPBConfig",
    "GlisterConfig",
]
