"""``SelectionPlan``: the unit of exchange between selectors and consumers.

A plan is everything a training loop needs for one epoch of subset training:
the sample indices, a per-sample loss weight aligned with them (uniform for
unweighted strategies; CRAIG's cluster masses and GRAD-MATCH's OMP
coefficients otherwise), the curriculum phase that produced it, and enough
provenance to reproduce the draw.  Replaces the bare index arrays of the old
``indices_for_epoch`` protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import numpy as np

#: Curriculum phases a plan may carry.
#:   sge      — easy subset from the pre-computed SGE bank (MILO warm-up)
#:   wre      — fresh weighted-random-exploration draw (MILO main phase)
#:   fixed    — one subset reused every epoch (RANDOM, EL2N, MILO-Fixed, ...)
#:   adaptive — re-selected every R epochs (ADAPTIVE-RANDOM, CRAIG-PB, ...)
PHASES = ("sge", "wre", "fixed", "adaptive")


@dataclasses.dataclass(frozen=True)
class SelectionPlan:
    """Immutable per-epoch selection decision."""

    indices: np.ndarray                 # (k,) int64 global sample indices
    weights: np.ndarray                 # (k,) float32 loss weights, mean ~= 1
    phase: str                          # one of PHASES
    epoch: int
    provenance: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        idx = np.asarray(self.indices, np.int64).reshape(-1)
        object.__setattr__(self, "indices", idx)
        if self.weights is None:
            w = np.ones(idx.shape, np.float32)
        else:
            w = np.asarray(self.weights, np.float32).reshape(-1)
        if w.shape != idx.shape:
            raise ValueError(
                f"weights shape {w.shape} does not match indices shape {idx.shape}"
            )
        object.__setattr__(self, "weights", w)
        if self.phase not in PHASES:
            raise ValueError(f"phase must be one of {PHASES}, got {self.phase!r}")

    @property
    def k(self) -> int:
        return int(self.indices.shape[0])

    def validate(self, n: int) -> "SelectionPlan":
        """Check the plan is a well-formed subset of range(n); returns self."""
        if self.k and (self.indices.min() < 0 or self.indices.max() >= n):
            raise ValueError(f"plan indices out of range for dataset of size {n}")
        if len(np.unique(self.indices)) != self.k:
            raise ValueError("plan indices contain duplicates")
        if not np.isfinite(self.weights).all() or (self.weights < 0).any():
            raise ValueError("plan weights must be finite and non-negative")
        return self


def uniform_plan(
    indices: np.ndarray, phase: str, epoch: int, **provenance: Any
) -> SelectionPlan:
    """Plan with unit weights (the common case for unweighted strategies)."""
    idx = np.asarray(indices, np.int64)
    return SelectionPlan(idx, np.ones(idx.shape, np.float32), phase, epoch, provenance)
