"""``MiloSession`` — the one-call facade for the paper's workflow.

One config object drives the whole decoupled pipeline::

    session = MiloSession(MiloSessionConfig(subset_fraction=0.1,
                                            total_epochs=40,
                                            metadata_path="/tmp/milo.npz"))
    session.preprocess(features, labels)        # once per (dataset, k)
    r1 = session.train(features, labels, test_x=tx, test_y=ty)
    r2 = session.train(features, labels, test_x=tx, test_y=ty, seed=1)
    best = session.tune(features, labels, vx, vy, space={...})

``preprocess`` runs the model-agnostic stage (or loads a saved artifact whose
config hash matches — the "train multiple models at no additional cost"
claim); ``train`` wires a registry-built selector into ``Pipeline`` +
``Trainer`` with plan weights flowing into the loss; ``tune`` drives the
Hyperband tuner over the same machinery.  The downstream model here is the
CPU-scale MLP classifier used throughout the benchmarks (the paper's setting:
frozen-encoder features + an arbitrary downstream model).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metadata import MetadataMismatchError, MiloMetadata, is_preprocessed
from repro.distributed import multihost
from repro.core.milo import MiloPreprocessor
from repro.data import pipeline as pipeline_mod
from repro.models.classifier import accuracy, init_mlp, nesterov_update, weighted_nll
from repro.selection.base import Selector
from repro.selection.registry import build_selector, selector_entry
from repro.train.trainer import Trainer, TrainerConfig
from repro.tuning.tuner import (
    HyperbandResult,
    RandomSearch,
    TPESearch,
    hyperband,
    subset_objective,
)

def _data_fingerprint(features: np.ndarray) -> str:
    """Cheap content identity for a feature matrix (same config + same
    length is not enough to prove an artifact belongs to this data)."""
    a = np.ascontiguousarray(np.asarray(features, np.float32))
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]


#: config keys that must match when reusing a saved preprocessing artifact
_PREPROCESS_KEYS = (
    "subset_fraction", "n_sge_subsets", "eps", "easy_fn", "hard_fn",
    "graph_cut_lambda", "classwise", "metric",
)


@dataclasses.dataclass
class MiloSessionConfig:
    """Everything the session needs, in one object."""

    # selection strategy (a repro.selection registry name)
    selector: str = "milo"
    # preprocessing (MiloPreprocessor knobs)
    subset_fraction: float = 0.1
    n_sge_subsets: int = 8
    eps: float = 0.01
    easy_fn: str = "graph_cut"
    hard_fn: str = "disparity_min"
    graph_cut_lambda: float = 0.4
    classwise: bool = True
    metric: str = "cosine"
    gram_block: int = 2048
    use_pallas: bool = False
    # preprocessing hot-path knobs (see MiloPreprocessor): gram-free FL/set
    # functions (O(n·d) per-class memory), power-of-two class-size bucketing
    # (one compile per bucket), vmapped SGE bank (one XLA program per class)
    gram_free: bool = False
    bucket_classes: bool = True
    sge_vmapped: bool = True
    # multi-device row-sharded selection (requires gram_free; trajectories
    # identical to single-device, so artifacts stay portable across meshes)
    shard_selection: bool = False
    # lazy gain reuse for the WRE full-greedy pass + its full-recompute
    # threshold (fraction of touched rows); FL hard functions only.
    # Composes with shard_selection: mesh-routed classes run the cached-gain
    # engine inside shard_map (see core.sharded.sharded_lazy_greedy)
    lazy_gains: bool = False
    lazy_threshold: float = 0.125
    # right-size lazy gathers to pow2 levels (bit-identical; shrinks the
    # sharded psum payload on calm steps — see MiloPreprocessor)
    lazy_two_level: bool = False
    # bucketed SGE candidate counts from the true class geometry instead of
    # the padded bucket's (changes the stochastic draws; see MiloPreprocessor)
    exact_sge_candidates: bool = False
    # input firewall policy screening the ground set before preprocessing
    # (None = off): "raise" | "repair" | "quarantine" — see
    # repro.health.firewall.  Recorded in artifact provenance (data_health).
    firewall: str | None = None
    # hierarchical partition-then-refine selection (see core.partition /
    # MiloPreprocessor): level-0 decomposition strategy ("by_class" is the
    # paper's flat path), block size + permutation seed for the block
    # strategies, and the level-1 oversampling factor (1 = refine off).
    # Stamped into artifact provenance and enforced on reuse whenever the
    # hierarchical path is active.
    partition: str = "by_class"
    partition_block: int = 4096
    partition_seed: int = 0
    refine_factor: int = 1
    # degraded-mode selection: selector names to fall back to (in order)
    # when the primary hits degenerate math (e.g. ("adaptive_random",)).
    # Every hop is recorded in plan provenance — see repro.health.fallback.
    selector_fallback: tuple[str, ...] = ()
    # curriculum
    total_epochs: int = 40
    kappa: float = 1.0 / 6.0
    R: int = 1
    seed: int = 0
    # preprocessing draw seed; None = reuse `seed`.  Kept separate so a
    # session tuning downstream seeds can still share one artifact (the
    # artifact is model-agnostic by design)
    prep_seed: int | None = None
    # device-resident fused training (train.engine): gather batches on
    # device from resident feature/label buffers and fuse `superstep` train
    # steps into one scan dispatch with the state donated.  Falls back to
    # the step loop automatically for pipelines without a column store.
    fused_training: bool = False
    superstep: int = 32
    # downstream classifier training
    lr: float = 0.05
    hidden: int = 64
    # classifier head width; None derives it from the train ∪ eval labels
    # seen by each train() call (train labels alone under-size the head when
    # a class never made it into the training split, and out-of-range eval
    # labels gather clipped logits under jit — silently wrong metrics)
    n_classes: int | None = None
    sub_steps: int = 4
    batch_size: int = 0          # 0 = one full-subset batch per epoch
    eval_every_epochs: int = 1
    # artifact persistence (enables cross-session / cross-model reuse)
    metadata_path: str | None = None
    # -- multi-host execution (distributed.multihost) -----------------------
    # initialize jax.distributed at session construction from the
    # MILO_COORDINATOR / MILO_NUM_PROCESSES / MILO_PROCESS_ID env triplet
    # (idempotent; a no-op when the env does not describe a multi-process
    # job).  After initialization jax.devices() is global, so
    # shard_selection's `sel` mesh — and every collective in core.sharded —
    # spans all hosts with no further knobs; trajectories are bit-identical
    # to a single process exposing the same logical device count.
    multihost_init: bool = False
    # host-liveness beacons for train(): every step boundary writes this
    # host's heartbeat and checks its peers'; a peer stale past the timeout
    # raises HostLossError so the launcher can re-mesh and resume from the
    # last globally-valid checkpoint.  The directory must be shared across
    # the job's hosts.  None = liveness off (single-process default).
    heartbeat_dir: str | None = None
    heartbeat_timeout: float = 60.0

    def preprocessor(self) -> MiloPreprocessor:
        return MiloPreprocessor(
            subset_fraction=self.subset_fraction,
            n_sge_subsets=self.n_sge_subsets,
            eps=self.eps,
            easy_fn=self.easy_fn,
            hard_fn=self.hard_fn,
            graph_cut_lambda=self.graph_cut_lambda,
            classwise=self.classwise,
            metric=self.metric,
            gram_block=self.gram_block,
            use_pallas=self.use_pallas,
            gram_free=self.gram_free,
            bucket_classes=self.bucket_classes,
            sge_vmapped=self.sge_vmapped,
            shard_selection=self.shard_selection,
            lazy_gains=self.lazy_gains,
            lazy_threshold=self.lazy_threshold,
            lazy_two_level=self.lazy_two_level,
            exact_sge_candidates=self.exact_sge_candidates,
            firewall=self.firewall,
            partition=self.partition,
            partition_block=self.partition_block,
            partition_seed=self.partition_seed,
            refine_factor=self.refine_factor,
        )

    def resolved_prep_seed(self) -> int:
        return self.seed if self.prep_seed is None else self.prep_seed

    def expected_artifact_config(self) -> dict[str, Any]:
        """The stored-config keys a reusable artifact must agree on."""
        return {k: getattr(self, k) for k in _PREPROCESS_KEYS}


@dataclasses.dataclass
class TrainReport:
    final_acc: float
    best_acc: float
    train_time: float
    steps: int
    history: list[dict]


class _ClassifierState(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array
    lr0: jax.Array          # () f32 — traced so lr sweeps don't recompile
    total_steps: jax.Array  # () f32


def _init_classifier(
    key, d_in: int, n_classes: int, hidden: int, lr0: float, total_steps: int
) -> _ClassifierState:
    params = init_mlp(key, d_in, n_classes, hidden)
    mom = jax.tree.map(jnp.zeros_like, params)
    return _ClassifierState(
        params, mom, jnp.zeros((), jnp.int32),
        jnp.asarray(lr0, jnp.float32), jnp.asarray(total_steps, jnp.float32),
    )


# One jitted step per sub_steps value, shared across every train()/tune()
# call: lr and horizon live in the (traced) state, so a Hyperband lr sweep
# reuses one compiled executable per batch shape instead of recompiling
# every trial.
_STEP_CACHE: dict[int, Any] = {}


def _classifier_step_fn(sub_steps: int):
    """Weighted-CE Nesterov-SGD step with cosine decay; consumes the plan
    weights the pipeline injects into ``batch["weights"]``."""
    fn = _STEP_CACHE.get(sub_steps)
    if fn is not None:
        return fn

    def train_step(state: _ClassifierState, batch: dict):
        x, y = batch["x"], batch["y"]
        w = batch.get("weights")
        if w is None:
            w = jnp.ones(x.shape[:1], jnp.float32)
        frac = state.step.astype(jnp.float32) / jnp.maximum(state.total_steps - 1.0, 1.0)
        lr = state.lr0 * 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.minimum(frac, 1.0)))

        def one(carry, _):
            params, mom = carry
            l, g = jax.value_and_grad(weighted_nll)(params, x, y, w)
            params, mom = nesterov_update(params, mom, g, lr)
            return (params, mom), l

        (params, mom), losses = jax.lax.scan(
            one, (state.params, state.mom), None, length=sub_steps
        )
        new = _ClassifierState(params, mom, state.step + 1, state.lr0, state.total_steps)
        return new, {"loss": losses[-1]}

    fn = _STEP_CACHE[sub_steps] = jax.jit(train_step)
    return fn




class MiloSession:
    """Facade over preprocess → (many) train → tune."""

    def __init__(
        self,
        config: MiloSessionConfig | None = None,
        *,
        buffer_registry: Any | None = None,
        **overrides: Any,
    ):
        if config is None:
            config = MiloSessionConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        if config.multihost_init:
            multihost.initialize()
        self.config = config
        self.metadata: MiloMetadata | None = None
        self.loaded_from_artifact = False
        # optional repro.serve.BufferRegistry: when attached, train() places
        # its feature/label columns through it, so N sessions over the same
        # dataset share one device buffer per column (fused path only)
        self.buffer_registry = buffer_registry

    # -- stage 1: model-agnostic preprocessing ------------------------------

    def preprocess(
        self,
        features: np.ndarray,
        labels: np.ndarray | None = None,
        *,
        force: bool = False,
        encoder_id: str = "precomputed",
    ) -> MiloMetadata:
        """Run (or load) the one-shot preprocessing pass.

        If ``metadata_path`` names an existing artifact whose config matches
        this session's preprocessing settings, it is loaded instead of
        recomputed — the amortization the paper's speedups rest on.  Pass
        ``force=True`` to recompute regardless.
        """
        cfg = self.config
        if not force and cfg.metadata_path and is_preprocessed(cfg.metadata_path):
            md = self._load_artifact(encoder_id, _data_fingerprint(features))
            if md.m != len(features):
                raise MetadataMismatchError(
                    f"{cfg.metadata_path}: artifact was preprocessed over "
                    f"{md.m} samples but this dataset has {len(features)} — "
                    "same config, different data; pass force=True to rebuild"
                )
            self.metadata = md
            self.loaded_from_artifact = True
            return self.metadata
        md = self.build_metadata(features, labels, encoder_id=encoder_id)
        if cfg.metadata_path:
            md.save(cfg.metadata_path)
        self.metadata = md
        self.loaded_from_artifact = False
        return md

    def build_metadata(
        self,
        features: np.ndarray,
        labels: np.ndarray | None = None,
        *,
        encoder_id: str = "precomputed",
        fingerprint: str | None = None,
    ) -> MiloMetadata:
        """The pure compute unit behind ``preprocess``: run the model-agnostic
        pass and return the stamped artifact WITHOUT touching session state or
        the configured ``metadata_path``.

        This is what a serving layer (``repro.serve.ArtifactStore``) calls as
        its build function — the store owns persistence and caching, so the
        session must not also write files or mutate ``self.metadata`` here.
        The data fingerprint is always stamped (callers may pass a
        precomputed one to skip rehashing the feature matrix).
        """
        cfg = self.config
        md = cfg.preprocessor().preprocess(
            features, labels, jax.random.PRNGKey(cfg.resolved_prep_seed()),
            encoder_id=encoder_id, prep_seed=cfg.resolved_prep_seed(),
        )
        md.config["data_fingerprint"] = (
            fingerprint if fingerprint is not None
            else _data_fingerprint(features)
        )
        return md

    def adopt_metadata(
        self, md: MiloMetadata, *, loaded: bool = True
    ) -> MiloMetadata:
        """Install an externally owned artifact (e.g. one the serving layer's
        store built or reloaded) as this session's preprocessing result, after
        the same config verification a ``metadata_path`` load applies."""
        expected = self.config.expected_artifact_config()
        bad = {
            k: (md.config.get(k), v)
            for k, v in expected.items()
            if k in md.config and md.config.get(k) != v
        }
        if bad:
            raise MetadataMismatchError(
                f"adopted artifact: config mismatch on {bad} (stored, expected)"
            )
        stored_seed = md.config.get("prep_seed")
        expected_seed = self.config.resolved_prep_seed()
        if stored_seed is not None and stored_seed != expected_seed:
            raise MetadataMismatchError(
                "adopted artifact: config mismatch on "
                f"{{'prep_seed': ({stored_seed}, {expected_seed})}} "
                "(stored, expected)"
            )
        self._check_partition_config(md, "adopted artifact")
        self.metadata = md
        self.loaded_from_artifact = loaded
        return md

    def _check_partition_config(self, md: MiloMetadata, where: str) -> None:
        """Hierarchical provenance guard shared by artifact load and adopt.

        Partition keys are stamped only when the hierarchical path is active
        (see ``MiloPreprocessor._preprocess_clean``), so absence means the
        flat path: legacy flat artifacts keep loading into flat sessions,
        while any partition/refine disagreement — including a hierarchical
        session reading a flat artifact, whose bank was built over a
        different decomposition — refuses."""
        cfg = self.config
        stored_part = md.config.get("partition", "by_class")
        stored_rf = int(md.config.get("refine_factor", 1))
        want_rf = max(1, int(cfg.refine_factor))
        bad: dict[str, tuple] = {}
        if stored_part != cfg.partition:
            bad["partition"] = (stored_part, cfg.partition)
        if stored_rf != want_rf:
            bad["refine_factor"] = (stored_rf, want_rf)
        # block/seed are stamped only by the strategies that depend on them
        for key, want in (("partition_block", cfg.partition_block),
                          ("partition_seed", cfg.partition_seed)):
            if key in md.config and int(md.config[key]) != int(want):
                bad[key] = (md.config[key], want)
        if bad:
            raise MetadataMismatchError(
                f"{where}: config mismatch on {bad} (stored, expected)"
            )

    def _load_artifact(
        self,
        encoder_id: str | None = None,
        data_fingerprint: str | None = None,
    ) -> MiloMetadata:
        """Load + verify the configured artifact.  The SGE bank is a
        stochastic-greedy draw, so a *recorded* preprocessing seed must match
        this session's; artifacts from other entry points (direct
        ``MiloPreprocessor``, pre-header formats) record no seed and are
        accepted on config alone.  When the caller knows which encoder
        produced its features, the artifact's recorded encoder must agree —
        subsets selected over one representation are meaningless for another."""
        cfg = self.config
        md = MiloMetadata.load(
            cfg.metadata_path, expected_config=cfg.expected_artifact_config()
        )
        stored_enc = md.config.get("encoder_id")
        if (encoder_id is not None and stored_enc is not None
                and stored_enc != encoder_id):
            raise MetadataMismatchError(
                f"{cfg.metadata_path}: config mismatch on "
                f"{{'encoder_id': ({stored_enc!r}, {encoder_id!r})}} "
                "(stored, expected)"
            )
        stored_fp = md.config.get("data_fingerprint")
        if (data_fingerprint is not None and stored_fp is not None
                and stored_fp != data_fingerprint):
            raise MetadataMismatchError(
                f"{cfg.metadata_path}: artifact was preprocessed over "
                "different data (feature fingerprint mismatch); pass "
                "force=True to rebuild"
            )
        # gram_free / bucket_classes / lazy_gains / exact_sge_candidates
        # change which selection trajectories the artifact holds, so a
        # recorded value must agree; artifacts from before these knobs
        # existed record neither and are accepted on the base config alone
        # (same tolerance as prep_seed below).  shard_selection is recorded
        # but deliberately NOT checked: sharded runs select identically to
        # single-device up to sub-ulp near-tie resolution (see core.sharded),
        # an accepted tolerance so artifacts stay portable across meshes —
        # including lazy+sharded runs, where the trajectory-affecting knobs
        # (lazy_gains, lazy_threshold) ARE checked and the mesh still is not.
        for knob in ("gram_free", "bucket_classes", "lazy_gains",
                     "exact_sge_candidates"):
            stored_knob = md.config.get(knob)
            expected_knob = getattr(cfg, knob)
            if stored_knob is not None and bool(stored_knob) != expected_knob:
                raise MetadataMismatchError(
                    f"{cfg.metadata_path}: config mismatch on "
                    f"{{{knob!r}: ({stored_knob}, {expected_knob})}} "
                    "(stored, expected)"
                )
        # with lazy gains active the recompute threshold shapes the drift
        # cadence (and thus near-tie resolution), so it must agree too
        stored_thr = md.config.get("lazy_threshold")
        if (cfg.lazy_gains and bool(md.config.get("lazy_gains"))
                and stored_thr is not None
                and float(stored_thr) != cfg.lazy_threshold):
            raise MetadataMismatchError(
                f"{cfg.metadata_path}: config mismatch on "
                f"{{'lazy_threshold': ({stored_thr}, {cfg.lazy_threshold})}} "
                "(stored, expected)"
            )
        stored_seed = md.config.get("prep_seed")
        expected_seed = cfg.resolved_prep_seed()
        if stored_seed is not None and stored_seed != expected_seed:
            raise MetadataMismatchError(
                f"{cfg.metadata_path}: config mismatch on "
                f"{{'prep_seed': ({stored_seed}, {expected_seed})}} "
                "(stored, expected) — set MiloSessionConfig.prep_seed="
                f"{stored_seed} to reuse this artifact with a different "
                "training seed"
            )
        # repair/quarantine rewrite the effective ground set, so an artifact
        # that RECORDS a firewall policy must agree with this session's;
        # pre-firewall artifacts record none and are accepted on the base
        # config (same legacy tolerance as the knobs above)
        stored_fw = md.config.get("firewall")
        if "firewall" in md.config and stored_fw != cfg.firewall:
            raise MetadataMismatchError(
                f"{cfg.metadata_path}: config mismatch on "
                f"{{'firewall': ({stored_fw!r}, {cfg.firewall!r})}} "
                "(stored, expected)"
            )
        # hierarchical decomposition provenance: the bank's indices are only
        # meaningful for the partition geometry + refine factor they were
        # selected under
        self._check_partition_config(md, str(cfg.metadata_path))
        return md

    def _require_metadata(
        self, n: int | None = None, features: np.ndarray | None = None
    ) -> MiloMetadata:
        if self.metadata is None:
            if self.config.metadata_path and is_preprocessed(self.config.metadata_path):
                self.metadata = self._load_artifact(
                    data_fingerprint=(
                        _data_fingerprint(features) if features is not None else None
                    ),
                )
                self.loaded_from_artifact = True
            else:
                raise MetadataMismatchError(
                    "no preprocessing artifact: call session.preprocess(...) first"
                )
        if n is not None and self.metadata.m != n:
            raise MetadataMismatchError(
                f"preprocessing artifact covers {self.metadata.m} samples but "
                f"this dataset has {n} — same config, different data"
            )
        return self.metadata

    # -- registry wiring ----------------------------------------------------

    def selector(
        self,
        name: str | None = None,
        *,
        n: int,
        epochs: int | None = None,
        seed: int | None = None,
        features: np.ndarray | None = None,
        **extra: Any,
    ) -> Selector:
        """Build this session's selector from the registry.

        ``milo``/``milo_fixed``/``full``/``random``/``adaptive_random`` are
        wired from session state; other strategies (el2n, craig_pb, ...) take
        their inputs (scores, grad_fn, ...) through ``extra``.

        With ``config.selector_fallback`` declared, the result is a
        ``repro.health.FallbackSelector`` walking ``(primary, *fallbacks)``:
        degenerate selection math degrades down the chain (with plan
        provenance recording every hop) instead of crashing the run.  The
        fallback tiers are wired from session state only (``extra`` kwargs
        apply to the primary).
        """
        cfg = self.config
        resolved = name or cfg.selector
        if not cfg.selector_fallback:
            return self._build_selector(
                resolved, n=n, epochs=epochs, seed=seed,
                features=features, **extra,
            )
        from repro.health.fallback import FallbackSelector

        def factory(nm: str, ex: dict):
            return lambda: self._build_selector(
                nm, n=n, epochs=epochs, seed=seed, features=features, **ex)

        chain = [(resolved, factory(resolved, dict(extra)))]
        chain += [(fb, factory(fb, {})) for fb in cfg.selector_fallback]
        return FallbackSelector(chain)

    def _build_selector(
        self,
        name: str | None = None,
        *,
        n: int,
        epochs: int | None = None,
        seed: int | None = None,
        features: np.ndarray | None = None,
        **extra: Any,
    ) -> Selector:
        cfg = self.config
        name = name or cfg.selector
        epochs = epochs if epochs is not None else cfg.total_epochs
        seed = seed if seed is not None else cfg.seed
        explicit_k = "k" in extra
        k = extra.pop("k", None)
        if k is None:
            k = (self.metadata.k if self.metadata is not None
                 else max(1, int(round(cfg.subset_fraction * n))))
        if name == "milo":
            md = self._require_metadata(n, features)
            if explicit_k and k != md.k:
                raise ValueError(
                    f"milo's subset size is fixed by the preprocessing "
                    f"artifact (k={md.k}); rebuild the artifact to change it"
                )
            return build_selector(
                "milo", metadata=md, total_epochs=epochs,
                kappa=cfg.kappa, R=cfg.R, seed=seed, **extra,
            )
        if name == "milo_fixed":
            if features is None:
                raise ValueError("milo_fixed needs `features`")
            return build_selector("milo_fixed", features=features, k=k, **extra)
        if name == "full":
            if explicit_k:
                raise ValueError("selector 'full' trains on the whole dataset; "
                                 "`k` is not applicable")
            return build_selector("full", n=n, **extra)
        if name == "random":
            return build_selector("random", n=n, k=k, seed=seed, **extra)
        if name == "adaptive_random":
            return build_selector(
                "adaptive_random", n=n, k=k, R=extra.pop("R", cfg.R), seed=seed, **extra
            )
        # other strategies (el2n, selfsup_prune, craig_pb, ...): forward the
        # session context for every field their config actually declares
        fields = {f.name for f in dataclasses.fields(selector_entry(name).config_cls)}
        kwargs = dict(extra)
        for key, val in (("k", k), ("n", n), ("seed", seed), ("features", features)):
            if key in fields and val is not None:
                kwargs.setdefault(key, val)
        return build_selector(name, **kwargs)

    def pipeline(
        self,
        make_batch,
        selector: Selector,
        batch_size: int,
        *,
        seed: int | None = None,
        prefetch: bool = True,
        arrays: dict | None = None,
        resident: dict | None = None,
    ) -> pipeline_mod.Pipeline:
        if resident is None and arrays is not None and self.buffer_registry is not None:
            resident = self.buffer_registry.get(arrays)
        return pipeline_mod.Pipeline(
            make_batch, selector, batch_size,
            seed=self.config.seed if seed is None else seed,
            prefetch=prefetch,
            arrays=arrays,
            resident=resident,
        )

    # -- stage 2: train any number of downstream models ---------------------

    def train(
        self,
        features: np.ndarray,
        labels: np.ndarray,
        *,
        test_x: np.ndarray,
        test_y: np.ndarray,
        selector: str | Selector | None = None,
        epochs: int | None = None,
        seed: int | None = None,
        lr: float | None = None,
        hidden: int | None = None,
        **selector_kwargs: Any,
    ) -> TrainReport:
        """Train one downstream classifier on registry-selected subsets."""
        cfg = self.config
        epochs = epochs if epochs is not None else cfg.total_epochs
        seed = seed if seed is not None else cfg.seed
        lr = lr if lr is not None else cfg.lr
        hidden = hidden if hidden is not None else cfg.hidden
        n = len(features)
        if isinstance(selector, Selector) or hasattr(selector, "plan"):
            if selector_kwargs:
                raise ValueError(
                    "selector is already a built instance; selector kwargs "
                    f"{sorted(selector_kwargs)} would be silently ignored — "
                    "pass a registry name to build from config"
                )
            sel = selector
        else:
            sel = self.selector(
                selector, n=n, epochs=epochs, seed=seed,
                features=features, **selector_kwargs,
            )

        feats = np.asarray(features, np.float32)
        labs = np.asarray(labels, np.int64)
        # size the head over every label the run will see: a test/val class
        # absent from the training split must still own a logit, or accuracy
        # gathers out-of-bounds (clipped under jit → silently wrong)
        max_label = int(max(labs.max(), np.asarray(test_y).max()))
        if cfg.n_classes is None:
            n_classes = max_label + 1
        elif cfg.n_classes <= max_label:
            raise ValueError(
                f"n_classes={cfg.n_classes} cannot cover label {max_label} "
                "present in the train/eval data — the override may only "
                "widen the head, never reintroduce clipped-logit metrics"
            )
        else:
            n_classes = cfg.n_classes

        def make_batch(idx: np.ndarray) -> dict:
            return {"x": feats[idx], "y": labs[idx]}

        # validate against THIS dataset: catches a loaded artifact whose
        # indices were selected over different data
        plan0 = sel.plan(0).validate(n)
        batch_size = cfg.batch_size or plan0.k
        if batch_size > plan0.k:
            raise ValueError(
                f"batch_size={batch_size} exceeds the selected subset size "
                f"k={plan0.k}; every epoch would yield zero batches"
            )
        # host batches here are cheap slices; prefetch=False keeps the epoch
        # iterator plain so the warm-up read below can't strand a worker.
        # The column store mirrors make_batch exactly, enabling the fused
        # device-resident path when cfg.fused_training asks for it.
        pipe = self.pipeline(
            make_batch, sel, batch_size, seed=seed, prefetch=False,
            arrays={"x": feats, "y": labs},
        )
        steps = max(1, pipe.steps_per_epoch()) * epochs
        train_step = _classifier_step_fn(cfg.sub_steps)

        def init_state():
            return _init_classifier(
                jax.random.PRNGKey(seed), feats.shape[1], n_classes,
                hidden, float(lr), steps,
            )

        state = init_state()
        tx, ty = jnp.asarray(test_x), jnp.asarray(test_y)

        def acc_fn(params):
            # module-level jit (shared with the benchmarks): one compiled
            # eval per test-set shape across all train()/tune() calls
            return accuracy(params, tx, ty)

        def eval_fn(st: _ClassifierState) -> dict:
            return {"acc": acc_fn(st.params)}

        trainer = Trainer(
            train_step, pipe,
            TrainerConfig(
                epochs=epochs, eval_every_epochs=cfg.eval_every_epochs,
                log_every_steps=1,
                heartbeat_dir=cfg.heartbeat_dir,
                heartbeat_timeout=cfg.heartbeat_timeout,
            ),
            eval_fn=eval_fn,
            fused=cfg.fused_training,
            superstep=cfg.superstep,
        )
        # warm the jit caches outside the timed region so selector comparisons
        # measure steady-state epochs, not compilation — including BOTH
        # curriculum phases (the first WRE draw compiles threefry/top_k);
        # skip for windowed selectors where a late plan() forces a wasted
        # re-selection
        if plan0.phase in ("sge", "wre"):
            _ = sel.plan(max(epochs - 1, 0))
        warm_batch = next(iter(pipe.epoch(0)))
        ws, _ = trainer.train_step(state, warm_batch)
        jax.block_until_ready(acc_fn(ws.params))
        # the fused path adds its own (segment-shaped) programs: compile them
        # on a throwaway state — donation invalidates ITS buffers, not ours
        if trainer.fused_active():
            trainer.warm_fused(init_state())
        # charge per-window/per-epoch selection to the timed region exactly
        # as benchmarks/common.py does — that cost is the paper's argument;
        # dropping BOTH caches keeps epoch 0's subset identical to the rest
        # of its R-window (one recompute inside fit, then memoized)
        getattr(sel, "reset_cache", lambda: None)()
        pipe.invalidate_plan_cache()

        t0 = time.perf_counter()
        state = trainer.fit(state, resume=False)
        train_time = time.perf_counter() - t0
        # always evaluate the FINAL state: history's last eval can be epochs
        # old when eval_every_epochs does not divide epochs
        final = float(acc_fn(state.params))
        accs = [float(h["acc"]) for h in trainer.history if "acc" in h] + [final]
        return TrainReport(
            final_acc=final,
            best_acc=max(accs),
            train_time=train_time,
            steps=int(state.step),
            history=trainer.history,
        )

    # -- stage 3: hyper-parameter tuning ------------------------------------

    def tune(
        self,
        features: np.ndarray,
        labels: np.ndarray,
        val_x: np.ndarray,
        val_y: np.ndarray,
        space: dict,
        *,
        selector: str | None = None,
        search: str = "tpe",
        max_budget: int = 9,
        eta: int = 3,
        seed: int | None = None,
        batched_objective: Any | None = None,
        should_stop: Any | None = None,
        checkpoint: str | None = None,
        **selector_kwargs: Any,
    ) -> HyperbandResult:
        """Hyperband over ``space`` with registry-selected subsets powering
        every configuration evaluation (paper §4's 20-75x tuning speedups).

        ``batched_objective(configs, budget) -> scores`` opts a rung into one
        batched evaluation of all its surviving configs (e.g. a trial scan
        vmapped over ``tuner.stack_configs`` leaves — possible whenever the
        space varies only traced leaves like ``lr``, not shapes like
        ``hidden``); trials fall back to the sequential per-config loop
        otherwise.  ``should_stop()`` is polled before every rung (see
        ``tuning.hyperband``) — the serving layer's cancellation/deadline
        hook; an early stop returns ``stopped=True``.  ``checkpoint`` names a
        JSON rung-state file making the sweep crash-safe: a killed sweep
        relaunched with the same arguments resumes at its rung boundary and
        reproduces the identical trial stream and ``best_config`` (see
        ``tuning.hyperband``)."""
        cfg = self.config
        seed = seed if seed is not None else cfg.seed
        tunable = {"lr", "hidden"}
        unknown = set(space) - tunable
        if unknown:
            raise ValueError(
                f"tune() searches over {sorted(tunable)}; unsupported space "
                f"keys {sorted(unknown)} would be sampled but never applied"
            )
        searches = {"tpe": TPESearch, "random": RandomSearch}
        if search not in searches:
            raise ValueError(
                f"unknown search {search!r}; available: {sorted(searches)}"
            )
        search_obj = searches[search](space, seed=seed)

        def train_fn(trial_cfg: dict, budget: int, sel) -> float:
            report = self.train(
                features, labels, test_x=val_x, test_y=val_y,
                selector=sel, epochs=max(2, budget), seed=seed,
                lr=trial_cfg.get("lr"), hidden=trial_cfg.get("hidden"),
            )
            return report.final_acc

        def selector_factory(budget: int):
            return self.selector(
                selector, n=len(features), epochs=max(2, budget), seed=seed,
                features=features, **selector_kwargs,
            )

        objective = subset_objective(train_fn, selector_factory)
        return hyperband(objective, search_obj, max_budget=max_budget, eta=eta,
                         batched_objective=batched_objective,
                         should_stop=should_stop, checkpoint=checkpoint)
