"""Hyper-parameter tuning: Random / TPE search + Hyperband scheduling, with
MILO (or baseline) subsets powering the configuration evaluations — the
AUTOMATA-style pipeline of paper §4 / Fig. 8.

Components (paper's three):
  a) search algorithms  — RandomSearch, TPESearch (kernel-density TPE),
  b) config evaluation  — ``objective(config, budget_epochs)``; use
     ``subset_objective`` to wire a ``repro.selection`` registry selector
     into every evaluation,
  c) scheduler          — Hyperband successive halving.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Any, Callable

import numpy as np

Space = dict[str, Any]  # name -> ("uniform", lo, hi) | ("log", lo, hi) | ("choice", [..])


def sample_config(space: Space, rng: np.random.Generator) -> dict:
    cfg = {}
    for name, spec in space.items():
        kind = spec[0]
        if kind == "uniform":
            cfg[name] = float(rng.uniform(spec[1], spec[2]))
        elif kind == "log":
            cfg[name] = float(np.exp(rng.uniform(np.log(spec[1]), np.log(spec[2]))))
        elif kind == "choice":
            cfg[name] = spec[1][int(rng.integers(len(spec[1])))]
        else:
            raise ValueError(kind)
    return cfg


class _RngStateMixin:
    """Serializable draw state for search algorithms.

    The searches are deterministic functions of (seed, suggestion history),
    so snapshotting the generator's bit state at a rung boundary and
    restoring it on resume replays the exact same future suggestions — the
    property hyperband's checkpointing relies on for identical trial
    streams across a kill/restart.
    """

    def get_state(self) -> dict:
        return {"rng": self._rng.bit_generator.state}

    def set_state(self, state: dict) -> None:
        self._rng.bit_generator.state = state["rng"]


@dataclasses.dataclass
class RandomSearch(_RngStateMixin):
    space: Space
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def suggest(self, history: list[tuple[dict, float]]) -> dict:
        return sample_config(self.space, self._rng)


@dataclasses.dataclass
class TPESearch(_RngStateMixin):
    """Tree-structured Parzen Estimator (continuous dims via KDE, choices via
    re-weighted categorical)."""

    space: Space
    seed: int = 0
    gamma: float = 0.25
    n_candidates: int = 24
    min_history: int = 8

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def suggest(self, history: list[tuple[dict, float]]) -> dict:
        if len(history) < self.min_history:
            return sample_config(self.space, self._rng)
        scores = np.asarray([s for _, s in history])
        cut = np.quantile(scores, 1 - self.gamma)     # maximize score
        good = [c for c, s in history if s >= cut]
        bad = [c for c, s in history if s < cut]
        cands = [sample_config(self.space, self._rng) for _ in range(self.n_candidates)]

        def logpdf(cfg: dict, group: list[dict]) -> float:
            if not group:
                return 0.0
            lp = 0.0
            for name, spec in self.space.items():
                kind = spec[0]
                v = cfg[name]
                if kind == "choice":
                    counts = sum(1 for g in group if g[name] == v) + 1.0
                    lp += math.log(counts / (len(group) + len(spec[1])))
                else:
                    xs = np.asarray([g[name] for g in group], float)
                    if kind == "log":
                        xs, vv = np.log(xs), math.log(v)
                        bw = max((math.log(spec[2]) - math.log(spec[1])) / 8, 1e-3)
                    else:
                        vv = v
                        bw = max((spec[2] - spec[1]) / 8, 1e-6)
                    lp += math.log(
                        np.mean(np.exp(-0.5 * ((vv - xs) / bw) ** 2)) / bw + 1e-12
                    )
            return lp

        ratios = [logpdf(c, good) - logpdf(c, bad) for c in cands]
        return cands[int(np.argmax(ratios))]


@dataclasses.dataclass
class HyperbandResult:
    best_config: dict
    best_score: float
    trials: list[dict]
    total_epochs: int
    wall_time: float
    # True when a ``should_stop`` hook ended the run early (server-driven
    # cancellation / deadline): best_config/trials cover the rungs that
    # actually ran.  A completed run always records False.
    stopped: bool = False
    # Evaluations quarantined by the trial guard: the objective raised or
    # returned a non-finite score, the trial was recorded failed-with--inf
    # and the sweep continued (see hyperband docstring).
    failed_trials: int = 0


def subset_objective(
    train_fn: Callable[[dict, int, Any], float],
    selector_factory: Callable[[int], Any],
) -> Callable[[dict, int], float]:
    """Adapt a (config, budget, selector) -> score trainer to hyperband's
    two-argument objective protocol, building a fresh subset selector (e.g.
    from ``repro.selection.build_selector``) for each evaluation so trials
    never share per-epoch draw state."""

    def objective(cfg: dict, budget: int) -> float:
        return train_fn(cfg, budget, selector_factory(budget))

    return objective


def stack_configs(configs: list[dict]) -> dict[str, np.ndarray]:
    """Stack per-config hyperparameter values into one array per name.

    The adapter between hyperband's list-of-dicts rung and a vmapped
    objective: ``stack_configs([{"lr": a}, {"lr": b}])["lr"]`` is the
    ``(2,)`` array a ``jax.vmap``-ed trial function maps over.  All configs
    must share the same keys (hyperband rungs always do — one search space).
    """
    if not configs:
        raise ValueError("no configs to stack")
    keys = set(configs[0])
    for c in configs[1:]:
        if set(c) != keys:
            raise ValueError(
                f"configs disagree on keys: {sorted(keys)} vs {sorted(c)}"
            )
    return {k: np.asarray([c[k] for c in configs]) for k in sorted(keys)}


def shape_bucketed_objective(
    batched_fn: Callable[[list[dict], int], Any],
    shape_keys: tuple[str, ...] = ("hidden",),
) -> Callable[[list[dict], int], list[float]]:
    """Make a ``batched_objective`` safe for shape-changing hyperparameters.

    A vmapped trial function can only batch configs whose traced shapes
    agree — a rung mixing ``hidden=8`` and ``hidden=16`` networks cannot be
    stacked into one ``vmap``.  This wrapper groups the rung's configs by
    the values of ``shape_keys`` (first-appearance order, so the inner
    function sees deterministic bucket order), calls ``batched_fn`` once
    per bucket, and scatters the scores back into the original config
    order.  The trial stream and ``best_config`` are identical to feeding
    the rung through ``batched_fn`` directly when all shapes agree: one
    bucket → one pass-through call.
    """

    def objective(configs: list[dict], budget: int) -> list[float]:
        buckets: dict[tuple, list[int]] = {}
        for i, cfg in enumerate(configs):
            sig = tuple((key, cfg[key]) for key in shape_keys if key in cfg)
            buckets.setdefault(sig, []).append(i)
        scores: list[float | None] = [None] * len(configs)
        for sig, idxs in buckets.items():
            vals = [float(v) for v in
                    batched_fn([configs[i] for i in idxs], budget)]
            if len(vals) != len(idxs):
                raise ValueError(
                    f"batched_fn returned {len(vals)} scores for "
                    f"{len(idxs)} configs (shape bucket {sig})")
            for i, v in zip(idxs, vals):
                scores[i] = v
        return [float(s) for s in scores]

    return objective


#: hyperband checkpoint file format version
HB_CHECKPOINT_FORMAT = 1


def _hb_identity(search, max_budget: int, eta: int) -> dict:
    """What a resumable sweep must agree on: the schedule geometry and the
    search algorithm + space (canonical JSON — tuples/lists unified)."""
    return {
        "max_budget": int(max_budget),
        "eta": int(eta),
        "search": type(search).__name__,
        "space": json.dumps(getattr(search, "space", None), sort_keys=True,
                            default=str),
    }


def _hb_write_checkpoint(path: str, state: dict) -> None:
    """Atomic write-then-rename, fsync'd — a kill mid-write leaves the
    previous rung's state intact, never a torn file."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(state, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


#: Keys every complete rung checkpoint carries (see ``write_state``): a
#: file missing any of them is torn/partial even when it parses as JSON.
_HB_REQUIRED_KEYS = (
    "bracket", "rung", "configs", "bracket_n", "trials", "history",
    "best_config", "best_score", "total_epochs", "search_state", "wall_time",
)


def _hb_load_checkpoint(path: str, identity: dict) -> dict | None:
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            state = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(
            f"{path}: corrupt hyperband checkpoint ({e}); delete it to "
            "restart the sweep from scratch"
        )
    if not isinstance(state, dict):
        raise ValueError(
            f"{path}: corrupt hyperband checkpoint (top-level JSON is "
            f"{type(state).__name__}, expected object); delete it to "
            "restart the sweep from scratch"
        )
    if state.get("format") != HB_CHECKPOINT_FORMAT:
        raise ValueError(
            f"{path}: hyperband checkpoint format "
            f"{state.get('format')} != {HB_CHECKPOINT_FORMAT}"
        )
    if state.get("identity") != identity:
        raise ValueError(
            f"{path}: checkpoint belongs to a different sweep "
            f"(stored {state.get('identity')}, this run {identity}); "
            "point `checkpoint` elsewhere or delete the file"
        )
    # a truncated file whose prefix still parses (or a write interrupted
    # between schema versions) must surface as the same clean identity
    # error, not as a KeyError deep inside the resume bookkeeping
    missing = [k for k in _HB_REQUIRED_KEYS if k not in state]
    if missing:
        raise ValueError(
            f"{path}: corrupt hyperband checkpoint (missing keys "
            f"{missing}); delete it to restart the sweep from scratch"
        )
    return state


def hyperband(
    objective: Callable[[dict, int], float] | None,
    search,
    *,
    max_budget: int = 27,
    eta: int = 3,
    seed: int = 0,
    batched_objective: Callable[[list[dict], int], Any] | None = None,
    should_stop: Callable[[], bool] | None = None,
    checkpoint: str | None = None,
) -> HyperbandResult:
    """Hyperband [Li'17]: brackets of successive halving.

    ``objective(config, budget_epochs) -> score`` (higher better); evaluations
    with larger budget may warm-start (caller's choice).

    ``batched_objective(configs, budget_epochs) -> scores`` evaluates ALL
    surviving configs of a rung in one call — the opt-in that lets a vmapped
    trial function (stack the hyperparameter leaves with ``stack_configs``,
    vmap the training scan over them) collapse a rung's Python trial
    serialization into one dispatch.  Bookkeeping (history order, trials,
    best tracking, halving) is identical to the sequential path, so two runs
    whose objectives return the same scores produce the identical
    ``best_config`` and trial set.  When provided, ``objective`` may be None.

    **Trial quarantine:** a sequential ``objective`` that raises, or an
    evaluation (either path) that returns a non-finite score, marks that
    trial failed-with--inf — recorded on the trial dict as
    ``failed``/``error`` — and the sweep continues; one poisoned config can
    no longer kill a whole sweep.  Failed evaluations lose every halving
    comparison, so they never advance a rung, and ``best_config`` over the
    surviving trials is identical to a sweep where the failing configs
    scored arbitrarily badly.  Only when EVERY evaluation failed does the
    sweep raise (``RuntimeError`` carrying the first error) — an
    all-failing objective is a harness bug, not bad luck.  Exceptions from
    ``batched_objective`` still propagate: one call covers the whole rung,
    so there is no per-trial failure to isolate.

    ``should_stop()`` is polled before every rung evaluation — the
    server-driven hook (``repro.serve.MiloServer``) that lets a tuning
    request honor a deadline or cancellation between rungs.  A True poll
    ends the run immediately; the result carries ``stopped=True`` and the
    best config among the rungs that completed (None if none did).

    ``checkpoint`` names a JSON state file making the sweep crash-safe at
    rung granularity: after every completed rung the full scheduler state
    (bracket, rung, surviving configs, trials, best, total epochs, search
    RNG bit state) is written atomically.  A killed sweep relaunched with
    the same arguments resumes at the rung it died in and produces the
    IDENTICAL trial stream and ``best_config`` as an uninterrupted run —
    the search RNG is restored bit-exactly, so every future suggestion
    matches.  A checkpoint from a different sweep (schedule, search class,
    or space disagree) raises instead of silently mixing runs; a finished
    sweep short-circuits and returns its recorded result.
    """
    if objective is None and batched_objective is None:
        raise ValueError("provide objective or batched_objective")
    t0 = time.time()
    s_max = int(math.log(max_budget, eta))
    trials: list[dict] = []
    history: list[tuple[dict, float]] = []
    best_config, best_score = None, -np.inf
    total_epochs = 0
    stopped = False
    failed = 0
    first_error: str | None = None

    identity = _hb_identity(search, max_budget, eta)
    resume = _hb_load_checkpoint(checkpoint, identity) if checkpoint else None
    if resume is not None:
        try:
            trials = resume["trials"]
            history = [(c, float(v)) for c, v in resume["history"]]
            best_config = resume["best_config"]
            best_score = float(resume["best_score"])
            total_epochs = int(resume["total_epochs"])
            search.set_state(resume["search_state"])
        except (KeyError, TypeError, ValueError) as e:
            # belt-and-braces behind _hb_load_checkpoint's key check:
            # malformed VALUES surface as the same clean identity error
            raise ValueError(
                f"{checkpoint}: corrupt hyperband checkpoint ({e!r}); "
                "delete it to restart the sweep from scratch") from e
        failed = sum(1 for t in trials if t.get("failed"))
        if resume.get("done"):
            return HyperbandResult(best_config, best_score, trials,
                                   total_epochs, float(resume["wall_time"]),
                                   stopped=False, failed_trials=failed)

    def write_state(bracket: int, rung: int, configs, n: int | None,
                    done: bool) -> None:
        if checkpoint is None:
            return
        _hb_write_checkpoint(checkpoint, {
            "format": HB_CHECKPOINT_FORMAT,
            "identity": identity,
            "bracket": bracket,
            "rung": rung,
            "configs": configs,
            "bracket_n": n,
            "trials": trials,
            "history": [[c, v] for c, v in history],
            "best_config": best_config,
            "best_score": (float(best_score) if best_config is not None
                           else -1e308),
            "total_epochs": total_epochs,
            "search_state": search.get_state(),
            "wall_time": time.time() - t0,
            "done": done,
        })

    for s in range(s_max, -1, -1):
        if stopped:
            break
        if resume is not None and s > resume["bracket"]:
            continue  # bracket completed before the crash; results restored
        if resume is not None and s == resume["bracket"] and resume["configs"] is not None:
            # resume mid-bracket: survivors + rung index from the checkpoint,
            # suggestions already drawn (the restored RNG state follows them)
            n = int(resume["bracket_n"])
            configs = resume["configs"]
            first_rung = int(resume["rung"])
        else:
            n = int(math.ceil((s_max + 1) / (s + 1) * eta ** s))
            configs = [search.suggest(history) for _ in range(n)]
            first_rung = 0
        resume = None
        r = max_budget * eta ** (-s)
        for i in range(first_rung, s + 1):
            if should_stop is not None and should_stop():
                stopped = True
                break
            n_i = int(n * eta ** (-i))
            r_i = max(1, int(round(r * eta ** i)))
            # (score, error): error is None for a healthy evaluation; a
            # raised/non-finite evaluation is quarantined at -inf so it
            # loses every halving comparison but cannot kill the sweep
            outcomes: list[tuple[float, str | None]] = []
            if batched_objective is not None:
                scores = [float(v) for v in batched_objective(list(configs), r_i)]
                if len(scores) != len(configs):
                    raise ValueError(
                        f"batched_objective returned {len(scores)} scores "
                        f"for {len(configs)} configs"
                    )
                outcomes = [
                    (v, None) if math.isfinite(v)
                    else (-np.inf, f"non-finite score {v!r}")
                    for v in scores
                ]
            else:
                for cfg in configs:
                    try:
                        v = float(objective(cfg, r_i))
                    except Exception as e:  # noqa: BLE001 — trial isolation
                        outcomes.append((-np.inf, repr(e)))
                    else:
                        outcomes.append(
                            (v, None) if math.isfinite(v)
                            else (-np.inf, f"non-finite score {v!r}"))
            results = [v for v, _ in outcomes]
            for cfg, (score, err) in zip(configs, outcomes):
                total_epochs += r_i
                history.append((cfg, score))
                trial = {"config": cfg, "budget": r_i, "score": score,
                         "bracket": s}
                if err is not None:
                    trial["failed"] = True
                    trial["error"] = err
                    failed += 1
                    if first_error is None:
                        first_error = err
                trials.append(trial)
                if score > best_score:
                    best_config, best_score = cfg, score
            order = np.argsort(results)[::-1]
            keep = max(1, int(n_i / eta))
            configs = [configs[j] for j in order[:keep]]
            # rung boundary: persist the full scheduler state (crash-safe
            # resume point).  The final rung of bracket 0 marks the sweep
            # done; the final rung of any other bracket arms the next one.
            if i == s:
                write_state(s - 1, 0, None, None, done=(s == 0))
            else:
                write_state(s, i + 1, configs, n, done=False)
            if len(configs) <= 1 and i < s:
                # nothing left to halve; finish bracket with the survivor
                continue
    if trials and failed == len(trials):
        raise RuntimeError(
            f"hyperband: all {len(trials)} trial evaluations failed "
            f"(first error: {first_error}) — quarantine keeps a sweep "
            "alive through bad configs, not through a broken objective")
    return HyperbandResult(best_config, float(best_score), trials, total_epochs,
                           time.time() - t0, stopped=stopped,
                           failed_trials=failed)


def kendall_tau(a: np.ndarray, b: np.ndarray) -> float:
    """Kendall rank correlation between two score vectors (paper Tab. 9).

    Vectorized sign-outer-product form: over the strict upper triangle of
    pairwise score differences, a pair is concordant when the signs agree
    (product +1), discordant when they disagree (-1), and dropped from both
    numerator and denominator when either vector ties on it — the exact
    semantics of the former O(n²) Python pair loop it replaces.
    """
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    iu = np.triu_indices(len(a), k=1)
    sa = np.sign(a[:, None] - a[None, :])[iu]
    sb = np.sign(b[:, None] - b[None, :])[iu]
    prod = sa * sb                       # +1 concordant, -1 discordant, 0 tie
    den = int(np.count_nonzero(prod))
    return float(prod.sum() / den) if den else 0.0
