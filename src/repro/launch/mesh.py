"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — ``dryrun.py`` must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    kinds = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=kinds)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (possibly forced-host) devices exist."""
    kinds = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((n_data, n_model), ("data", "model"), axis_types=kinds)
