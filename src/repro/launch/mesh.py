"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — ``dryrun.py`` must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
init, and smoke tests must keep seeing 1 device.

``make_mesh`` papers over the jax API drift around explicit axis types:
``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
only exist on newer jax; older versions get the positional call, which
defaults every axis to Auto anyway.
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Version-compatible ``jax.make_mesh`` with all axes typed Auto."""
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes), axis_types=(axis_type,) * len(axes)
            )
        except TypeError:  # jax exposes AxisType but not the kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (possibly forced-host) devices exist."""
    return make_mesh((n_data, n_model), ("data", "model"))
