"""End-to-end training launcher: ``--arch <id>`` + MILO-selected data.

On a real pod this drives the full mesh; on CPU it runs the smoke-reduced
config so the whole path (MILO preprocessing -> curriculum pipeline ->
jit train step -> checkpoints -> restart) is exercised end to end.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --epochs 4 --subset-fraction 0.25 --smoke --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--subset-fraction", type=float, default=0.25)
    ap.add_argument("--selector", default="milo",
                    choices=["milo", "random", "adaptive_random", "full", "milo_fixed"])
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-docs", type=int, default=512)
    args = ap.parse_args()

    import jax

    from repro.configs import registry
    from repro.core import MiloPreprocessor
    from repro.data.datasets import TokenLMDataset
    from repro.data.pipeline import Pipeline
    from repro.optim.optimizers import adamw
    from repro.optim.schedules import cosine
    from repro.selection import build_selector
    from repro.train.train_state import init_train_state, make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.get(args.arch)
    if args.smoke:
        cfg = registry.smoke(args.arch)

    ds = TokenLMDataset(n_docs=args.n_docs, seq_len=64, vocab=cfg.vocab_size, seed=args.seed)
    t0 = time.time()
    k = max(1, int(ds.n * args.subset_fraction))
    if args.selector == "milo":
        pre = MiloPreprocessor(subset_fraction=args.subset_fraction, n_sge_subsets=4,
                               classwise=False)
        md = pre.preprocess(ds.features(), None, jax.random.PRNGKey(args.seed))
        selector = build_selector("milo", metadata=md, total_epochs=args.epochs,
                                  seed=args.seed)
        k = md.k
    elif args.selector == "random":
        selector = build_selector("random", n=ds.n, k=k, seed=args.seed)
    elif args.selector == "adaptive_random":
        selector = build_selector("adaptive_random", n=ds.n, k=k, seed=args.seed)
    elif args.selector == "milo_fixed":
        selector = build_selector("milo_fixed", features=ds.features(), k=k)
    else:
        selector = build_selector("full", n=ds.n)
        k = ds.n
    preprocess_s = time.time() - t0

    pipeline = Pipeline(ds.batch, selector, args.batch_size, seed=args.seed)
    opt = adamw()
    total_steps = max(1, pipeline.steps_per_epoch() * args.epochs)
    train_step = make_train_step(cfg, opt, cosine(args.lr, total_steps))
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt)

    trainer = Trainer(
        train_step, pipeline,
        TrainerConfig(epochs=args.epochs, checkpoint_dir=args.ckpt,
                      checkpoint_every_steps=20 if args.ckpt else 0,
                      log_every_steps=5),
    )
    state = trainer.fit(state)
    final = trainer.history[-1] if trainer.history else {}
    print(json.dumps({
        "arch": cfg.name, "selector": args.selector, "subset_k": int(k),
        "preprocess_s": round(preprocess_s, 2),
        "steps": int(state.step), "final": final,
        "mean_step_s": round(trainer.monitor.mean_step_time, 4),
        "stragglers": trainer.monitor.flagged,
    }, indent=1))


if __name__ == "__main__":
    main()
