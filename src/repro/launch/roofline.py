"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh) cell — TPU v5e constants:
    compute    = HLO_FLOPs        / (chips * 197e12 FLOP/s bf16)
    memory     = HLO_bytes        / (chips * 819e9  B/s HBM)
    collective = collective_bytes / (chips * 50e9   B/s per ICI link)

``cost_analysis()`` on a GSPMD-partitioned executable reports the *per-device*
program (the SPMD module is the single per-device program); we convert to
global totals by multiplying by chip count — validated in
tests/test_roofline.py against the analytic 6·N·D model FLOPs.

collective_bytes is not in cost_analysis: we parse the optimized HLO, build
an id->shape table from instruction results, and sum *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Operand shapes in the SPMD module are per-device shards, so the sum is
per-device traffic; global = per-device * chips.
"""
from __future__ import annotations

import re
from typing import Any

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[d0,d1]{layout}' shape string (tuple-aware)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum operand bytes of every collective op in the optimized HLO."""
    # id -> result shape string
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    per_op: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    operand_re = re.compile(r"%([\w.\-]+)")
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        counts[kind] += 1
        # operands: names inside the parens after the op name
        paren = line[line.find(op) + len(op):]
        lo = paren.find("(")
        hi = _match_paren(paren, lo)
        ops_str = paren[lo + 1 : hi] if lo >= 0 and hi > lo else ""
        obytes = 0
        for om in operand_re.finditer(ops_str):
            s = shapes.get(om.group(1))
            if s:
                obytes += _shape_bytes(s)
        if obytes == 0:
            # fallback: result shape (all-reduce in/out sizes match)
            obytes = _shape_bytes(m.group(2))
        per_op[kind] += obytes
    return {
        "per_op_bytes": per_op,
        "counts": counts,
        "total_bytes": sum(per_op.values()),
    }


def _match_paren(s: str, lo: int) -> int:
    if lo < 0:
        return -1
    depth = 0
    for i in range(lo, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


def memory_summary(mem) -> dict[str, Any]:
    """Normalize compiled.memory_analysis() across backends."""
    if mem is None:
        return {"available": False}
    out = {"available": True}
    for attr in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes", "peak_memory_in_bytes",
    ):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def model_flops(cfg, shape) -> float:
    """6 * N_active * tokens (the standard training-FLOPs model).

    For inference steps we use 2*N per token (forward only).
    """
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one new token per request
    return 2.0 * n * tokens


def roofline_terms_from_hlo(cfg, shape, hlo_totals: dict, *, multi_pod: bool) -> dict:
    """Preferred path: trip-count-aware totals from hlo_analysis.analyze."""
    cost = {"flops": hlo_totals["flops"], "bytes accessed": hlo_totals["bytes"]}
    coll = {"total_bytes": hlo_totals["collective_total_bytes"]}
    return roofline_terms(cfg, shape, None, cost, coll, multi_pod=multi_pod)


def roofline_terms(cfg, shape, mesh, cost: dict, coll: dict, *, multi_pod: bool) -> dict:
    chips = 512 if multi_pod else 256
    flops_dev = float(cost.get("flops") or 0.0)
    bytes_dev = float(cost.get("bytes accessed") or 0.0)
    coll_dev = float(coll["total_bytes"])

    flops_global = flops_dev * chips
    bytes_global = bytes_dev * chips
    coll_global = coll_dev * chips

    compute_s = flops_global / (chips * PEAK_FLOPS)
    memory_s = bytes_global / (chips * HBM_BW)
    collective_s = coll_global / (chips * ICI_BW)

    mf = model_flops(cfg, shape)
    terms = {
        "chips": chips,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "model_flops": mf,
        "useful_flops_ratio": mf / flops_global if flops_global else 0.0,
        "bound": max(
            ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
            key=lambda kv: kv[1],
        )[0],
    }
    dom = max(compute_s, memory_s, collective_s)
    terms["step_time_lower_bound_s"] = dom
    terms["roofline_fraction"] = (
        (mf / (chips * PEAK_FLOPS)) / dom if dom > 0 else 0.0
    )
    return terms
