"""Abstract input specs for the dry-run: ShapeDtypeStruct stand-ins with
NamedShardings attached — weak-type-correct, shardable, zero allocation.

For each (arch, shape) cell this builds exactly what the corresponding step
function consumes:
  train_4k     -> (TrainState, batch{tokens, labels [, context]})
  prefill_32k  -> (params, batch{tokens [, context]}, caches)
  decode_*     -> (params, caches, batch{token, pos [, context]})
Modality frontends are stubs per the assignment: ``context`` is precomputed
frame/patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import lm
from repro.optim.optimizers import Optimizer
from repro.train import train_state as ts


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def abstract_params(cfg: ModelConfig, mesh: Mesh):
    """Abstract (no allocation) params with production shardings attached."""
    a = jax.eval_shape(lambda k: lm.init_lm(k, cfg), jax.random.PRNGKey(0))
    shards = shd.param_shardings(mesh, a)
    return jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), a, shards)


def abstract_train_state(cfg: ModelConfig, mesh: Mesh, opt: Optimizer):
    a = jax.eval_shape(
        lambda k: ts.init_train_state(k, cfg, opt), jax.random.PRNGKey(0)
    )
    # params and each optimizer-state leaf shard identically (FSDP): optimizer
    # moments have the same shapes/paths under opt_state/m, /v.
    p_sh = shd.param_shardings(mesh, a.params)

    def opt_leaf(leaf, path_hint):
        return leaf

    o_sh = jax.tree.map(lambda l: None, a.opt_state)
    # match opt-state ("m"/"v" mirror params; scalars replicate)
    def shard_opt(subtree):
        return jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            subtree,
            p_sh,
        )

    opt_state = {}
    for k, v in a.opt_state.items():
        if isinstance(v, jax.ShapeDtypeStruct) and v.shape == ():
            opt_state[k] = jax.ShapeDtypeStruct(
                v.shape, v.dtype, sharding=NamedSharding(mesh, P())
            )
        else:
            opt_state[k] = shard_opt(v)

    params = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), a.params, p_sh
    )
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return ts.TrainState(params, opt_state, step)


def abstract_caches(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int):
    a = jax.eval_shape(lambda: lm.init_caches(cfg, batch, cache_len))

    # SSM states are (G, B, H, N, P) = 5 dims like KV caches (G,B,S,H,D);
    # distinguish by shape[2] == cache_len (the KV sequence axis).
    def shard(leaf):
        shp = leaf.shape
        if len(shp) == 5 and shp[2] == cache_len:      # (G,B,S,H,D) KV
            spec = P(None, *shd.cache_spec(mesh, shp[1], shp[2], shp[3]))
        elif len(shp) == 5:                            # (G,B,H,N,P) SSM state
            spec = P(None, *shd.ssm_state_spec(mesh, shp[1], shp[2]))
        elif len(shp) == 3:                            # (G,B,D) slstm
            ax = shd.batch_axes(mesh)
            spec = P(None, ax if shp[1] % _axsize(mesh, ax) == 0 else None, None)
        elif len(shp) <= 2:  # (G,) / (G, B) cache lengths — tiny, replicate
            spec = P(*([None] * len(shp)))
        else:
            spec = P(*([None] * len(shp)))
        return jax.ShapeDtypeStruct(shp, leaf.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree.map(shard, a)


def _axsize(mesh, axis):
    import numpy as np

    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def train_batch_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    spec = shd.data_spec(mesh, b, 1)
    batch = {
        "tokens": _sds((b, s), jnp.int32, mesh, spec),
        "labels": _sds((b, s), jnp.int32, mesh, spec),
    }
    if cfg.is_encdec:
        batch["context"] = _sds(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16, mesh, shd.data_spec(mesh, b, 2)
        )
    elif cfg.num_context_tokens:
        batch["context"] = _sds(
            (b, cfg.num_context_tokens, cfg.d_model), jnp.bfloat16, mesh, shd.data_spec(mesh, b, 2)
        )
    return batch


def decode_batch_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> dict:
    b = shape.global_batch
    spec = shd.data_spec(mesh, b, 1)
    batch = {
        "token": _sds((b, 1), jnp.int32, mesh, spec),
        "pos": jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    }
    if cfg.is_encdec:
        batch["context"] = _sds(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16, mesh, shd.data_spec(mesh, b, 2)
        )
    elif cfg.num_context_tokens:
        batch["context"] = _sds(
            (b, cfg.num_context_tokens, cfg.d_model), jnp.bfloat16, mesh, shd.data_spec(mesh, b, 2)
        )
    return batch


def input_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, opt: Optimizer) -> tuple:
    """Everything the cell's step function consumes, fully abstract."""
    if shape.kind == "train":
        return (abstract_train_state(cfg, mesh, opt), train_batch_specs(cfg, mesh, shape))
    if shape.kind == "prefill":
        params = abstract_params(cfg, mesh)
        batch = train_batch_specs(cfg, mesh, shape)
        batch.pop("labels")
        caches = abstract_caches(cfg, mesh, shape.global_batch, shape.seq_len)
        return (params, batch, caches)
    # decode
    params = abstract_params(cfg, mesh)
    caches = abstract_caches(cfg, mesh, shape.global_batch, shape.seq_len)
    batch = decode_batch_specs(cfg, mesh, shape)
    return (params, caches, batch)
