import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh over 512 placeholder host devices, lowers the cell's step
function against abstract ShapeDtypeStruct inputs (no allocation), compiles,
and extracts memory_analysis / cost_analysis / the collective schedule for
the roofline (§Roofline in EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str | None = None,
             attention_impl: str | None = None, overrides: dict | None = None) -> dict:
    import dataclasses

    import jax

    from repro.configs import registry
    from repro.configs.base import SHAPES, shape_applies
    from repro.launch import roofline, specs
    from repro.launch.mesh import make_production_mesh
    from repro.optim.optimizers import adamw
    from repro.train import train_state as ts

    cfg = registry.get(arch)
    if attention_impl:
        cfg = dataclasses.replace(cfg, attention_impl=attention_impl)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applies(cfg, shape)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}.json"
            with open(os.path.join(out_dir, tag), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    opt = adamw()
    t0 = time.time()
    try:
        with mesh:
            if shape.kind == "train":
                step_fn = ts.make_train_step(
                    cfg, opt, lambda s: 1e-4, interpret=True
                )
                args = specs.input_specs(cfg, mesh, shape, opt)
                lowered = jax.jit(step_fn).lower(*args)
            elif shape.kind == "prefill":
                step_fn = ts.make_prefill_step(cfg)
                params, batch, caches = specs.input_specs(cfg, mesh, shape, opt)
                lowered = jax.jit(step_fn).lower(params, batch, caches)
            else:  # decode
                step_fn = ts.make_serve_step(cfg)
                params, caches, batch = specs.input_specs(cfg, mesh, shape, opt)
                lowered = jax.jit(step_fn).lower(params, caches, batch)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        from repro.launch import hlo_analysis

        mem = compiled.memory_analysis()
        cost = hlo_analysis.xla_cost(compiled)
        hlo_text = compiled.as_text()
        totals = hlo_analysis.analyze(hlo_text)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=roofline.memory_summary(mem),
            # raw XLA numbers (while bodies counted once — kept for reference)
            xla_cost={k: cost.get(k) for k in ("flops", "bytes accessed")},
            # trip-count-aware per-device totals (see hlo_analysis.py)
            hlo={
                "flops": totals["flops"],
                "bytes": totals["bytes"],
                "collective_bytes": totals["collective_bytes"],
                "collective_counts": totals["collective_counts"],
                "collective_total_bytes": totals["collective_total_bytes"],
                "collective_shapes": dict(sorted(
                    totals["collective_shapes"].items(), key=lambda kv: -kv[1])[:12]),
                "while_trips": totals["while_trips"],
            },
        )
        rec["roofline"] = roofline.roofline_terms_from_hlo(
            cfg, shape, totals, multi_pod=multi_pod
        )
    except Exception as e:  # noqa: BLE001 — report per-cell failures
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}.json"
        with open(os.path.join(out_dir, tag), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--attention-impl", default=None)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.configs.base import SHAPES

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for a in registry.ARCHS:
            for s in SHAPES:
                cells.append((a, s, False))
                cells.append((a, s, True))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    failed = 0
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                       attention_impl=args.attention_impl)
        status = rec["status"]
        extra = ""
        if status == "ok":
            rl = rec["roofline"]
            extra = (f" compile={rec['compile_s']}s bound={rl['bound']}"
                     f" frac={rl['roofline_fraction']:.3f}"
                     f" useful={rl['useful_flops_ratio']:.2f}")
        elif status == "error":
            extra = " " + rec["error"][:200]
            failed += 1
        print(f"[{status:7s}] {arch} x {shape} ({rec['mesh']}){extra}", flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
