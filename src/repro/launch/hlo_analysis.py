"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` visits each while-loop body ONCE, ignoring trip
counts (verified empirically: a scan of 8 matmuls reports the FLOPs of 1), so
it wildly undercounts scanned layer stacks.  This module re-derives the three
roofline inputs directly from the optimized HLO text:

  * FLOPs       — 2 * numel(result) * contraction for every ``dot`` (einsums
                  lower to dots; elementwise FLOPs are bandwidth-bound and
                  attributed to the memory term),
  * HBM bytes   — operands + result of every top-level (post-fusion)
                  instruction, i.e. one read per operand and one write per
                  result, the standard post-fusion traffic model,
  * collectives — operand bytes per all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, split per op kind,

each multiplied by the product of enclosing while trip counts (extracted from
the loop-condition constant).  Shapes in the SPMD module are per-device
shards, so all totals are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}]+))\s+([\w\-]+)\(")
_ATTR = re.compile(r"(\w+)=%?([\w.\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def xla_cost(compiled: Any) -> dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return a one-element list of dicts, newer ones the dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(shape_str: str) -> int:
    n_total = 0
    for _, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        n_total += n
    return n_total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str


def parse_module(text: str) -> tuple[dict[str, list[Instr]], dict[str, dict[str, str]], str]:
    """Returns (computations, per-comp symbol tables, entry name)."""
    comps: dict[str, list[Instr]] = {}
    symtab: dict[str, dict[str, str]] = {}
    entry = ""
    cur: str | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{") and "->" in line:
                cur = m.group(2)
                comps[cur] = []
                symtab[cur] = {}
                if m.group(1):
                    entry = cur
                # parameters carry shapes in the signature (balanced parens)
                lo = line.find("(")
                depth, hi = 0, -1
                for i in range(lo, len(line)):
                    if line[i] == "(":
                        depth += 1
                    elif line[i] == ")":
                        depth -= 1
                        if depth == 0:
                            hi = i
                            break
                sig = line[lo + 1 : hi] if hi > lo else ""
                # split top-level commas
                parts, d, start = [], 0, 0
                for i, c in enumerate(sig):
                    if c == "(":
                        d += 1
                    elif c == ")":
                        d -= 1
                    elif c == "," and d == 0:
                        parts.append(sig[start:i])
                        start = i + 1
                parts.append(sig[start:])
                for p in parts:
                    if ":" in p:
                        nm, sh = p.split(":", 1)
                        symtab[cur][nm.strip().lstrip("%")] = sh.strip()
                continue
        else:
            if line.strip() == "}":
                cur = None
                continue
            im = _INSTR.match(line)
            if im:
                name, shape, op = im.group(1), im.group(2), im.group(3)
                comps[cur].append(Instr(name, shape, op, line))
                symtab[cur][name] = shape
    return comps, symtab, entry


def _operands(line: str, op: str) -> list[str]:
    idx = line.find(op + "(")
    if idx < 0:
        return []
    depth = 0
    start = idx + len(op)
    buf = []
    for i in range(start, len(line)):
        c = line[i]
        if c == "(":
            depth += 1
            if depth == 1:
                continue
        elif c == ")":
            depth -= 1
            if depth == 0:
                buf.append(line[start + 1 : i])
                break
    if not buf:
        return []
    return re.findall(r"%([\w.\-]+)", buf[0])


def _attr(line: str, key: str) -> str | None:
    m = re.search(rf"{key}=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _trip_count(cond_comp: list[Instr]) -> int:
    """Max integer constant in the loop condition (counter starts at 0)."""
    best = 1
    for ins in cond_comp:
        for m in re.finditer(r"constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, syms: dict[str, str]) -> float:
    ops = _operands(ins.line, ins.op)
    if not ops:
        return 0.0
    lhs_shape = syms.get(ops[0])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if lhs_shape is None or m is None:
        # fallback: assume contraction == last dim of result's sibling
        return 2.0 * _numel(ins.shape)
    dims = _shape_dims(lhs_shape)
    if not dims:
        return 0.0
    lhs_dims = dims[0][1]
    contract = 1
    for d in (m.group(1).split(",") if m.group(1) else []):
        di = int(d)
        if di < len(lhs_dims):
            contract *= lhs_dims[di]
    return 2.0 * _numel(ins.shape) * contract


def _fusion_operand_bytes(ins: Instr, syms: dict[str, str], callee: str | None,
                          comps: dict[str, list[Instr]]) -> int:
    """Operand bytes of a fusion, charging dynamic-slice'd params at slice size.

    The scan weight-gather pattern (`dynamic-slice(stacked_params, i)`) would
    otherwise be charged the FULL stacked array per loop iteration — a
    ~n_groups x overcount of weight traffic.
    """
    ops = _operands(ins.line, ins.op)
    if not callee or callee not in comps:
        b = 0
        for o in ops:
            s = syms.get(o)
            if s:
                b += shape_bytes(s)
        return b
    # map parameter index -> bytes actually read (slice size if the only
    # consumer is a dynamic-slice)
    body = comps[callee]
    param_read: dict[int, int] = {}
    param_names: dict[str, int] = {}
    for bi in body:
        if bi.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", bi.line)
            if m:
                param_names[bi.name] = int(m.group(1))
    consumers: dict[str, list[Instr]] = {}
    for bi in body:
        for o in _operands(bi.line, bi.op):
            consumers.setdefault(o, []).append(bi)
    for pname, pidx in param_names.items():
        cons = consumers.get(pname, [])
        if cons and all(c.op == "dynamic-slice" for c in cons):
            param_read[pidx] = sum(shape_bytes(c.shape) for c in cons)
    b = 0
    for i, o in enumerate(ops):
        if i in param_read:
            b += param_read[i]
        else:
            s = syms.get(o)
            if s:
                b += shape_bytes(s)
    return b


def analyze(text: str) -> dict[str, Any]:
    comps, symtab, entry = parse_module(text)
    totals = {
        "flops": 0.0,
        "bytes": 0.0,
        "collective_bytes": {c: 0.0 for c in _COLLECTIVES},
        "collective_counts": {c: 0 for c in _COLLECTIVES},
        "collective_shapes": {},
        "bytes_by": {},
        "dot_count": 0,
        "while_trips": [],
    }

    def add_bytes(ins: Instr, n: float, mult: float) -> None:
        totals["bytes"] += mult * n
        key = f"{ins.op} {ins.shape[:70]}"
        totals["bytes_by"][key] = totals["bytes_by"].get(key, 0.0) + mult * n

    def inst_operand_bytes(ins: Instr, syms) -> int:
        b = 0
        for o in _operands(ins.line, ins.op):
            s = syms.get(o)
            if s:
                b += shape_bytes(s)
        return b

    def visit(comp_name: str, mult: float, *, in_fusion: bool) -> None:
        syms = symtab.get(comp_name, {})
        for ins in comps.get(comp_name, []):
            op = ins.op
            if op == "while":
                cond = _attr(ins.line, "condition")
                body = _attr(ins.line, "body")
                trip = _trip_count(comps.get(cond, [])) if cond else 1
                totals["while_trips"].append(trip)
                if body:
                    visit(body, mult * trip, in_fusion=False)
                continue
            if op == "conditional":
                for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))", ins.line):
                    for name in br:
                        for c in filter(None, re.findall(r"%?([\w.\-]+)", name or "")):
                            if c in comps:
                                visit(c, mult, in_fusion=False)
                continue
            if op == "fusion":
                callee = _attr(ins.line, "calls")
                if not in_fusion:
                    add_bytes(ins, _fusion_operand_bytes(ins, syms, callee, comps)
                              + shape_bytes(ins.shape), mult)
                if callee:
                    visit(callee, mult, in_fusion=True)  # count dots inside only
                continue
            if op in ("call", "async-start", "async-done"):
                callee = _attr(ins.line, "calls") or _attr(ins.line, "to_apply")
                if callee and callee in comps:
                    visit(callee, mult, in_fusion=in_fusion)
                continue
            kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if kind:
                ob = inst_operand_bytes(ins, syms)
                if ob == 0:
                    ob = shape_bytes(ins.shape)
                totals["collective_bytes"][kind] += mult * ob
                totals["collective_counts"][kind] += int(mult)
                key = f"{kind} {ins.shape[:60]}"
                totals["collective_shapes"][key] = totals["collective_shapes"].get(key, 0.0) + mult * ob
                if not in_fusion:
                    add_bytes(ins, ob + shape_bytes(ins.shape), mult)
                continue
            if op == "dynamic-slice":
                # reads the slice, writes the slice — not the whole operand
                if not in_fusion:
                    add_bytes(ins, 2 * shape_bytes(ins.shape), mult)
                continue
            if op == "dynamic-update-slice":
                # in-place aliased update: read+write of the update region only
                ops_ = _operands(ins.line, ins.op)
                upd = syms.get(ops_[1]) if len(ops_) > 1 else None
                if not in_fusion:
                    add_bytes(ins, 2 * (shape_bytes(upd) if upd else shape_bytes(ins.shape)), mult)
                continue
            if op in ("dot", "convolution"):
                totals["flops"] += mult * _dot_flops(ins, syms)
                totals["dot_count"] += 1
                if not in_fusion:
                    add_bytes(ins, inst_operand_bytes(ins, syms) + shape_bytes(ins.shape), mult)
                continue
            if op == "custom-call" and ("matmul" in ins.line or "dot" in ins.line.lower()):
                totals["flops"] += mult * 2.0 * _numel(ins.shape) * 1  # unknown k
            if op in _FREE_OPS:
                continue
            if not in_fusion:
                add_bytes(ins, inst_operand_bytes(ins, syms) + shape_bytes(ins.shape), mult)

    visit(entry, 1.0, in_fusion=False)
    totals["collective_total_bytes"] = sum(totals["collective_bytes"].values())
    return totals
