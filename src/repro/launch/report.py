"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json
import os


def load_all(out_dir: str) -> list[dict]:
    recs = []
    for fn in sorted(os.listdir(out_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                recs.append(json.load(f))
    return recs


def fmt_table(recs: list[dict], mesh: str) -> str:
    rows = [
        "| arch | shape | status | compute s | memory s | collective s | bound "
        "| MODEL_FLOPs | useful ratio | roofline frac | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(recs, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped — {r['reason'][:46]} "
                        "| | | | | | | | |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | | |")
            continue
        t = r["roofline"]
        mem_dev = r.get("memory", {}).get("argument_size_in_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {t['compute_s']:.3g} "
            f"| {t['memory_s']:.3g} | {t['collective_s']:.3g} | **{t['bound']}** "
            f"| {t['model_flops']:.3g} | {t['useful_flops_ratio']:.2f} "
            f"| {t['roofline_fraction']:.4f} | {mem_dev/1e9:.2f} GB |"
        )
    return "\n".join(rows)


def fmt_summary(recs: list[dict]) -> str:
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    er = sum(1 for r in recs if r["status"] not in ("ok", "skipped"))
    return f"{ok} ok, {sk} skipped (documented), {er} errors of {len(recs)} compiles"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load_all(args.dir)
    print("## Summary:", fmt_summary(recs))
    print("\n### Single-pod (16x16 = 256 chips)\n")
    print(fmt_table(recs, "16x16"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(fmt_table(recs, "2x16x16"))


if __name__ == "__main__":
    main()
