"""Optimizers: Nesterov SGD (paper's vision setup), Adam, AdamW.

Implemented in-repo (no optax dependency) as pure pytree transforms with the
standard (init, update) pair.  Optimizer state shards exactly like the params
(FSDP over ``data``): the sharding rules map state leaves through the same
path-based spec as their parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd_nesterov(momentum: float = 0.9, weight_decay: float = 5e-4) -> Optimizer:
    """Nesterov SGD + decoupled L2 (paper: lr .05, wd 5e-4, momentum .9)."""

    def init(params):
        return {"m": _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        g32 = _tmap(lambda g, p: g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32),
                    grads, params)
        m = _tmap(lambda m_, g: momentum * m_ + g, state["m"], g32)
        step = _tmap(lambda g, m_: g + momentum * m_, g32, m)  # Nesterov lookahead
        new_params = _tmap(lambda p, s: (p.astype(jnp.float32) - lr * s).astype(p.dtype),
                           params, step)
        return new_params, {"m": m}

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam / AdamW (weight_decay > 0 => decoupled AdamW)."""

    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        g32 = _tmap(lambda g: g.astype(jnp.float32), grads)
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        return _tmap(upd, params, m, v), {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adamw(weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(weight_decay=weight_decay, **kw)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


GET = {"sgd": sgd_nesterov, "adam": adam, "adamw": adamw}
