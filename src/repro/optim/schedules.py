"""LR schedules: cosine annealing (paper default), cyclic (ImageNet), linear
decay (tuning search space), constant, with optional linear warmup."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(base_lr: float, total_steps: int, warmup: int = 0, min_lr: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return fn


def cyclic(base_lr: float, max_lr: float, period: int):
    def fn(step):
        t = jnp.asarray(step % (2 * period), jnp.float32)
        up = base_lr + (max_lr - base_lr) * (t / period)
        down = max_lr - (max_lr - base_lr) * ((t - period) / period)
        return jnp.where(t < period, up, down)

    return fn


def linear_decay(base_lr: float, gamma: float, every: int):
    """Multiply lr by (1-gamma) every ``every`` steps (paper tuning space)."""
    def fn(step):
        k = jnp.asarray(step // every, jnp.float32)
        return base_lr * (1.0 - gamma) ** k

    return fn


def constant(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


GET = {"cosine": cosine, "cyclic": cyclic, "linear_decay": linear_decay, "constant": constant}
