"""Import shim: the LM decode engine moved to ``repro.serve.lm_engine``.

``repro.serve`` now hosts two engines — the batched LM prefill/decode
engine (``lm_engine``) and the selection-serving subsystem
(``store``/``buffers``/``server``: persistent multi-tenant ``MiloServer``).
The old ``repro.serve.engine`` path keeps resolving to the LM engine so
existing imports and scripts continue to work.
"""
from repro.serve.lm_engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
