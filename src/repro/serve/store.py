"""Versioned artifact store: the server-side home of ``MiloMetadata``.

MILO's economics rest on computing a preprocessing artifact ONCE per
(dataset, config) and serving it to arbitrarily many downstream trainings.
``ArtifactStore`` makes that a property of a long-lived process instead of a
file path convention:

  * **Keying** — artifacts are addressed by ``(data_fingerprint,
    config_hash)``: the content hash of the feature matrix and the canonical
    hash of the preprocessing config (``repro.core.metadata.config_hash``).
    Same data + same config → same key → one artifact, however many clients
    ask.
  * **Single-flight builds** — concurrent requests for a missing key block
    on one per-key build lock; exactly one preprocessing run happens and
    every waiter receives its result.  A build that RAISES releases the
    flight lock on unwind and installs nothing — the next caller simply
    rebuilds — so one bad build can never wedge a key.  ``builds`` /
    ``build_failures`` / ``hits`` / ``disk_loads`` counters make both
    claims testable.
  * **Cross-process single-flight** — with a disk root, the build section
    is additionally guarded by an ``O_EXCL`` lockfile next to the artifact
    (``<artifact>.npz.lock`` recording the holder's PID), so N *processes*
    sharing one store root (the multi-host deployment shape) also build a
    key exactly once: the losers poll, and the moment the winner's atomic
    rename lands they load the finished artifact from disk.  A lockfile
    whose recorded PID is dead is taken over — the taker renames it to a
    tombstone (exactly one racing taker wins the ``rename``) and retries —
    so a SIGKILLed builder can never wedge the key for its peers.  A
    stuck-but-ALIVE holder only stalls waiters until ``lock_timeout``,
    after which they build redundantly rather than hang (the artifact
    write is an atomic rename, so the race costs duplicate work, never a
    torn file).  ``lock_waits`` / ``lock_steals`` / ``lock_timeouts``
    counters expose each path.
  * **Two tiers** — an in-memory LRU of decoded ``MiloMetadata`` objects in
    front of an optional on-disk root (one ``.npz`` per key, written through
    ``MiloMetadata.save``'s atomic temp-file rename).  Evicting a memory
    entry keeps the disk copy; the next request reloads it through the PR 1
    reuse guards (config-hash verification), bit-identical to the original.
  * **Pinning** — pinned keys are exempt from LRU eviction (for tenants with
    a latency SLO on a known dataset).
  * **Versioning** — each rebuild of a key (``force=True``) bumps a
    monotonically increasing per-key version, recorded in the entry and the
    request log, so a client can tell whether two responses came from the
    same artifact generation.

The store never invents artifacts: a disk file whose stored config hash does
not match the requested config raises ``MetadataMismatchError`` (the same
guard ``MiloSession`` applies to ``metadata_path`` artifacts).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from typing import Any, Callable

from repro.core.metadata import (
    MetadataMismatchError,
    MiloMetadata,
    config_hash,
)

#: (data_fingerprint, config_hash)
ArtifactKey = tuple[str, str]


def _pid_alive(pid: int) -> bool:
    """Whether ``pid`` names a live process (signal-0 probe)."""
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


@dataclasses.dataclass
class ArtifactEntry:
    """Bookkeeping for one stored artifact (metadata may be evicted)."""

    key: ArtifactKey
    version: int
    pinned: bool = False
    hits: int = 0
    path: str | None = None


class ArtifactStore:
    """In-memory LRU + on-disk artifact store with single-flight builds."""

    def __init__(
        self,
        root: str | None = None,
        *,
        capacity: int = 8,
        lock_timeout: float = 300.0,
        lock_poll: float = 0.05,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.root = root
        self.capacity = capacity
        # cross-process lockfile knobs (root-backed stores only); clock and
        # sleep are injectable so the timeout paths are testable without
        # real waiting
        self.lock_timeout = lock_timeout
        self.lock_poll = lock_poll
        self._clock = clock
        self._sleep = sleep
        self._lock = threading.RLock()
        # insertion order == recency order (move_to_end on every touch)
        self._memory: collections.OrderedDict[ArtifactKey, MiloMetadata] = (
            collections.OrderedDict()
        )
        self._entries: dict[ArtifactKey, ArtifactEntry] = {}
        self._flights: dict[ArtifactKey, threading.Lock] = {}
        #: consecutive build failures per key (reset by a successful build);
        #: the observable MiloServer's circuit breaker trips on
        self._key_failures: dict[ArtifactKey, int] = {}
        self.builds = 0
        self.build_failures = 0
        self.hits = 0
        self.disk_loads = 0
        self.evictions = 0
        self.lock_waits = 0
        self.lock_steals = 0
        self.lock_timeouts = 0
        if root:
            os.makedirs(root, exist_ok=True)

    # -- keys ---------------------------------------------------------------

    @staticmethod
    def key_for(data_fingerprint: str, config: dict[str, Any]) -> ArtifactKey:
        """The store key for a (dataset, preprocessing-config) pair."""
        return (data_fingerprint, config_hash(config))

    def path_for(self, key: ArtifactKey) -> str | None:
        if self.root is None:
            return None
        return os.path.join(self.root, f"{key[0]}_{key[1]}.npz")

    # -- pin policy ---------------------------------------------------------

    def pin(self, key: ArtifactKey) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                raise KeyError(f"unknown artifact key {key}")
            entry.pinned = True

    def unpin(self, key: ArtifactKey) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                entry.pinned = False

    # -- lookup / build -----------------------------------------------------

    def get_or_build(
        self,
        key: ArtifactKey,
        expected_config: dict[str, Any],
        build_fn: Callable[[], MiloMetadata],
        *,
        pin: bool = False,
        force: bool = False,
    ) -> tuple[MiloMetadata, ArtifactEntry, str]:
        """Return ``(artifact, entry, source)`` for ``key``, building at most
        once; ``source`` is ``"memory"`` / ``"disk"`` / ``"built"`` (the
        request-log observable behind the serving bench's warm/cold split).

        Resolution order: in-memory hit → on-disk reload (verified against
        ``expected_config`` through the ``MiloMetadata.load`` reuse guards)
        → ``build_fn()`` (exactly one concurrent caller runs it; the rest
        wait on the per-key flight lock and hit the fresh entry).
        ``force=True`` skips both caches, reruns ``build_fn`` and bumps the
        key's version.
        """
        flight = self._flight(key)
        with flight:
            if not force:
                cached = self._memory_hit(key)
                if cached is not None:
                    if pin:
                        cached[1].pinned = True
                    return (*cached, "memory")
                loaded = self._disk_load(key, expected_config)
                if loaded is not None:
                    if pin:
                        loaded[1].pinned = True
                    return (*loaded, "disk")
            path = self.path_for(key)
            lock_path = None
            if path is not None and not force:
                # cross-process single-flight: win the O_EXCL lockfile or
                # wait for the winning process's artifact to land on disk
                lock_path, loaded = self._acquire_build_lock(
                    key, path, expected_config
                )
                if loaded is not None:
                    if pin:
                        loaded[1].pinned = True
                    return (*loaded, "disk")
            try:
                try:
                    md = build_fn()
                except BaseException:
                    # a failed build must not poison the key: count it, let
                    # the ``with flight:`` release the per-key lock on
                    # unwind, and leave no partial entry behind.  Each
                    # waiter blocked on the flight lock then resolves the
                    # key itself (cache miss → its own build attempt)
                    # instead of hanging forever on a lock the dead builder
                    # never released.
                    with self._lock:
                        self.build_failures += 1
                        self._key_failures[key] = (
                            self._key_failures.get(key, 0) + 1
                        )
                    raise
                with self._lock:
                    self.builds += 1
                    self._key_failures.pop(key, None)
                    entry = self._entries.get(key)
                    if entry is None:
                        entry = ArtifactEntry(key=key, version=1,
                                              path=self.path_for(key))
                        self._entries[key] = entry
                    else:
                        entry.version += 1
                    entry.pinned = entry.pinned or pin
                if path is not None:
                    md.save(path)
            finally:
                # released AFTER the atomic save, so a waiter that sees the
                # lock vanish also sees the finished artifact
                if lock_path is not None:
                    self._release_build_lock(lock_path)
            self._install(key, md)
            return md, self._entries[key], "built"

    # -- cross-process lockfile ---------------------------------------------

    def _acquire_build_lock(
        self, key: ArtifactKey, path: str, expected_config: dict[str, Any]
    ) -> tuple[str | None, tuple[MiloMetadata, ArtifactEntry] | None]:
        """Win the key's cross-process build lock, or load the peer's result.

        Returns ``(lock_path, None)`` once this process owns the lockfile
        (build may proceed; the caller must ``_release_build_lock``), or
        ``(None, (md, entry))`` when another process finished the build
        first and its artifact was loaded from disk.  On ``lock_timeout``
        returns ``(None, None)``: the caller builds WITHOUT the lock —
        ``MiloMetadata.save`` is an atomic rename, so a stuck-but-alive
        holder costs duplicated work, never a torn artifact.
        """
        lock_path = path + ".lock"
        deadline = self._clock() + self.lock_timeout
        waited = False
        while True:
            if self._try_lock(lock_path):
                return lock_path, None
            if not waited:
                waited = True
                with self._lock:
                    self.lock_waits += 1
            if os.path.exists(path):
                loaded = self._disk_load(key, expected_config)
                if loaded is not None:
                    return None, loaded
            if self._clock() >= deadline:
                with self._lock:
                    self.lock_timeouts += 1
                return None, None
            self._sleep(self.lock_poll)

    def _try_lock(self, lock_path: str) -> bool:
        """One O_EXCL attempt; reaps a dead holder's lock as a side effect."""
        try:
            fd = os.open(lock_path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            self._reap_stale_lock(lock_path)
            return False
        try:
            os.write(fd, str(os.getpid()).encode("ascii"))
        finally:
            os.close(fd)
        return True

    def _reap_stale_lock(self, lock_path: str) -> None:
        """Remove ``lock_path`` if its recorded holder PID is dead.

        The takeover is race-free: every contender renames the lock to its
        OWN tombstone name first, and ``os.rename`` lets exactly one win;
        the losers' renames fail and they simply retry the O_EXCL open
        (now against the new holder's lock).
        """
        try:
            with open(lock_path, encoding="ascii") as f:
                pid = int(f.read().strip())
        except (OSError, ValueError):
            # vanished under us, or the holder hasn't recorded its PID yet
            # (microsecond window after its O_EXCL open): treat as live
            return
        if _pid_alive(pid):
            return
        tombstone = f"{lock_path}.stale.{os.getpid()}"
        try:
            os.rename(lock_path, tombstone)
        except OSError:
            return  # a racing reaper won the rename
        try:
            os.unlink(tombstone)
        except OSError:
            pass
        with self._lock:
            self.lock_steals += 1

    def _release_build_lock(self, lock_path: str) -> None:
        try:
            os.unlink(lock_path)
        except OSError:
            pass

    def _flight(self, key: ArtifactKey) -> threading.Lock:
        with self._lock:
            return self._flights.setdefault(key, threading.Lock())

    def _memory_hit(self, key: ArtifactKey) -> tuple[MiloMetadata, ArtifactEntry] | None:
        with self._lock:
            md = self._memory.get(key)
            if md is None:
                return None
            self._memory.move_to_end(key)
            entry = self._entries[key]
            entry.hits += 1
            self.hits += 1
            return md, entry

    def _disk_load(
        self, key: ArtifactKey, expected_config: dict[str, Any]
    ) -> tuple[MiloMetadata, ArtifactEntry] | None:
        path = self.path_for(key)
        if path is None or not os.path.exists(path):
            return None
        # the reuse guards (same semantics as MiloSession's metadata_path
        # load): the stored config must agree with the request's on every
        # key the request specifies — partial-dict check, because the
        # artifact records MORE than the request config (encoder, seeds,
        # engine provenance) and key[1] hashes only the request's view —
        # and a recorded data fingerprint must match the key's.  A foreign
        # file parked at this key's path fails one of the two.
        md = MiloMetadata.load(path, expected_config=expected_config or None)
        stored_fp = md.config.get("data_fingerprint")
        if stored_fp is not None and stored_fp != key[0]:
            raise MetadataMismatchError(
                f"{path}: artifact was preprocessed over different data "
                f"(fingerprint {stored_fp} != requested {key[0]})"
            )
        with self._lock:
            self.disk_loads += 1
            entry = self._entries.get(key)
            if entry is None:
                # artifact predates this process (written by an earlier
                # server); adopt it at version 1
                entry = ArtifactEntry(key=key, version=1, path=path)
                self._entries[key] = entry
            entry.hits += 1
        self._install(key, md)
        return md, self._entries[key]

    def _install(self, key: ArtifactKey, md: MiloMetadata) -> None:
        """Insert into the memory tier, evicting LRU unpinned entries."""
        with self._lock:
            self._memory[key] = md
            self._memory.move_to_end(key)
            evictable = [
                k for k in self._memory
                if k != key and not self._entries[k].pinned
            ]
            # oldest first (OrderedDict preserves recency order)
            while len(self._memory) > self.capacity and evictable:
                victim = evictable.pop(0)
                del self._memory[victim]
                self.evictions += 1

    # -- introspection ------------------------------------------------------

    def resident(self, key: ArtifactKey) -> bool:
        """Whether the decoded artifact currently sits in the memory tier."""
        with self._lock:
            return key in self._memory

    def failures_for(self, key: ArtifactKey) -> int:
        """Consecutive build failures for ``key`` since its last success."""
        with self._lock:
            return self._key_failures.get(key, 0)

    def entries(self) -> list[ArtifactEntry]:
        with self._lock:
            return [dataclasses.replace(e) for e in self._entries.values()]

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "builds": self.builds,
                "build_failures": self.build_failures,
                "failing_keys": len(self._key_failures),
                "hits": self.hits,
                "disk_loads": self.disk_loads,
                "evictions": self.evictions,
                "lock_waits": self.lock_waits,
                "lock_steals": self.lock_steals,
                "lock_timeouts": self.lock_timeouts,
                "resident": len(self._memory),
                "known": len(self._entries),
            }
