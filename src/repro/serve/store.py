"""Versioned artifact store: the server-side home of ``MiloMetadata``.

MILO's economics rest on computing a preprocessing artifact ONCE per
(dataset, config) and serving it to arbitrarily many downstream trainings.
``ArtifactStore`` makes that a property of a long-lived process instead of a
file path convention:

  * **Keying** — artifacts are addressed by ``(data_fingerprint,
    config_hash)``: the content hash of the feature matrix and the canonical
    hash of the preprocessing config (``repro.core.metadata.config_hash``).
    Same data + same config → same key → one artifact, however many clients
    ask.
  * **Single-flight builds** — concurrent requests for a missing key block
    on one per-key build lock; exactly one preprocessing run happens and
    every waiter receives its result.  A build that RAISES releases the
    flight lock on unwind and installs nothing — the next caller simply
    rebuilds — so one bad build can never wedge a key.  ``builds`` /
    ``build_failures`` / ``hits`` / ``disk_loads`` counters make both
    claims testable.
  * **Two tiers** — an in-memory LRU of decoded ``MiloMetadata`` objects in
    front of an optional on-disk root (one ``.npz`` per key, written through
    ``MiloMetadata.save``'s atomic temp-file rename).  Evicting a memory
    entry keeps the disk copy; the next request reloads it through the PR 1
    reuse guards (config-hash verification), bit-identical to the original.
  * **Pinning** — pinned keys are exempt from LRU eviction (for tenants with
    a latency SLO on a known dataset).
  * **Versioning** — each rebuild of a key (``force=True``) bumps a
    monotonically increasing per-key version, recorded in the entry and the
    request log, so a client can tell whether two responses came from the
    same artifact generation.

The store never invents artifacts: a disk file whose stored config hash does
not match the requested config raises ``MetadataMismatchError`` (the same
guard ``MiloSession`` applies to ``metadata_path`` artifacts).
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
from typing import Any, Callable

from repro.core.metadata import (
    MetadataMismatchError,
    MiloMetadata,
    config_hash,
)

#: (data_fingerprint, config_hash)
ArtifactKey = tuple[str, str]


@dataclasses.dataclass
class ArtifactEntry:
    """Bookkeeping for one stored artifact (metadata may be evicted)."""

    key: ArtifactKey
    version: int
    pinned: bool = False
    hits: int = 0
    path: str | None = None


class ArtifactStore:
    """In-memory LRU + on-disk artifact store with single-flight builds."""

    def __init__(self, root: str | None = None, *, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.root = root
        self.capacity = capacity
        self._lock = threading.RLock()
        # insertion order == recency order (move_to_end on every touch)
        self._memory: collections.OrderedDict[ArtifactKey, MiloMetadata] = (
            collections.OrderedDict()
        )
        self._entries: dict[ArtifactKey, ArtifactEntry] = {}
        self._flights: dict[ArtifactKey, threading.Lock] = {}
        #: consecutive build failures per key (reset by a successful build);
        #: the observable MiloServer's circuit breaker trips on
        self._key_failures: dict[ArtifactKey, int] = {}
        self.builds = 0
        self.build_failures = 0
        self.hits = 0
        self.disk_loads = 0
        self.evictions = 0
        if root:
            os.makedirs(root, exist_ok=True)

    # -- keys ---------------------------------------------------------------

    @staticmethod
    def key_for(data_fingerprint: str, config: dict[str, Any]) -> ArtifactKey:
        """The store key for a (dataset, preprocessing-config) pair."""
        return (data_fingerprint, config_hash(config))

    def path_for(self, key: ArtifactKey) -> str | None:
        if self.root is None:
            return None
        return os.path.join(self.root, f"{key[0]}_{key[1]}.npz")

    # -- pin policy ---------------------------------------------------------

    def pin(self, key: ArtifactKey) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                raise KeyError(f"unknown artifact key {key}")
            entry.pinned = True

    def unpin(self, key: ArtifactKey) -> None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                entry.pinned = False

    # -- lookup / build -----------------------------------------------------

    def get_or_build(
        self,
        key: ArtifactKey,
        expected_config: dict[str, Any],
        build_fn: Callable[[], MiloMetadata],
        *,
        pin: bool = False,
        force: bool = False,
    ) -> tuple[MiloMetadata, ArtifactEntry, str]:
        """Return ``(artifact, entry, source)`` for ``key``, building at most
        once; ``source`` is ``"memory"`` / ``"disk"`` / ``"built"`` (the
        request-log observable behind the serving bench's warm/cold split).

        Resolution order: in-memory hit → on-disk reload (verified against
        ``expected_config`` through the ``MiloMetadata.load`` reuse guards)
        → ``build_fn()`` (exactly one concurrent caller runs it; the rest
        wait on the per-key flight lock and hit the fresh entry).
        ``force=True`` skips both caches, reruns ``build_fn`` and bumps the
        key's version.
        """
        flight = self._flight(key)
        with flight:
            if not force:
                cached = self._memory_hit(key)
                if cached is not None:
                    if pin:
                        cached[1].pinned = True
                    return (*cached, "memory")
                loaded = self._disk_load(key, expected_config)
                if loaded is not None:
                    if pin:
                        loaded[1].pinned = True
                    return (*loaded, "disk")
            try:
                md = build_fn()
            except BaseException:
                # a failed build must not poison the key: count it, let the
                # ``with flight:`` release the per-key lock on unwind, and
                # leave no partial entry behind.  Each waiter blocked on the
                # flight lock then resolves the key itself (cache miss →
                # its own build attempt) instead of hanging forever on a
                # lock the dead builder never released.
                with self._lock:
                    self.build_failures += 1
                    self._key_failures[key] = self._key_failures.get(key, 0) + 1
                raise
            with self._lock:
                self.builds += 1
                self._key_failures.pop(key, None)
                entry = self._entries.get(key)
                if entry is None:
                    entry = ArtifactEntry(key=key, version=1,
                                          path=self.path_for(key))
                    self._entries[key] = entry
                else:
                    entry.version += 1
                entry.pinned = entry.pinned or pin
            path = self.path_for(key)
            if path is not None:
                md.save(path)
            self._install(key, md)
            return md, self._entries[key], "built"

    def _flight(self, key: ArtifactKey) -> threading.Lock:
        with self._lock:
            return self._flights.setdefault(key, threading.Lock())

    def _memory_hit(self, key: ArtifactKey) -> tuple[MiloMetadata, ArtifactEntry] | None:
        with self._lock:
            md = self._memory.get(key)
            if md is None:
                return None
            self._memory.move_to_end(key)
            entry = self._entries[key]
            entry.hits += 1
            self.hits += 1
            return md, entry

    def _disk_load(
        self, key: ArtifactKey, expected_config: dict[str, Any]
    ) -> tuple[MiloMetadata, ArtifactEntry] | None:
        path = self.path_for(key)
        if path is None or not os.path.exists(path):
            return None
        # the reuse guards (same semantics as MiloSession's metadata_path
        # load): the stored config must agree with the request's on every
        # key the request specifies — partial-dict check, because the
        # artifact records MORE than the request config (encoder, seeds,
        # engine provenance) and key[1] hashes only the request's view —
        # and a recorded data fingerprint must match the key's.  A foreign
        # file parked at this key's path fails one of the two.
        md = MiloMetadata.load(path, expected_config=expected_config or None)
        stored_fp = md.config.get("data_fingerprint")
        if stored_fp is not None and stored_fp != key[0]:
            raise MetadataMismatchError(
                f"{path}: artifact was preprocessed over different data "
                f"(fingerprint {stored_fp} != requested {key[0]})"
            )
        with self._lock:
            self.disk_loads += 1
            entry = self._entries.get(key)
            if entry is None:
                # artifact predates this process (written by an earlier
                # server); adopt it at version 1
                entry = ArtifactEntry(key=key, version=1, path=path)
                self._entries[key] = entry
            entry.hits += 1
        self._install(key, md)
        return md, self._entries[key]

    def _install(self, key: ArtifactKey, md: MiloMetadata) -> None:
        """Insert into the memory tier, evicting LRU unpinned entries."""
        with self._lock:
            self._memory[key] = md
            self._memory.move_to_end(key)
            evictable = [
                k for k in self._memory
                if k != key and not self._entries[k].pinned
            ]
            # oldest first (OrderedDict preserves recency order)
            while len(self._memory) > self.capacity and evictable:
                victim = evictable.pop(0)
                del self._memory[victim]
                self.evictions += 1

    # -- introspection ------------------------------------------------------

    def resident(self, key: ArtifactKey) -> bool:
        """Whether the decoded artifact currently sits in the memory tier."""
        with self._lock:
            return key in self._memory

    def failures_for(self, key: ArtifactKey) -> int:
        """Consecutive build failures for ``key`` since its last success."""
        with self._lock:
            return self._key_failures.get(key, 0)

    def entries(self) -> list[ArtifactEntry]:
        with self._lock:
            return [dataclasses.replace(e) for e in self._entries.values()]

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "builds": self.builds,
                "build_failures": self.build_failures,
                "failing_keys": len(self._key_failures),
                "hits": self.hits,
                "disk_loads": self.disk_loads,
                "evictions": self.evictions,
                "resident": len(self._memory),
                "known": len(self._entries),
            }
