"""Selection-as-a-service: the persistent multi-tenant ``MiloServer``.

MILO's central economic claim is that the model-agnostic preprocessing pass
is paid ONCE per (dataset, config) and amortized across every downstream
training and tuning trial.  A batch script realizes that amortization within
one process lifetime; ``MiloServer`` turns it into an operational property —
a long-lived process that N tenants submit train/tune requests to, where

  * the **artifact store** (``repro.serve.store.ArtifactStore``) resolves
    each request's ``(data_fingerprint, config_hash)`` key against memory →
    disk → a single-flight preprocessing build, so concurrent identical
    requests trigger exactly one preprocessing run ever;
  * the **warm program pool** keeps every jitted program a request needs
    compiled before it arrives: ``MiloPreprocessor.warmup`` covers the
    selection engines per class geometry, and one throwaway tune replay per
    (dataset, eval-shape) covers the classifier step / fused-engine /
    accuracy programs.  A warm repeat request records ZERO backend compiles
    (the serving bench asserts this with jax.monitoring's compile counter);
  * the **buffer registry** (``repro.serve.buffers.BufferRegistry``) places
    each dataset column on device once, shared by every concurrent Trainer;
  * the **request lifecycle** layer runs submissions on worker threads with
    per-request deadlines and cancellation (polled between hyperband rungs
    via ``should_stop``), classifies failures transient-vs-permanent and
    retries transient ones under ``RetryPolicy`` (exponential backoff with
    deterministic jitter, interruptible by cancel), and appends one
    structured row per request — including its attempt count — to the
    request log;
  * the **hardening layer** (PR 8) bounds the queue — ``submit`` raises
    ``ServerOverloadedError`` synchronously at ``max_queue`` pending
    requests instead of accepting unbounded work — and puts a per-key
    ``repro.health.CircuitBreaker`` around artifact builds, so a key whose
    build fails deterministically fast-fails (``CircuitOpenError``) after
    ``threshold`` consecutive failures while cached artifacts keep serving;
    ``health()`` reports ok/degraded with the evidence.

``MiloClient`` is the thin synchronous facade a tenant holds; the transport
is in-process (function calls + queues), which is where the interesting
state lives — wire protocols can wrap this without touching the caching
semantics.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import queue
import threading
import time
import weakref
from typing import Any, Callable

import numpy as np

from repro.core.metadata import MiloMetadata, config_hash
from repro.distributed.multihost import HeartbeatMonitor
from repro.health.breaker import CircuitBreaker, CircuitOpenError
from repro.selection.session import (
    MiloSession,
    MiloSessionConfig,
    _data_fingerprint,
)
from repro.serve.buffers import BufferRegistry
from repro.serve.store import ArtifactKey, ArtifactStore


def _with_overrides(
    cfg: MiloSessionConfig, overrides: dict[str, Any] | None
) -> MiloSessionConfig:
    """Per-request config = base config + overrides, with persistence kept
    under the store's control whatever the overrides say."""
    if not overrides:
        return cfg
    ov = dict(overrides)
    ov["metadata_path"] = None
    return dataclasses.replace(cfg, **ov)

class ServerOverloadedError(RuntimeError):
    """Fast-fail at admission: the submit queue is at ``max_queue``.

    Raised synchronously from :meth:`MiloServer.submit` — the request is
    never enqueued, so the caller can shed load or back off on its own
    schedule instead of silently deepening an unbounded queue.  Deliberately
    not transient: retrying into a full queue is the problem, not the fix.
    """


class TransientServeError(RuntimeError):
    """An error the server should retry: the failure is a property of the
    attempt (a flaky artifact build, a contended resource), not of the
    request.  Raise it — or any exception carrying a truthy ``transient``
    attribute — from a handler to opt into the retry policy."""

    transient = True


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter for transient failures.

    Attempt ``k`` (1-indexed) that fails transiently sleeps
    ``min(max_delay, base_delay * 2**(k-1)) * (1 + jitter * u)`` before the
    next try, where ``u ∈ [0, 1)`` is derived by hashing
    ``(request_id, attempt)`` — jittered like production backoff (no
    thundering herd of identical schedules) yet bit-reproducible across
    runs, which is what lets the fault suite assert exact retry behavior.
    The backoff sleep waits on the request's cancel event, so cancellation
    interrupts it immediately.

    ``retry_on`` lists the exception types classified transient; any
    exception with a truthy ``transient`` attribute also qualifies (the
    duck-typed escape hatch for errors the server does not know by type).
    Everything else is permanent and fails the request on first raise.
    """

    max_attempts: int = 3
    base_delay: float = 0.05
    max_delay: float = 2.0
    jitter: float = 0.25
    retry_on: tuple = (TransientServeError, ConnectionError, TimeoutError)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")

    def is_transient(self, exc: BaseException) -> bool:
        return isinstance(exc, self.retry_on) or bool(
            getattr(exc, "transient", False))

    def delay(self, request_id: str, attempt: int) -> float:
        """Backoff before attempt ``attempt + 1``; deterministic per
        (request, attempt)."""
        base = min(self.max_delay, self.base_delay * (2 ** (attempt - 1)))
        if self.jitter <= 0:
            return base
        digest = hashlib.sha256(f"{request_id}:{attempt}".encode()).digest()
        u = int.from_bytes(digest[:8], "little") / 2.0 ** 64
        return base * (1.0 + self.jitter * u)


#: request lifecycle states
QUEUED = "queued"
RUNNING = "running"
DONE = "done"
ERROR = "error"
CANCELLED = "cancelled"
EXPIRED = "expired"

_TERMINAL = frozenset({DONE, ERROR, CANCELLED, EXPIRED})


@dataclasses.dataclass
class ServeRequest:
    """One submitted unit of work and its full lifecycle record."""

    request_id: str
    kind: str                       # "preprocess" | "train" | "tune"
    tenant: str
    payload: dict[str, Any]
    config: MiloSessionConfig
    deadline: float | None = None   # absolute wall-clock time, None = none
    pin: bool = False
    status: str = QUEUED
    result: Any = None
    error: BaseException | None = None
    artifact_key: ArtifactKey | None = None
    artifact_version: int | None = None
    artifact_source: str | None = None   # "memory" | "disk" | "built"
    submitted: float = 0.0
    started: float | None = None
    finished: float | None = None
    attempts: int = 0               # handler invocations (1 + retries)
    cancel_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)
    done_event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False)

    def snapshot(self) -> dict[str, Any]:
        """Structured view for poll() and the request log (no live objects)."""
        return {
            "request_id": self.request_id,
            "kind": self.kind,
            "tenant": self.tenant,
            "status": self.status,
            "artifact_key": self.artifact_key,
            "artifact_version": self.artifact_version,
            "artifact_source": self.artifact_source,
            "submitted": self.submitted,
            "started": self.started,
            "finished": self.finished,
            "attempts": self.attempts,
            "error": repr(self.error) if self.error is not None else None,
        }


def artifact_request_config(cfg: MiloSessionConfig) -> dict[str, Any]:
    """The config view an artifact is keyed and verified on: the base
    reuse-guard keys plus every knob that changes the selection trajectories
    the artifact holds.  Deliberately excludes mesh/runtime knobs
    (``shard_selection``, ``gram_block``, ...) — artifacts are portable
    across those, exactly as ``MiloSession._load_artifact`` tolerates."""
    req = cfg.expected_artifact_config()
    req.update(
        gram_free=cfg.gram_free,
        bucket_classes=cfg.bucket_classes,
        lazy_gains=cfg.lazy_gains,
        exact_sge_candidates=cfg.exact_sge_candidates,
        prep_seed=cfg.resolved_prep_seed(),
    )
    if cfg.lazy_gains:
        req["lazy_threshold"] = cfg.lazy_threshold
    return req


class MiloServer:
    """Persistent multi-tenant selection server (in-process).

    ::

        server = MiloServer(MiloSessionConfig(...), store_root="/tmp/artifacts")
        server.start()
        server.warm(features, labels, val_x=vx, val_y=vy, space=SPACE)
        rid = server.submit("tune", features=..., labels=..., val_x=...,
                            val_y=..., space=SPACE, deadline=30.0)
        best = server.result(rid)          # HyperbandResult
        server.shutdown()

    Also usable as a context manager (``with MiloServer(...) as s:``).
    """

    KINDS = ("preprocess", "train", "tune")

    def __init__(
        self,
        config: MiloSessionConfig | None = None,
        *,
        store_root: str | None = None,
        store_capacity: int = 8,
        num_workers: int = 2,
        retry_policy: RetryPolicy | None = None,
        max_queue: int = 256,
        breaker: CircuitBreaker | None = None,
        heartbeat_dir: str | None = None,
        heartbeat_timeout: float = 60.0,
        heartbeat_monitor: Any | None = None,
        **config_overrides: Any,
    ):
        cfg = config if config is not None else MiloSessionConfig()
        if config_overrides:
            cfg = dataclasses.replace(cfg, **config_overrides)
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        # the store owns persistence; a session-level metadata_path would
        # write a second, unversioned copy outside the server's control
        self.config = dataclasses.replace(cfg, metadata_path=None)
        self.store = ArtifactStore(store_root, capacity=store_capacity)
        self.buffers = BufferRegistry()
        self.num_workers = max(1, int(num_workers))
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy())
        self.max_queue = int(max_queue)
        # per-artifact-key circuit breaker around store builds: a key whose
        # build fails deterministically stops burning worker time after
        # `threshold` consecutive failures (fast CircuitOpenError instead),
        # while cached artifacts for that key keep serving
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        # host liveness (multi-host deployments): health() folds per-host
        # heartbeat ages into its verdict — any stale peer ⇒ "degraded".
        # Pass heartbeat_monitor directly for a custom clock/expected-set;
        # otherwise heartbeat_dir builds one over the shared beacon dir.
        if heartbeat_monitor is not None:
            self.liveness: HeartbeatMonitor | None = heartbeat_monitor
        elif heartbeat_dir is not None:
            self.liveness = HeartbeatMonitor(
                heartbeat_dir, timeout=heartbeat_timeout)
        else:
            self.liveness = None
        self._queued = 0          # admission-controlled queue depth
        self._retries = 0         # transient failures that were retried
        self._failures = 0        # requests that terminated in ERROR
        self._sessions: dict[tuple, MiloSession] = {}
        self._requests: dict[str, ServeRequest] = {}
        self._log: list[dict[str, Any]] = []
        self._warmed: set[tuple] = set()
        self._fp_memo: dict[int, tuple[weakref.ref, str]] = {}
        self._lock = threading.RLock()
        self._queue: "queue.Queue[ServeRequest | None]" = queue.Queue()
        self._ids = itertools.count()
        self._workers: list[threading.Thread] = []
        self._started = False

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "MiloServer":
        with self._lock:
            if self._started:
                return self
            self._started = True
            for i in range(self.num_workers):
                t = threading.Thread(
                    target=self._worker_loop, daemon=True,
                    name=f"milo-serve-worker-{i}",
                )
                t.start()
                self._workers.append(t)
        return self

    def shutdown(self, *, wait: bool = True) -> None:
        """Stop the workers.  Queued requests still drain (each worker exits
        on its sentinel, which sits behind them in the queue)."""
        with self._lock:
            if not self._started:
                return
            self._started = False
            workers, self._workers = self._workers, []
        for _ in workers:
            self._queue.put(None)
        if wait:
            for t in workers:
                t.join()

    def __enter__(self) -> "MiloServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- submission ---------------------------------------------------------

    def submit(
        self,
        kind: str,
        *,
        features: np.ndarray,
        labels: np.ndarray | None = None,
        tenant: str = "default",
        deadline: float | None = None,
        pin: bool = False,
        overrides: dict[str, Any] | None = None,
        **payload: Any,
    ) -> str:
        """Enqueue a request; returns its id immediately.

        ``deadline`` is RELATIVE seconds from submission (converted to an
        absolute wall time here); an expired request never starts, and a
        running tune stops at the next hyperband rung boundary.
        ``overrides`` are per-tenant ``MiloSessionConfig`` field overrides on
        the server's base config — preprocessing-affecting overrides change
        the artifact key, so tenants can never poison each other's cache.
        """
        if kind not in self.KINDS:
            raise ValueError(f"unknown request kind {kind!r}; one of {self.KINDS}")
        if not self._started:
            raise RuntimeError("server not started: call start() first")
        cfg = _with_overrides(self.config, overrides)
        req = ServeRequest(
            request_id=f"r{next(self._ids):06d}",
            kind=kind,
            tenant=tenant,
            payload={"features": features, "labels": labels, **payload},
            config=cfg,
            deadline=(time.time() + deadline) if deadline is not None else None,
            pin=pin,
            submitted=time.time(),
        )
        with self._lock:
            # bounded admission: fail fast at submit time rather than
            # accepting work the workers are hopelessly behind on
            if self._queued >= self.max_queue:
                raise ServerOverloadedError(
                    f"queue full ({self._queued}/{self.max_queue} requests "
                    f"pending); retry later or raise max_queue")
            self._queued += 1
            self._requests[req.request_id] = req
        self._queue.put(req)
        return req.request_id

    def poll(self, request_id: str) -> dict[str, Any]:
        """Non-blocking status snapshot."""
        return self._request(request_id).snapshot()

    def result(self, request_id: str, *, timeout: float | None = None) -> Any:
        """Block until the request reaches a terminal state; return its
        result.  Re-raises the worker's exception for ERROR requests and
        raises ``TimeoutError`` for cancelled/expired ones (the result a
        stopped tune did compute is still on ``poll()``'s ``status`` +
        ``ServeRequest.result``)."""
        req = self._request(request_id)
        if not req.done_event.wait(timeout):
            raise TimeoutError(f"{request_id} still {req.status} after {timeout}s")
        if req.status == ERROR:
            raise req.error
        if req.status in (CANCELLED, EXPIRED):
            raise TimeoutError(f"{request_id} was {req.status}")
        return req.result

    def cancel(self, request_id: str) -> bool:
        """Request cancellation.  Queued requests never start; running tunes
        stop at the next rung boundary.  Returns False once terminal."""
        req = self._request(request_id)
        if req.status in _TERMINAL:
            return False
        req.cancel_event.set()
        return True

    def request_log(self) -> list[dict[str, Any]]:
        """One structured row per COMPLETED request, in completion order."""
        with self._lock:
            return [dict(row) for row in self._log]

    def stats(self) -> dict[str, Any]:
        with self._lock:
            statuses: dict[str, int] = {}
            for r in self._requests.values():
                statuses[r.status] = statuses.get(r.status, 0) + 1
            retries, failures = self._retries, self._failures
        return {
            "requests": statuses,
            "retries": retries,
            "failures": failures,
            "store": self.store.stats(),
            "buffers": self.buffers.stats(),
            "sessions": len(self._sessions),
            "warmed": len(self._warmed),
        }

    def health(self) -> dict[str, Any]:
        """Operational health snapshot (JSON-safe).

        ``status`` is ``"ok"`` when the server is accepting work with every
        circuit closed, ``"degraded"`` when any artifact key's breaker is
        open/half-open, the queue is at capacity, or (when a heartbeat
        monitor is attached) any expected host's beacon is stale, and
        ``"stopped"`` after shutdown.  The rest is the evidence: queue
        depth vs. limit, the per-key breaker snapshot, per-host heartbeat
        ages, store/retry/failure counters.
        """
        with self._lock:
            started = self._started
            queued = self._queued
            retries, failures = self._retries, self._failures
        breakers = self.breaker.snapshot()
        tripped = sorted(
            k for k, st in breakers.items() if st["state"] != "closed")
        hosts = self.liveness.snapshot() if self.liveness is not None else None
        stale_hosts = hosts["stale"] if hosts is not None else []
        if not started:
            status = "stopped"
        elif tripped or stale_hosts or queued >= self.max_queue:
            status = "degraded"
        else:
            status = "ok"
        out = {
            "status": status,
            "queue": {"depth": queued, "limit": self.max_queue},
            "breakers": breakers,
            "tripped_keys": tripped,
            "retries": retries,
            "failures": failures,
            "store": self.store.stats(),
        }
        if hosts is not None:
            out["hosts"] = hosts
        return out

    # -- warm pool ----------------------------------------------------------

    def warm(
        self,
        features: np.ndarray,
        labels: np.ndarray | None = None,
        *,
        val_x: np.ndarray | None = None,
        val_y: np.ndarray | None = None,
        space: dict | None = None,
        pin: bool = True,
        overrides: dict[str, Any] | None = None,
    ) -> dict[str, Any]:
        """Pre-build the artifact and pre-compile every program tune/train
        requests over this dataset will hit.

        Three layers, mirroring what a request touches:
          1. the artifact itself (store build, pinned against eviction),
          2. ``MiloPreprocessor.warmup`` over the dataset's true class
             geometry — covers a future ``force=True`` rebuild,
          3. when ``val_x``/``val_y``/``space`` are given, ONE throwaway tune
             replay with the same shapes — populates the classifier-step /
             fused-engine / eval jit caches (lr is traced, so any lr the
             search samples later reuses these programs).

        Synchronous and idempotent per (artifact, eval-shape) signature;
        call before accepting traffic.  After it, repeat requests record
        zero backend compiles — the bench's acceptance criterion.
        """
        cfg = _with_overrides(self.config, overrides)
        md, key, session, _ = self._ensure_artifact(
            cfg, features, labels, pin=pin)
        sig = (key, None if val_x is None else np.shape(val_x),
               None if space is None else tuple(sorted(space)))
        with self._lock:
            already = sig in self._warmed
        if already:
            return {"artifact_key": key, "warmed_geometries": 0,
                    "tune_replayed": False}
        from repro.core.partition import proportional_budgets

        labs = (np.zeros(len(features), np.int64) if labels is None
                else np.asarray(labels))
        pre = cfg.preprocessor()
        # replay the preprocessor's own decomposition (strategy-aware, so
        # hierarchical geometries warm the same per-partition + refine
        # programs a rebuild would compile)
        parts = pre.partition_strategy().partition(
            labs if cfg.classwise else None, len(features))
        if len(parts) > 1:
            buckets = [(len(p.indices), b)
                       for p, b in zip(parts, proportional_budgets(parts, md.k))]
        else:
            buckets = [(len(features), md.k)]
        warmed = pre.warmup(buckets, d=int(np.shape(features)[1]))
        replayed = False
        if val_x is not None and val_y is not None and space is not None:
            session.tune(features, labels, val_x, val_y, space,
                         max_budget=3, eta=3)
            replayed = True
        with self._lock:
            self._warmed.add(sig)
        return {"artifact_key": key, "warmed_geometries": warmed,
                "tune_replayed": replayed}

    # -- internals ----------------------------------------------------------

    def _request(self, request_id: str) -> ServeRequest:
        with self._lock:
            req = self._requests.get(request_id)
        if req is None:
            raise KeyError(f"unknown request id {request_id!r}")
        return req

    def data_fingerprint(self, features: np.ndarray) -> str:
        """``selection.session._data_fingerprint`` with an identity memo, so
        N requests carrying the same host matrix hash it once."""
        features = np.asarray(features)
        with self._lock:
            cached = self._fp_memo.get(id(features))
            if cached is not None:
                ref, fp = cached
                if ref() is features:
                    return fp
                del self._fp_memo[id(features)]
        fp = _data_fingerprint(features)
        with self._lock:
            try:
                self._fp_memo[id(features)] = (weakref.ref(features), fp)
            except TypeError:  # pragma: no cover — non-weakref-able input
                pass
        return fp

    def _ensure_artifact(
        self,
        cfg: MiloSessionConfig,
        features: np.ndarray,
        labels: np.ndarray | None,
        *,
        pin: bool = False,
        force: bool = False,
    ) -> tuple[MiloMetadata, ArtifactKey, MiloSession, tuple[int, str]]:
        """Resolve (or single-flight build) the request's artifact and the
        session that serves it; returns (md, key, session, (version, source))."""
        req_config = artifact_request_config(cfg)
        fp = self.data_fingerprint(features)
        key = self.store.key_for(fp, req_config)
        session = self._session_for(key, cfg)

        def guarded_build() -> MiloMetadata:
            # the breaker gates BUILDS only — memory/disk hits for the key
            # keep serving while its circuit is open (a cached artifact is
            # fine; re-paying a deterministically-failing build is not)
            self.breaker.check(key)
            try:
                md = session.build_metadata(features, labels, fingerprint=fp)
            except CircuitOpenError:
                raise
            except BaseException:
                self.breaker.record_failure(key)
                raise
            self.breaker.record_success(key)
            return md

        md, entry, source = self.store.get_or_build(
            key, req_config, guarded_build, pin=pin, force=force,
        )
        if session.metadata is not md:
            session.adopt_metadata(md, loaded=source != "built")
        return md, key, session, (entry.version, source)

    def _session_for(self, key: ArtifactKey, cfg: MiloSessionConfig) -> MiloSession:
        """One session per (artifact, downstream-config): jit-warm state and
        adopted metadata persist across requests.  Sessions share the
        server's buffer registry, so their Trainers share device columns."""
        skey = (key, config_hash(dataclasses.asdict(cfg)))
        with self._lock:
            sess = self._sessions.get(skey)
            if sess is None:
                sess = MiloSession(cfg, buffer_registry=self.buffers)
                self._sessions[skey] = sess
            return sess

    def _worker_loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                return
            with self._lock:
                self._queued -= 1
            self._execute(req)

    def _finish(self, req: ServeRequest, status: str) -> None:
        req.status = status
        req.finished = time.time()
        req.done_event.set()
        with self._lock:
            self._log.append(req.snapshot())

    def _should_retry(self, req: ServeRequest, exc: BaseException) -> bool:
        """Retry iff the error is transient, attempts remain, and the
        request is still live (not cancelled, deadline not passed)."""
        policy = self.retry_policy
        if not policy.is_transient(exc):
            return False
        if req.attempts >= policy.max_attempts:
            return False
        if req.cancel_event.is_set():
            return False
        if req.deadline is not None and time.time() > req.deadline:
            return False
        return True

    def _execute(self, req: ServeRequest) -> None:
        if req.cancel_event.is_set():
            self._finish(req, CANCELLED)
            return
        if req.deadline is not None and time.time() > req.deadline:
            self._finish(req, EXPIRED)
            return
        req.status = RUNNING
        req.started = time.time()
        handler: Callable[[ServeRequest], Any] = getattr(self, f"_run_{req.kind}")
        while True:
            req.attempts += 1
            try:
                req.result = handler(req)
            except BaseException as e:  # noqa: BLE001 — re-raised in result()
                req.error = e
                if not self._should_retry(req, e):
                    with self._lock:
                        self._failures += 1
                    self._finish(req, ERROR)
                    return
                with self._lock:
                    self._retries += 1
                # backoff on the cancel event: a cancel() mid-backoff wakes
                # the wait immediately instead of sleeping the delay out
                if req.cancel_event.wait(
                        self.retry_policy.delay(req.request_id, req.attempts)):
                    self._finish(req, CANCELLED)
                    return
                continue
            # a retried-then-succeeded request is a success, not an error
            req.error = None
            break
        stopped = bool(getattr(req.result, "stopped", False))
        if req.cancel_event.is_set():
            self._finish(req, CANCELLED)
        elif stopped or (req.deadline is not None and time.time() > req.deadline):
            # a tune that should_stop ended early, or a train that ran past
            # its deadline (trains have no mid-run poll point)
            self._finish(req, EXPIRED)
        else:
            self._finish(req, DONE)

    def _resolve(self, req: ServeRequest, *, pin: bool = False,
                 force: bool = False) -> tuple[MiloMetadata, MiloSession]:
        p = req.payload
        md, key, session, (version, source) = self._ensure_artifact(
            req.config, p["features"], p["labels"],
            pin=pin or req.pin, force=force,
        )
        req.artifact_key = key
        req.artifact_version = version
        req.artifact_source = source
        return md, session

    # -- request handlers ---------------------------------------------------

    def _run_preprocess(self, req: ServeRequest) -> dict[str, Any]:
        _, _ = self._resolve(req, force=bool(req.payload.get("force", False)))
        return {
            "artifact_key": req.artifact_key,
            "version": req.artifact_version,
            "source": req.artifact_source,
        }

    def _run_train(self, req: ServeRequest):
        _, session = self._resolve(req)
        p = dict(req.payload)
        features, labels = p.pop("features"), p.pop("labels")
        p.pop("force", None)
        return session.train(features, labels, **p)

    def _run_tune(self, req: ServeRequest):
        _, session = self._resolve(req)
        p = dict(req.payload)
        features, labels = p.pop("features"), p.pop("labels")
        p.pop("force", None)

        def should_stop() -> bool:
            return req.cancel_event.is_set() or (
                req.deadline is not None and time.time() > req.deadline
            )

        return session.tune(features, labels, should_stop=should_stop, **p)


class MiloClient:
    """Thin synchronous tenant facade over one ``MiloServer``."""

    def __init__(self, server: MiloServer, *, tenant: str = "default",
                 overrides: dict[str, Any] | None = None):
        self.server = server
        self.tenant = tenant
        self.overrides = dict(overrides) if overrides else None

    def _submit(self, kind: str, **kw: Any) -> str:
        return self.server.submit(
            kind, tenant=self.tenant, overrides=self.overrides, **kw)

    def preprocess(self, features, labels=None, *, pin: bool = False,
                   force: bool = False, deadline: float | None = None):
        rid = self._submit("preprocess", features=features, labels=labels,
                           pin=pin, force=force, deadline=deadline)
        return self.server.result(rid)

    def train(self, features, labels, *, test_x, test_y,
              deadline: float | None = None, **kw: Any):
        rid = self._submit("train", features=features, labels=labels,
                           test_x=test_x, test_y=test_y, deadline=deadline, **kw)
        return self.server.result(rid)

    def tune(self, features, labels, val_x, val_y, space, *,
             deadline: float | None = None, **kw: Any):
        rid = self._submit("tune", features=features, labels=labels,
                           val_x=val_x, val_y=val_y, space=space,
                           deadline=deadline, **kw)
        return self.server.result(rid)

    # async variants: submit now, collect with server.poll/result later
    def submit_tune(self, features, labels, val_x, val_y, space, *,
                    deadline: float | None = None, **kw: Any) -> str:
        return self._submit("tune", features=features, labels=labels,
                            val_x=val_x, val_y=val_y, space=space,
                            deadline=deadline, **kw)

    def submit_train(self, features, labels, *, test_x, test_y,
                     deadline: float | None = None, **kw: Any) -> str:
        return self._submit("train", features=features, labels=labels,
                            test_x=test_x, test_y=test_y, deadline=deadline,
                            **kw)
