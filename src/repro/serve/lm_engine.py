"""Batched LM serving engine: continuous prefill + decode over a fixed-slot
request pool (vLLM-style slot management, JAX-native static shapes).

The engine owns a KV cache of ``max_batch`` slots x ``max_len`` positions.
Requests enter a queue; free slots are prefilled (one request at a time on
CPU; batched prefill on a real pod), and every ``step()`` decodes one token
for all active slots.  Finished requests (EOS or length) free their slot.

Static shapes everywhere — the decode step compiles once; slot turnover is
pure data movement.  This is the LM serving-side end-to-end driver
(deliverable (b)): see examples/serve_lm.py.  The *selection*-serving
subsystem (``MiloServer``: artifact store, warm compiled-program pool,
shared device buffers) lives next door in ``repro.serve.server``; the two
engines serve different workloads and share only the package.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new_tokens: int = 32
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 4,
                 max_len: int = 128, eos_id: int | None = None,
                 sampler: Callable | None = None):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = lm.init_caches(cfg, max_batch, max_len)
        self.slot_req: list[Request | None] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)     # next write position
        self.slot_budget = np.zeros(max_batch, np.int32)  # remaining new tokens
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        self._decode = jax.jit(
            lambda p, c, tok, pos: lm.decode_step(p, cfg, tok, c, pos)
        )
        # per-slot prefill uses a batch-1 forward then writes into the pool
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(
                p, cfg, toks, lm.init_caches(cfg, 1, self.max_len)
            )
        )

    # -- queue management ----------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            logits, cache1 = self._prefill(self.params, req.prompt[None, :])
            first = int(np.argmax(np.asarray(logits)[0, -1]))
            req.generated.append(first)
            # copy the request's prefill state into the pool at ``slot`` —
            # every cache leaf (KV, SSM state, per-slot lengths) has the
            # batch at dim 1
            self.caches = jax.tree.map(
                lambda pool, one: pool.at[:, slot : slot + 1].set(one.astype(pool.dtype)),
                self.caches,
                cache1,
            )
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            self.slot_budget[slot] = req.max_new_tokens - 1
            self.last_token[slot, 0] = first

    # -- decode --------------------------------------------------------------

    def step(self) -> int:
        """Admit waiting requests, decode one token for all active slots.

        Returns the number of active slots stepped.
        """
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        # per-slot positions: each slot decodes at its own cache length (the
        # KVCache.length leaves track this inside the model; rope positions
        # come from the same per-slot vector)
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.last_token), pos
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.generated.append(tok)
            self.slot_pos[i] += 1
            self.last_token[i, 0] = tok
            self.slot_budget[i] -= 1
            if self.slot_budget[i] <= 0 or (self.eos_id is not None and tok == self.eos_id) \
               or self.slot_pos[i] >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
        return len(active)

    def run(self, max_steps: int = 1000) -> list[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and steps < max_steps:
            if self.step() == 0 and not self.queue:
                break
            steps += 1
        return self.finished
