"""repro.serve — selection-as-a-service.

* ``MiloServer`` / ``MiloClient`` — persistent multi-tenant selection
  server: versioned artifact store, warm compiled-program pool, shared
  device buffers, worker-thread request lifecycle (submit/poll/result/
  cancel, deadlines, transient-failure retry under ``RetryPolicy``,
  structured request log, bounded-queue admission raising
  ``ServerOverloadedError``, per-key ``CircuitBreaker`` around artifact
  builds, ``health()`` endpoint).
* ``ArtifactStore`` — (data_fingerprint, config_hash)-keyed two-tier
  (memory LRU + disk) ``MiloMetadata`` store with single-flight builds,
  pinning, and per-key versions.
* ``BufferRegistry`` — device-resident column dedup: N concurrent
  Trainers over one dataset share one ``device_put`` per column.
* ``ServeEngine`` (``repro.serve.lm_engine``) — the separate batched LM
  decode engine; unrelated workload, same package.
"""
from repro.health.breaker import CircuitBreaker, CircuitOpenError
from repro.serve.buffers import BufferRegistry, array_fingerprint
from repro.serve.server import (
    CANCELLED,
    DONE,
    ERROR,
    EXPIRED,
    QUEUED,
    RUNNING,
    MiloClient,
    MiloServer,
    RetryPolicy,
    ServeRequest,
    ServerOverloadedError,
    TransientServeError,
    artifact_request_config,
)
from repro.serve.store import ArtifactEntry, ArtifactKey, ArtifactStore

__all__ = [
    "ArtifactEntry",
    "ArtifactKey",
    "ArtifactStore",
    "BufferRegistry",
    "CircuitBreaker",
    "CircuitOpenError",
    "MiloClient",
    "MiloServer",
    "RetryPolicy",
    "ServeRequest",
    "ServerOverloadedError",
    "TransientServeError",
    "array_fingerprint",
    "artifact_request_config",
    "QUEUED",
    "RUNNING",
    "DONE",
    "ERROR",
    "CANCELLED",
    "EXPIRED",
]
