"""Shared device-resident feature buffers (``BufferRegistry``).

Every ``Trainer`` on the device-resident fused path needs its pipeline's
column store (``arrays={"x": feats, "y": labs}``) placed on device.  Without
sharing, N concurrent train/tune requests against the same dataset pay N
``device_put`` transfers and hold N copies of an O(n·d) feature matrix in
device memory.  The registry deduplicates them: a column is placed once and
every consumer receives the SAME device buffer object (buffers are never
donated — ``train.engine`` donates only the train state — so sharing is
safe).

Keying is two-tier, per column:

  * **identity fast path** — ``id(array)`` (guarded by a weakref so a
    recycled id can never alias a dead array) maps straight to the placed
    buffer; repeat requests with the same host array never rehash it.
  * **content fingerprint** — otherwise the column is hashed
    (sha256 of bytes + shape + dtype, the same scheme as the artifact
    store's data fingerprint), so two *equal* arrays owned by different
    clients still share one device buffer.

``put_count`` counts actual device placements and ``hits`` counts reuses —
the observable behind the "N Trainers, one buffer" test and bench claims.
"""
from __future__ import annotations

import hashlib
import threading
import weakref

import jax.numpy as jnp
import numpy as np


def array_fingerprint(arr: np.ndarray) -> str:
    """Content identity of one host column (dtype/shape-qualified)."""
    a = np.ascontiguousarray(arr)
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:16]


class BufferRegistry:
    """Device-resident column cache keyed on array identity/fingerprint."""

    def __init__(self):
        self._lock = threading.RLock()
        self._buffers: dict[str, jnp.ndarray] = {}          # fingerprint -> device buffer
        self._id_cache: dict[int, tuple[weakref.ref, str]] = {}  # id -> (ref, fp)
        self.put_count = 0
        self.hits = 0

    # -- fingerprinting -----------------------------------------------------

    def fingerprint(self, arr: np.ndarray) -> str:
        """``array_fingerprint`` with an identity memo: the same host array
        object is hashed once, however many requests carry it."""
        arr = np.asarray(arr)
        with self._lock:
            cached = self._id_cache.get(id(arr))
            if cached is not None:
                ref, fp = cached
                if ref() is arr:
                    return fp
                del self._id_cache[id(arr)]  # id was recycled
        fp = array_fingerprint(arr)
        with self._lock:
            try:
                self._id_cache[id(arr)] = (weakref.ref(arr), fp)
            except TypeError:  # pragma: no cover — non-weakref-able view
                pass
        return fp

    # -- placement ----------------------------------------------------------

    def column(self, arr: np.ndarray) -> jnp.ndarray:
        """The shared device buffer for one host column (placed on first
        request, reused afterwards)."""
        fp = self.fingerprint(arr)
        with self._lock:
            buf = self._buffers.get(fp)
            if buf is not None:
                self.hits += 1
                return buf
        placed = jnp.asarray(arr)
        with self._lock:
            # lost a race: keep the first placement so identity stays stable
            buf = self._buffers.get(fp)
            if buf is not None:
                self.hits += 1
                return buf
            self._buffers[fp] = placed
            self.put_count += 1
            return placed

    def get(self, arrays: dict[str, np.ndarray]) -> dict[str, jnp.ndarray]:
        """Shared device buffers for a pipeline column store."""
        return {k: self.column(v) for k, v in arrays.items()}

    # -- lifecycle ----------------------------------------------------------

    def release(self, arr_or_fp) -> bool:
        """Drop one column (by host array or fingerprint) from the registry.
        Existing consumers keep their references; only future sharing stops."""
        fp = arr_or_fp if isinstance(arr_or_fp, str) else self.fingerprint(arr_or_fp)
        with self._lock:
            return self._buffers.pop(fp, None) is not None

    def clear(self) -> None:
        with self._lock:
            self._buffers.clear()
            self._id_cache.clear()

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "resident_columns": len(self._buffers),
                "put_count": self.put_count,
                "hits": self.hits,
            }
