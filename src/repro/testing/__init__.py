"""Test-support utilities shipped with the library.

``repro.testing.faults`` is the deterministic fault-injection harness the
fault-tolerance suite and the CI ``fault-smoke`` job drive: process kills at
a chosen training step, scripted build-callable failures, slow-step
injection, and checkpoint corruption — all counter-driven, never random, so
every injected failure is replayable.
"""
from repro.testing.faults import (
    FaultInjected,
    KillAtStep,
    TransientFault,
    corrupt_checkpoint,
    fail_nth_calls,
    flaky,
    slow_steps,
)

__all__ = [
    "FaultInjected",
    "KillAtStep",
    "TransientFault",
    "corrupt_checkpoint",
    "fail_nth_calls",
    "flaky",
    "slow_steps",
]
