"""Deterministic fault-injection harness.

Fault-tolerance claims are only as strong as the faults they were tested
against, and randomized fault injection makes failures unreproducible.
Everything here is **counter-driven**: a fault fires on an exact call number
or training step, so a failing run replays bit-identically under the same
schedule.

Injectable faults (each maps to one failure mode the execution layer must
survive):

  * ``KillAtStep``      — SIGKILL the process at training step N (a drop-in
    ``StragglerMonitor``: assign it to ``trainer.monitor`` and the kill
    lands at the first step/segment boundary >= N, i.e. mid-epoch for any N
    that is not a multiple of the epoch length).  The process dies without
    unwinding — exactly what preemption looks like to the checkpoint layer.
  * ``flaky`` / ``fail_nth_calls`` — scripted exceptions from any callable
    (artifact builds, objectives): fail the first K calls, or an explicit
    set of call numbers, then delegate.  Used to prove single-flight lock
    release, server retry/backoff, and hyperband resume.
  * ``slow_steps``      — host-side sleeps on chosen step numbers, for
    straggler-detection tests with a known ground truth.
  * ``corrupt_checkpoint`` — truncate or bit-flip a written checkpoint's
    shard / manifest, for ``latest_valid_step`` skip-torn-checkpoint tests.
"""
from __future__ import annotations

import functools
import os
import signal
from typing import Any, Callable, Collection

from repro.distributed.fault_tolerance import StragglerMonitor


class FaultInjected(RuntimeError):
    """Base class for every harness-raised exception."""


class TransientFault(FaultInjected):
    """An injected failure the caller is expected to retry.

    Carries the duck-typed ``transient`` marker the serving layer's
    ``RetryPolicy`` classifies on, so injecting it exercises the real
    retry path without registering harness types in production config.
    """

    transient = True


def kill_process() -> None:
    """SIGKILL the current process — no cleanup, no atexit, no flushing.

    This is what preemption / OOM-kill looks like to everything the process
    was mid-way through writing; only crash-safe state survives it.
    """
    os.kill(os.getpid(), signal.SIGKILL)


class KillAtStep(StragglerMonitor):
    """A ``StragglerMonitor`` that SIGKILLs the process at a chosen step.

    The trainer calls ``monitor.stop(global_step)`` after every step (loop
    path) or segment (fused path), so assigning ``trainer.monitor =
    KillAtStep(kill_step)`` plants a deterministic crash at the first
    boundary whose global step reaches ``kill_step`` — *before* any
    checkpoint scheduled at that boundary is written, exactly like a
    preemption landing between compute and save.
    """

    def __init__(self, kill_step: int, **monitor_kwargs: Any):
        super().__init__(**monitor_kwargs)
        self.kill_step = kill_step

    def observe(self, step: int, dt: float) -> bool:
        if step >= self.kill_step:
            kill_process()
        return super().observe(step, dt)


def flaky(
    fn: Callable[..., Any],
    *,
    failures: int,
    exc: Callable[[str], BaseException] = TransientFault,
) -> Callable[..., Any]:
    """Wrap ``fn`` to raise on its first ``failures`` calls, then delegate.

    The wrapper exposes ``calls`` (total invocations) and
    ``failures_injected`` counters for assertions.
    """
    return fail_nth_calls(fn, fail_on=range(1, failures + 1), exc=exc)


def fail_nth_calls(
    fn: Callable[..., Any],
    *,
    fail_on: Collection[int],
    exc: Callable[[str], BaseException] = TransientFault,
) -> Callable[..., Any]:
    """Wrap ``fn`` to raise on an explicit set of (1-indexed) call numbers.

    ``fail_on={3}`` lets a test crash exactly the third artifact build or
    the third hyperband rung evaluation — the deterministic analogue of "the
    job died somewhere in the middle".
    """
    fail_set = frozenset(int(n) for n in fail_on)

    @functools.wraps(fn)
    def wrapper(*args: Any, **kwargs: Any) -> Any:
        wrapper.calls += 1
        if wrapper.calls in fail_set:
            wrapper.failures_injected += 1
            raise exc(f"injected fault on call {wrapper.calls} of "
                      f"{getattr(fn, '__name__', fn)!r}")
        return fn(*args, **kwargs)

    wrapper.calls = 0
    wrapper.failures_injected = 0
    return wrapper


def slow_steps(
    train_step: Callable[..., Any],
    *,
    slow: Collection[int],
    delay: float,
) -> Callable[..., Any]:
    """Wrap a train step to sleep ``delay`` seconds before chosen calls.

    Call numbers are 1-indexed; on the fused path the wrapped step is traced
    (not called per step), so apply this on the loop path where per-step
    wall time is observable.  The sleep happens on the host before dispatch,
    which is exactly where a straggling input pipeline or a contended host
    shows up.
    """
    import time

    slow_set = frozenset(int(n) for n in slow)

    @functools.wraps(train_step)
    def wrapper(*args: Any, **kwargs: Any) -> Any:
        wrapper.calls += 1
        if wrapper.calls in slow_set:
            time.sleep(delay)
        return train_step(*args, **kwargs)

    wrapper.calls = 0
    return wrapper


#: corruption modes -> what they simulate
CORRUPTION_MODES = (
    "truncate_shard",     # crash mid shard write / lost trailing pages
    "flip_shard_byte",    # silent media corruption inside the payload
    "truncate_manifest",  # torn manifest JSON
    "delete_shard",       # shard file lost entirely
)


def corrupt_checkpoint(
    directory: str, step: int, *, mode: str = "truncate_shard"
) -> str:
    """Deterministically damage checkpoint ``step_<step>`` under ``directory``.

    Returns the path of the file that was damaged.  Every mode must be
    caught by ``CheckpointManager.validate_step`` and skipped by
    ``latest_valid_step`` — that is the contract the fault-tolerance suite
    pins down.
    """
    if mode not in CORRUPTION_MODES:
        raise ValueError(f"unknown corruption mode {mode!r}; one of "
                         f"{CORRUPTION_MODES}")
    path = os.path.join(directory, f"step_{step}")
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint directory {path}")
    manifest = os.path.join(path, "manifest.json")
    shards = sorted(
        os.path.join(path, f) for f in os.listdir(path)
        if f.startswith("shard_") and f.endswith(".npz")
    )
    if mode == "truncate_manifest":
        size = os.path.getsize(manifest)
        with open(manifest, "r+b") as f:
            f.truncate(max(1, size // 2))
        return manifest
    if not shards:
        raise FileNotFoundError(f"no shard files under {path}")
    target = shards[0]
    if mode == "delete_shard":
        os.remove(target)
    elif mode == "truncate_shard":
        size = os.path.getsize(target)
        with open(target, "r+b") as f:
            f.truncate(max(1, size // 2))
    elif mode == "flip_shard_byte":
        size = os.path.getsize(target)
        with open(target, "r+b") as f:
            # flip a byte in the back half: inside the zip payload, past the
            # npz header, so the damage is to array bytes not file framing
            pos = max(0, size - max(1, size // 4))
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0xFF]))
    return target
