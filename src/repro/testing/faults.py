"""Deterministic fault-injection harness.

Fault-tolerance claims are only as strong as the faults they were tested
against, and randomized fault injection makes failures unreproducible.
Everything here is **counter-driven**: a fault fires on an exact call number
or training step, so a failing run replays bit-identically under the same
schedule.

Injectable faults (each maps to one failure mode the execution layer must
survive):

  * ``KillAtStep``      — SIGKILL the process at training step N (a drop-in
    ``StragglerMonitor``: assign it to ``trainer.monitor`` and the kill
    lands at the first step/segment boundary >= N, i.e. mid-epoch for any N
    that is not a multiple of the epoch length).  The process dies without
    unwinding — exactly what preemption looks like to the checkpoint layer.
  * ``KillHost``        — the multi-host variant: SIGKILL only when this
    process's ``jax.process_index()`` matches, so one host of a
    ``launch_hosts`` job dies at an exact step boundary while its peers run
    on into dead-host detection (heartbeat timeout / checkpoint-barrier
    timeout → ``HostLossError``).
  * ``launch_hosts``    — the multi-process harness itself: picks a free
    coordinator port, spawns N copies of a ``python -c`` script with the
    ``MILO_COORDINATOR``/``MILO_NUM_PROCESSES``/``MILO_PROCESS_ID`` env
    triplet ``multihost.initialize()`` reads, and collects per-process
    (returncode, stdout, stderr).
  * ``flaky`` / ``fail_nth_calls`` — scripted exceptions from any callable
    (artifact builds, objectives): fail the first K calls, or an explicit
    set of call numbers, then delegate.  Used to prove single-flight lock
    release, server retry/backoff, and hyperband resume.
  * ``slow_steps``      — host-side sleeps on chosen step numbers, for
    straggler-detection tests with a known ground truth.
  * ``corrupt_checkpoint`` — truncate or bit-flip a written checkpoint's
    shard / manifest, for ``latest_valid_step`` skip-torn-checkpoint tests.
  * ``nan_at_step``     — poisons one exact training step's update and loss
    with NaN *inside the trace* (scan-compatible), the ground truth for
    divergence-guard skip/rollback tests.
  * ``poison_features`` — plants non-finite / zero rows at exact indices in
    a feature matrix, the ground truth for input-firewall tests.
  * ``fail_objective_for_configs`` — scripted hyperband objective failures
    for an exact set of configs, the ground truth for trial-quarantine
    tests.
"""
from __future__ import annotations

import functools
import os
import signal
from typing import Any, Callable, Collection

from repro.distributed.fault_tolerance import StragglerMonitor


class FaultInjected(RuntimeError):
    """Base class for every harness-raised exception."""


class TransientFault(FaultInjected):
    """An injected failure the caller is expected to retry.

    Carries the duck-typed ``transient`` marker the serving layer's
    ``RetryPolicy`` classifies on, so injecting it exercises the real
    retry path without registering harness types in production config.
    """

    transient = True


def kill_process() -> None:
    """SIGKILL the current process — no cleanup, no atexit, no flushing.

    This is what preemption / OOM-kill looks like to everything the process
    was mid-way through writing; only crash-safe state survives it.
    """
    os.kill(os.getpid(), signal.SIGKILL)


class KillAtStep(StragglerMonitor):
    """A ``StragglerMonitor`` that SIGKILLs the process at a chosen step.

    The trainer calls ``monitor.stop(global_step)`` after every step (loop
    path) or segment (fused path), so assigning ``trainer.monitor =
    KillAtStep(kill_step)`` plants a deterministic crash at the first
    boundary whose global step reaches ``kill_step`` — *before* any
    checkpoint scheduled at that boundary is written, exactly like a
    preemption landing between compute and save.
    """

    def __init__(self, kill_step: int, **monitor_kwargs: Any):
        super().__init__(**monitor_kwargs)
        self.kill_step = kill_step

    def observe(self, step: int, dt: float) -> bool:
        if step >= self.kill_step:
            kill_process()
        return super().observe(step, dt)


class KillHost(KillAtStep):
    """SIGKILL one specific host of a multi-process job at a step boundary.

    Drop-in for ``trainer.monitor`` on EVERY host (the schedule must be
    identical everywhere or the surviving hosts' step streams would
    diverge); only the host whose ``jax.process_index()`` matches
    ``process_to_kill`` actually dies.  The survivors then hit dead-host
    detection — a stale heartbeat or an unreached checkpoint barrier —
    and exit with ``HostLossError``, which is the restart contract the
    kill-and-resume bit-identity test drives end to end.
    """

    def __init__(self, kill_step: int, process_to_kill: int = 1,
                 **monitor_kwargs: Any):
        super().__init__(kill_step, **monitor_kwargs)
        self.process_to_kill = process_to_kill

    def observe(self, step: int, dt: float) -> bool:
        import jax

        if step >= self.kill_step and jax.process_index() == self.process_to_kill:
            kill_process()
        return StragglerMonitor.observe(self, step, dt)


def free_port() -> int:
    """An OS-assigned free TCP port (for the jax coordination service)."""
    import socket

    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


class HostResult:
    """One launched host's outcome: returncode / stdout / stderr."""

    def __init__(self, process_id: int, returncode: int, stdout: str, stderr: str):
        self.process_id = process_id
        self.returncode = returncode
        self.stdout = stdout
        self.stderr = stderr

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"HostResult(process_id={self.process_id}, "
                f"returncode={self.returncode})")


def launch_hosts(
    script: str,
    argv: list[str],
    *,
    num_processes: int = 2,
    env: dict[str, str] | None = None,
    timeout: float = 600.0,
    cwd: str | None = None,
) -> list[HostResult]:
    """Run ``script`` as ``num_processes`` coordinated jax processes.

    Spawns ``python -c script argv...`` once per process with the
    ``MILO_*`` env triplet ``multihost.initialize()`` consumes (one shared
    free coordinator port), waits for ALL of them, and returns their
    results in process order.  No return code policy is imposed here — a
    kill test asserts ``-SIGKILL`` on the victim and nonzero on the
    survivors, a happy-path test asserts all zero.
    """
    import subprocess
    import sys

    port = free_port()
    procs = []
    base_env = dict(os.environ)
    if env:
        base_env.update(env)
    for i in range(num_processes):
        e = dict(base_env)
        e.update(
            MILO_COORDINATOR=f"localhost:{port}",
            MILO_NUM_PROCESSES=str(num_processes),
            MILO_PROCESS_ID=str(i),
        )
        procs.append(subprocess.Popen(
            [sys.executable, "-c", script, *[str(a) for a in argv]],
            env=e, cwd=cwd,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        ))
    results = []
    try:
        for i, p in enumerate(procs):
            out, err = p.communicate(timeout=timeout)
            results.append(HostResult(i, p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.communicate()
    return results


def flaky(
    fn: Callable[..., Any],
    *,
    failures: int,
    exc: Callable[[str], BaseException] = TransientFault,
) -> Callable[..., Any]:
    """Wrap ``fn`` to raise on its first ``failures`` calls, then delegate.

    The wrapper exposes ``calls`` (total invocations) and
    ``failures_injected`` counters for assertions.
    """
    return fail_nth_calls(fn, fail_on=range(1, failures + 1), exc=exc)


def fail_nth_calls(
    fn: Callable[..., Any],
    *,
    fail_on: Collection[int],
    exc: Callable[[str], BaseException] = TransientFault,
) -> Callable[..., Any]:
    """Wrap ``fn`` to raise on an explicit set of (1-indexed) call numbers.

    ``fail_on={3}`` lets a test crash exactly the third artifact build or
    the third hyperband rung evaluation — the deterministic analogue of "the
    job died somewhere in the middle".
    """
    fail_set = frozenset(int(n) for n in fail_on)

    @functools.wraps(fn)
    def wrapper(*args: Any, **kwargs: Any) -> Any:
        wrapper.calls += 1
        if wrapper.calls in fail_set:
            wrapper.failures_injected += 1
            raise exc(f"injected fault on call {wrapper.calls} of "
                      f"{getattr(fn, '__name__', fn)!r}")
        return fn(*args, **kwargs)

    wrapper.calls = 0
    wrapper.failures_injected = 0
    return wrapper


def slow_steps(
    train_step: Callable[..., Any],
    *,
    slow: Collection[int],
    delay: float,
) -> Callable[..., Any]:
    """Wrap a train step to sleep ``delay`` seconds before chosen calls.

    Call numbers are 1-indexed; on the fused path the wrapped step is traced
    (not called per step), so apply this on the loop path where per-step
    wall time is observable.  The sleep happens on the host before dispatch,
    which is exactly where a straggling input pipeline or a contended host
    shows up.
    """
    import time

    slow_set = frozenset(int(n) for n in slow)

    @functools.wraps(train_step)
    def wrapper(*args: Any, **kwargs: Any) -> Any:
        wrapper.calls += 1
        if wrapper.calls in slow_set:
            time.sleep(delay)
        return train_step(*args, **kwargs)

    wrapper.calls = 0
    return wrapper


def nan_at_step(
    train_step: Callable[..., Any], *, step: int
) -> Callable[..., Any]:
    """Wrap a train step so the step numbered ``step`` diverges to NaN.

    The fault fires when the *incoming* ``state.step`` counter equals
    ``step`` (the state the trainer's global step tracks), implemented with
    ``jnp.where`` on a traced predicate — so it works identically under the
    per-batch loop and inside a fused ``lax.scan`` superstep, and the same
    schedule replays bit-identically after a crash.  Every floating leaf of
    the new state and metrics is poisoned (a real divergence takes the
    parameters with it, not just the loss), so an unguarded run is visibly
    wrecked from this step on while a guarded run must skip or roll back.
    """
    import jax
    import jax.numpy as jnp

    target = int(step)

    @functools.wraps(train_step)
    def wrapper(state: Any, batch: Any) -> Any:
        new_state, metrics = train_step(state, batch)
        hit = state.step == target

        def nanify(x):
            x = jnp.asarray(x)
            if not jnp.issubdtype(x.dtype, jnp.floating):
                return x
            return jnp.where(hit, jnp.full_like(x, jnp.nan), x)

        return jax.tree.map(nanify, new_state), jax.tree.map(nanify, metrics)

    return wrapper


def poison_features(
    features: Any,
    *,
    nan_rows: Collection[int] = (),
    inf_rows: Collection[int] = (),
    zero_rows: Collection[int] = (),
) -> Any:
    """Return a copy of ``features`` with exact rows poisoned.

    ``nan_rows`` / ``inf_rows`` become all-NaN / all-inf (non-finite input),
    ``zero_rows`` become exact zero vectors (the silent ``normalize_rows``
    hazard the firewall screens for).  Indices are explicit — never sampled
    — so every firewall test has a known ground truth to assert against.
    """
    import numpy as np

    out = np.array(features, copy=True)
    if not np.issubdtype(out.dtype, np.floating):
        raise TypeError(
            f"poison_features needs a floating dtype to hold NaN/inf, "
            f"got {out.dtype}")
    for i in nan_rows:
        out[int(i)] = np.nan
    for i in inf_rows:
        out[int(i)] = np.inf
    for i in zero_rows:
        out[int(i)] = 0.0
    return out


def fail_objective_for_configs(
    objective: Callable[..., Any],
    *,
    fail_configs: Collection[dict],
    exc: Callable[[str], BaseException] = FaultInjected,
) -> Callable[..., Any]:
    """Wrap a hyperband objective to raise for an exact set of configs.

    Configs are matched structurally (``tuple(sorted(cfg.items()))``), so a
    scripted failure follows its trial through every rung it is promoted to
    — the deterministic analogue of "this hyperparameter combination always
    diverges".  The wrapper exposes ``calls`` and ``failures_injected``
    counters for assertions.
    """
    fail_set = frozenset(tuple(sorted(c.items())) for c in fail_configs)

    @functools.wraps(objective)
    def wrapper(config: dict, budget: Any) -> Any:
        wrapper.calls += 1
        if tuple(sorted(config.items())) in fail_set:
            wrapper.failures_injected += 1
            raise exc(f"injected objective failure for config {config!r}")
        return objective(config, budget)

    wrapper.calls = 0
    wrapper.failures_injected = 0
    return wrapper


#: corruption modes -> what they simulate
CORRUPTION_MODES = (
    "truncate_shard",     # crash mid shard write / lost trailing pages
    "flip_shard_byte",    # silent media corruption inside the payload
    "truncate_manifest",  # torn manifest JSON
    "delete_shard",       # shard file lost entirely
)


def corrupt_checkpoint(
    directory: str, step: int, *, mode: str = "truncate_shard"
) -> str:
    """Deterministically damage checkpoint ``step_<step>`` under ``directory``.

    Returns the path of the file that was damaged.  Every mode must be
    caught by ``CheckpointManager.validate_step`` and skipped by
    ``latest_valid_step`` — that is the contract the fault-tolerance suite
    pins down.
    """
    if mode not in CORRUPTION_MODES:
        raise ValueError(f"unknown corruption mode {mode!r}; one of "
                         f"{CORRUPTION_MODES}")
    path = os.path.join(directory, f"step_{step}")
    if not os.path.isdir(path):
        raise FileNotFoundError(f"no checkpoint directory {path}")
    manifest = os.path.join(path, "manifest.json")
    shards = sorted(
        os.path.join(path, f) for f in os.listdir(path)
        if f.startswith("shard_") and f.endswith(".npz")
    )
    if mode == "truncate_manifest":
        size = os.path.getsize(manifest)
        with open(manifest, "r+b") as f:
            f.truncate(max(1, size // 2))
        return manifest
    if not shards:
        raise FileNotFoundError(f"no shard files under {path}")
    target = shards[0]
    if mode == "delete_shard":
        os.remove(target)
    elif mode == "truncate_shard":
        size = os.path.getsize(target)
        with open(target, "r+b") as f:
            f.truncate(max(1, size // 2))
    elif mode == "flip_shard_byte":
        size = os.path.getsize(target)
        with open(target, "r+b") as f:
            # flip a byte in the back half: inside the zip payload, past the
            # npz header, so the damage is to array bytes not file framing
            pos = max(0, size - max(1, size // 4))
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0xFF]))
    return target
