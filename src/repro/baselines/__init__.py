from repro.baselines.selectors import (
    AdaptiveRandomSelector,
    CraigPBSelector,
    EL2NSelector,
    GlisterSelector,
    GradMatchPBSelector,
    MiloFixedSelector,
    RandomSelector,
    SelfSupPruneSelector,
)
