"""Subset-selection baselines from the paper's experiments (§4).

The classes here are the *legacy* entry points exposing the deprecated
``indices_for_epoch`` protocol; new code should build the same strategies
through the ``repro.selection`` registry (``build_selector("craig_pb", ...)``)
which wraps them in the weighted ``SelectionPlan`` protocol.  The actual
selection math lives in the module-level functions (``craig_pb_select``,
``gradmatch_omp_select``, ``glister_select``) shared by both paths.

Model-independent strategies (selection cost off the critical path):

  RandomSelector          — fixed random subset (paper: RANDOM)
  AdaptiveRandomSelector  — fresh random subset every R epochs (ADAPTIVE-RANDOM)
  MiloFixedSelector       — fixed subset maximizing disparity-min (MILO (Fixed))
  EL2NSelector            — keep hardest/easiest by EL2N score [Paul et al.'21]
  SelfSupPruneSelector    — self-supervised prototype-distance pruning
                            [Sorscher et al.'22] (App. I.8 comparison)

Model-dependent per-epoch strategies (selection uses the *current* model):

  CraigPBSelector         — per-batch CRAIG: facility location over last-layer
                            gradient similarity [Mirzasoleiman'20, per-batch
                            variant of Killamsetty'21]
  GradMatchPBSelector     — per-batch GRAD-MATCH: OMP matching of the full
                            gradient sum [Killamsetty'21]
  GlisterSelector         — greedy validation-gain selection [Killamsetty'21]

The model-dependent ones take ``grad_fn(indices) -> (n, d) per-sample (proxy)
gradients`` and ``val_grad_fn() -> (d,)``; the trainer wires these to the
last-layer-gradient approximation exactly as CORDS does.  Their *cost* is the
paper's argument: each refresh is O(n·d + selection), on the training
critical path — MILO moves all of it to preprocessing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.greedy import greedy
from repro.core.similarity import gram_matrix
from repro.core.submodular import disparity_min, facility_location


# --------------------------------------------------------------------------
# selection math (shared by the legacy classes and repro.selection wrappers)
# --------------------------------------------------------------------------

def _normalize_weights(w: np.ndarray) -> np.ndarray:
    """Scale weights to mean 1 so the weighted loss keeps its usual scale."""
    w = np.asarray(w, np.float32)
    total = float(w.sum())
    if not np.isfinite(total) or total <= 0.0:
        return np.ones_like(w)
    return w * (len(w) / total)


def craig_pb_select(g: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """CRAIG: facility-location medoids of the gradient-similarity kernel.

    Returns (indices, weights) where weight_j is the mass of the cluster
    represented by medoid j (CRAIG's γ coefficients), normalized to mean 1.
    """
    K = gram_matrix(jnp.asarray(g))
    idx = np.asarray(greedy(facility_location, K, k).indices, np.int64)
    # every sample is "covered" by its most similar medoid; the medoid's
    # loss weight is how many samples it stands in for.  Reduce on device:
    # only the (n,) assignment vector crosses to the host, not the n^2 kernel
    assign = np.asarray(jnp.argmax(K[:, jnp.asarray(idx)], axis=1))
    w = np.bincount(assign, minlength=len(idx)).astype(np.float32)
    return idx, _normalize_weights(w)


def gradmatch_omp_select(
    g: np.ndarray, k: int, lam: float = 0.5
) -> tuple[np.ndarray, np.ndarray]:
    """GRAD-MATCH: OMP-style matching of the mean gradient.

    Returns (indices, weights) with the non-negative OMP coefficients as
    weights (normalized to mean 1).
    """
    g = np.asarray(g, np.float64)
    target = g.mean(0)
    residual = target.copy()
    chosen: list[int] = []
    coefs: list[float] = []
    for _ in range(k):
        scores = g @ residual
        scores[chosen] = -np.inf
        j = int(np.argmax(scores))
        chosen.append(j)
        # per-element weight via nonneg projection (simplified OMP)
        denom = (g[j] @ g[j]) + lam
        w = max(0.0, (g[j] @ residual) / denom)
        coefs.append(w)
        residual = residual - w * g[j]
    return np.asarray(chosen, np.int64), _normalize_weights(np.asarray(coefs))


def glister_select(
    g: np.ndarray, gv: np.ndarray, k: int, eta: float = 0.1
) -> np.ndarray:
    """GLISTER: greedy validation-gain selection (bilevel approximation):
    score(j) ≈ <g_j, g_val> taken greedily with residual updates."""
    g = np.asarray(g, np.float64)
    gv = np.asarray(gv, np.float64)
    chosen: list[int] = []
    acc = np.zeros_like(gv)
    for _ in range(k):
        # validation gain if j's gradient step is added
        scores = g @ (gv - eta * acc)
        scores[chosen] = -np.inf
        j = int(np.argmax(scores))
        chosen.append(j)
        acc = acc + g[j]
    return np.asarray(chosen, np.int64)


# --------------------------------------------------------------------------
# model-independent baselines
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RandomSelector:
    n: int
    k: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._idx = rng.choice(self.n, size=self.k, replace=False)

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return self._idx


@dataclasses.dataclass
class AdaptiveRandomSelector:
    n: int
    k: int
    R: int = 1
    seed: int = 0

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        window = epoch // self.R
        rng = np.random.default_rng(self.seed * 7919 + window)
        return rng.choice(self.n, size=self.k, replace=False)


@dataclasses.dataclass
class MiloFixedSelector:
    """Fixed subset maximizing disparity-min over frozen-encoder features.

    ``gram_free=True`` runs the selection directly over row-normalized
    features (O(n·d) memory) instead of materializing the (n, n) Gram —
    identical trajectories, see ``repro.core.gram_free``.

    ``shard_selection=True`` additionally shards the feature rows across all
    local devices (``repro.core.sharded``; implies the gram-free route) —
    still trajectory-identical, falling back to the local path when n does
    not divide the device count or only one device exists.
    """

    features: np.ndarray
    k: int
    gram_free: bool = False
    shard_selection: bool = False

    def __post_init__(self):
        if self.gram_free or self.shard_selection:
            from repro.core.gram_free import make_gram_free_disparity_min
            from repro.core.similarity import normalize_rows

            z = normalize_rows(jnp.asarray(self.features, jnp.float32))
            if self.shard_selection:
                from repro.core import sharded as sharded_mod
                from repro.distributed.sharding import selection_mesh

                mesh = selection_mesh(axis=sharded_mod.AXIS)
                ndev = mesh.shape[sharded_mod.AXIS]
                if ndev > 1 and z.shape[0] % ndev == 0:
                    fn = sharded_mod.make_sharded_gram_free(
                        "disparity_min", n_shards=ndev
                    )
                    res = sharded_mod.sharded_greedy(fn, z, self.k, mesh=mesh)
                    self._idx = np.asarray(res.indices, np.int64)
                    return
            fn = make_gram_free_disparity_min()
            self._idx = np.asarray(greedy(fn, z, self.k).indices, np.int64)
            return
        K = gram_matrix(jnp.asarray(self.features))
        self._idx = np.asarray(greedy(disparity_min, K, self.k).indices, np.int64)

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return self._idx


@dataclasses.dataclass
class EL2NSelector:
    """Data-diet scoring: EL2N = ||p - onehot(y)||2, computed from an early
    model snapshot; keeps hardest (or easiest) k."""

    scores: np.ndarray
    k: int
    keep: str = "hard"  # hard | easy

    def __post_init__(self):
        order = np.argsort(self.scores)
        self._idx = (order[-self.k:] if self.keep == "hard" else order[: self.k]).astype(np.int64)

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return self._idx


@dataclasses.dataclass
class SelfSupPruneSelector:
    """[Sorscher'22]: k-means prototypes in feature space; prune by distance
    to the nearest prototype (keep hardest = farthest for large budgets)."""

    features: np.ndarray
    k: int
    n_prototypes: int = 10
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        z = self.features
        protos = z[rng.choice(len(z), self.n_prototypes, replace=False)].copy()
        for _ in range(10):  # lloyd iterations
            d = ((z[:, None] - protos[None]) ** 2).sum(-1)
            assign = d.argmin(1)
            for c in range(self.n_prototypes):
                m = assign == c
                if m.any():
                    protos[c] = z[m].mean(0)
        dist = ((z[:, None] - protos[None]) ** 2).sum(-1).min(1)
        self._idx = np.argsort(dist)[-self.k:].astype(np.int64)  # hardest

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        return self._idx


# --------------------------------------------------------------------------
# model-dependent baselines (selection on the training critical path)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CraigPBSelector:
    """Facility location over per-sample gradient similarity, every R epochs."""

    grad_fn: Callable[[], np.ndarray]   # () -> (n, d) current per-sample grads
    k: int
    R: int = 10
    selection_time: float = 0.0

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        import time

        if epoch % self.R == 0 or not hasattr(self, "_idx"):
            t0 = time.perf_counter()
            self._idx, self._weights = craig_pb_select(self.grad_fn(), self.k)
            self.selection_time += time.perf_counter() - t0
        return self._idx


@dataclasses.dataclass
class GradMatchPBSelector:
    """OMP-style matching of the mean gradient, every R epochs."""

    grad_fn: Callable[[], np.ndarray]
    k: int
    R: int = 10
    lam: float = 0.5
    selection_time: float = 0.0

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        import time

        if epoch % self.R == 0 or not hasattr(self, "_idx"):
            t0 = time.perf_counter()
            self._idx, self._weights = gradmatch_omp_select(
                self.grad_fn(), self.k, self.lam
            )
            self.selection_time += time.perf_counter() - t0
        return self._idx


@dataclasses.dataclass
class GlisterSelector:
    """Greedy maximization of validation-set gain (bilevel approximation)."""

    grad_fn: Callable[[], np.ndarray]
    val_grad_fn: Callable[[], np.ndarray]
    k: int
    R: int = 10
    eta: float = 0.1
    selection_time: float = 0.0

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        import time

        if epoch % self.R == 0 or not hasattr(self, "_idx"):
            t0 = time.perf_counter()
            self._idx = glister_select(
                self.grad_fn(), self.val_grad_fn(), self.k, self.eta
            )
            self.selection_time += time.perf_counter() - t0
        return self._idx
