"""Train state + the jit-able train/serve step factories used everywhere
(trainer, dry-run, benchmarks)."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim.optimizers import Optimizer, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array  # () int32


def init_train_state(key: jax.Array, cfg: ModelConfig, opt: Optimizer) -> TrainState:
    params = lm.init_lm(key, cfg)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt: Optimizer, lr_schedule, *,
                    grad_clip: float = 1.0, interpret: bool = True):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(p, cfg, batch, interpret=interpret), has_aux=True
        )(state.params)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
            metrics = dict(metrics, grad_norm=gnorm)
        lr = lr_schedule(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, lr)
        metrics = dict(metrics, lr=lr)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_grad_accum_train_step(cfg: ModelConfig, opt: Optimizer, lr_schedule, *,
                               accum: int, grad_clip: float = 1.0, interpret: bool = True):
    """Gradient-accumulated step: batch dims are (accum, micro_batch, ...).

    Used by the elastic plan to preserve global batch on fewer devices.
    """

    def train_step(state: TrainState, batch: dict):
        def micro(i, carry):
            grads, loss_sum = carry
            mb = jax.tree.map(lambda a: a[i], batch)
            (loss, _), g = jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, mb, interpret=interpret), has_aux=True
            )(state.params)
            return jax.tree.map(jnp.add, grads, g), loss_sum + loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
        grads, loss_sum = jax.lax.fori_loop(0, accum, micro, (zeros, jnp.zeros(())))
        grads = jax.tree.map(lambda g: g / accum, grads)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = lr_schedule(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state, state.params, lr)
        return TrainState(new_params, new_opt, state.step + 1), {
            "loss": loss_sum / accum, "lr": lr,
        }

    return train_step


def make_prefill_step(cfg: ModelConfig, *, interpret: bool = True):
    def prefill_step(params, batch, caches):
        logits, caches = lm.prefill(
            params, cfg, batch["tokens"], caches,
            context=batch.get("context"), interpret=interpret,
        )
        # next-token for the last position of every request
        return jnp.argmax(logits[:, -1, :], axis=-1), caches

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, interpret: bool = True):
    """decode: one new token against a KV cache of fixed length."""

    def serve_step(params, caches, batch):
        logits, caches = lm.decode_step(
            params, cfg, batch["token"], caches, batch["pos"],
            context=batch.get("context"), interpret=interpret,
        )
        return jnp.argmax(logits[:, -1, :], axis=-1), caches

    return serve_step
