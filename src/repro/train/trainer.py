"""Curriculum trainer: MILO subsets + fault tolerance + (optionally) the
distributed mesh.  This is deliverable (b)'s end-to-end driver substrate.

The trainer composes:
  * a ``Pipeline`` whose selector is any ``repro.selection`` registry entry
    (MILO or a baseline); the selector's per-sample plan weights arrive in
    each batch under ``weights`` and are consumed by the loss,
  * a jit'd train step (optimizer + schedule + clipping),
  * ``CheckpointManager`` (atomic, async, keep-last-k),
  * ``StragglerMonitor``,
  * deterministic (seed, epoch, step) replay on restart.

Logged history records carry the curriculum ``phase`` (sge/wre/fixed/
adaptive) the epoch's subset came from, so loss curves can be segmented by
selection regime.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.checkpointer import CheckpointManager
from repro.data.pipeline import Pipeline
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.train.train_state import TrainState


@dataclasses.dataclass
class TrainerConfig:
    epochs: int
    eval_every_epochs: int = 0
    checkpoint_dir: str | None = None
    checkpoint_every_steps: int = 0
    async_checkpoint: bool = True
    log_every_steps: int = 50


class Trainer:
    def __init__(
        self,
        train_step: Callable[[TrainState, dict], tuple[TrainState, dict]],
        pipeline: Pipeline,
        tcfg: TrainerConfig,
        *,
        eval_fn: Callable[[TrainState], dict] | None = None,
        put_batch: Callable[[dict], dict] | None = None,
    ):
        # respect pre-jitted steps (they expose .lower): re-wrapping would
        # give each Trainer its own compilation cache and defeat sharing
        self.train_step = train_step if hasattr(train_step, "lower") else jax.jit(train_step)
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.eval_fn = eval_fn
        self.put_batch = put_batch or (lambda b: b)
        self.monitor = StragglerMonitor()
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir) if tcfg.checkpoint_dir else None
        )
        self.history: list[dict] = []

    def _epoch_phase(self, epoch: int) -> str | None:
        """Curriculum phase of this epoch's SelectionPlan (None for custom
        pipelines that don't expose plans)."""
        plan_fn = getattr(self.pipeline, "plan_for_epoch", None)
        if plan_fn is None:
            return None
        return plan_fn(epoch).phase

    def _maybe_restore(self, state: TrainState) -> tuple[TrainState, int]:
        if self.ckpt is None:
            return state, 0
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        state = self.ckpt.restore(latest, state)
        return state, latest

    def fit(self, state: TrainState, *, resume: bool = True) -> TrainState:
        t0 = time.time()
        global_step = 0
        if resume:
            state, global_step = self._maybe_restore(state)
        steps_per_epoch = self.pipeline.steps_per_epoch()
        start_epoch = global_step // max(steps_per_epoch, 1)
        start_step = global_step % max(steps_per_epoch, 1)

        for epoch in range(start_epoch, self.tcfg.epochs):
            phase = self._epoch_phase(epoch)
            for batch in self.pipeline.epoch(epoch, start_step=start_step if epoch == start_epoch else 0):
                self.monitor.start()
                state, metrics = self.train_step(state, self.put_batch(batch))
                slow = self.monitor.stop(global_step)
                global_step += 1
                if self.tcfg.log_every_steps and global_step % self.tcfg.log_every_steps == 0:
                    rec = {k: float(v) for k, v in metrics.items()}
                    rec.update(step=global_step, epoch=epoch,
                               wall=round(time.time() - t0, 2), straggler=slow)
                    if phase is not None:
                        rec["phase"] = phase
                    self.history.append(rec)
                if (
                    self.ckpt is not None
                    and self.tcfg.checkpoint_every_steps
                    and global_step % self.tcfg.checkpoint_every_steps == 0
                ):
                    if self.tcfg.async_checkpoint:
                        self.ckpt.save_async(global_step, state)
                    else:
                        self.ckpt.save(global_step, state)
            if self.eval_fn and self.tcfg.eval_every_epochs and (
                (epoch + 1) % self.tcfg.eval_every_epochs == 0
            ):
                ev = {k: float(v) for k, v in self.eval_fn(state).items()}
                ev.update(step=global_step, epoch=epoch, eval=True,
                          wall=round(time.time() - t0, 2))
                self.history.append(ev)
        if self.ckpt is not None:
            self.ckpt.wait()
            self.ckpt.save(global_step, state)
        return state
