"""Curriculum trainer: MILO subsets + fault tolerance + (optionally) the
distributed mesh.  This is deliverable (b)'s end-to-end driver substrate.

The trainer composes:
  * a ``Pipeline`` whose selector is any ``repro.selection`` registry entry
    (MILO or a baseline); the selector's per-sample plan weights arrive in
    each batch under ``weights`` and are consumed by the loss,
  * a jit'd train step (optimizer + schedule + clipping),
  * ``CheckpointManager`` (atomic, async, checksummed, keep-last-k),
  * ``StragglerMonitor`` (per-record ``straggler`` flags plus the run-level
    ``straggler_report()`` roll-up),
  * deterministic (seed, epoch, step) replay on restart: ``fit(resume=True)``
    restores the newest checkpoint that passes validation (torn/corrupted
    ones are skipped), derives the mid-epoch cursor through
    ``distributed.fault_tolerance.restart_state``, and — when the device
    count changed since the checkpoint was written — surfaces an
    ``elastic_plan`` (grad-accum preserving the global batch) on
    ``Trainer.elastic`` and in the history.

Logged history records carry the curriculum ``phase`` (sge/wre/fixed/
adaptive) the epoch's subset came from, so loss curves can be segmented by
selection regime.

``Trainer(fused=True, superstep=S)`` swaps the per-batch Python loop for the
device-resident engine (``train.engine``): the epoch's permuted plan
(indices, weights) is device_put once, batches are gathered on device from
the pipeline's resident column store, and ``S`` steps fuse into one
``lax.scan`` dispatch with the state donated.  Checkpoint boundaries cut the
scan into segments (the saved state is the real state at that step) and
per-step metrics come back stacked, so history/checkpoint/restart semantics
are identical to the loop path — same (seed, epoch, step) stream, same
records.  Pipelines without an ``arrays`` column store (custom
``make_batch``) or trainers with a custom ``put_batch`` fall back to the
step loop automatically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import CheckpointManager
from repro.data.pipeline import Pipeline
from repro.distributed.fault_tolerance import (
    ElasticPlan,
    StragglerMonitor,
    elastic_plan,
    restart_state,
)
from repro.health import guard as guard_mod
from repro.health.guard import DivergenceError, GuardPolicy
from repro.train import engine as engine_mod
from repro.train.train_state import TrainState


class _GuardRollback(Exception):
    """Internal control flow: a segment tripped the rollback guard.

    Carries the step the bad segment ended on and the (valid, post-skip)
    state to use as the restore template — the pre-segment state's buffers
    were donated to the engine and must not be touched again.
    """

    def __init__(self, step: int, state: TrainState):
        super().__init__(f"guard rollback at step {step}")
        self.step = step
        self.state = state


@dataclasses.dataclass
class TrainerConfig:
    epochs: int
    eval_every_epochs: int = 0
    checkpoint_dir: str | None = None
    checkpoint_every_steps: int = 0
    async_checkpoint: bool = True
    log_every_steps: int = 50
    # model-parallel degree assumed by the elastic-restart planner: when a
    # resumed run sees a different device count than the run that wrote the
    # checkpoint, ``elastic_plan`` re-tiles (data, model) and computes the
    # grad-accumulation factor that keeps the global batch constant.  The
    # plan is surfaced on ``Trainer.elastic`` and as an ``elastic`` history
    # record for the launch layer to apply.
    model_parallel: int = 1
    # fused path only: drain segment i's stacked metrics to host AFTER
    # segment i+1 has been dispatched, so the device→host copy overlaps the
    # next scan's execution instead of stalling the dispatch pipeline.
    # History records are bit-identical to the synchronous drain (same
    # metrics, same order — only the wall timestamps move); False restores
    # the in-line copy for A/B tests.
    async_history: bool = True
    # Divergence guard (repro.health.GuardPolicy) or None.  The non-finite
    # / loss-spike check is fused into the step (engine scan body on the
    # fused path): a flagged step is a deterministic zero-update on device.
    # action="skip_step" adds ZERO host syncs — the flag rides the metrics
    # already drained at log boundaries; "rollback"/"abort" read one small
    # flag vector per segment (per step on the loop path) to decide.
    # "rollback" restores latest_valid_step via the checkpointer and
    # replays the stretch deterministically; flags at or before the
    # rolled-back step are tolerated on replay (skip semantics) so a
    # deterministic NaN cannot re-trigger forever.
    guard: GuardPolicy | None = None
    # Multi-host liveness: when set, this host writes a heartbeat beacon
    # (``multihost.HeartbeatWriter``) at every step/segment boundary and
    # checks every peer's freshness — a peer stale past
    # ``heartbeat_timeout`` raises ``HostLossError`` (the launcher restarts
    # with the survivors; ``elastic_plan`` re-meshes; resume lands on the
    # last *globally*-valid checkpoint).  The directory must be shared
    # across the job's hosts (two local processes share a tmpdir in CI).
    heartbeat_dir: str | None = None
    heartbeat_timeout: float = 60.0
    # bound on every wait a dead peer could hang inside the two-phase
    # distributed checkpoint (barriers, manifest collection, publication
    # poll); expiry raises HostLossError instead of deadlocking the job
    barrier_timeout: float = 120.0


class Trainer:
    def __init__(
        self,
        train_step: Callable[[TrainState, dict], tuple[TrainState, dict]],
        pipeline: Pipeline,
        tcfg: TrainerConfig,
        *,
        eval_fn: Callable[[TrainState], dict] | None = None,
        put_batch: Callable[[dict], dict] | None = None,
        fused: bool = False,
        superstep: int = 32,
        resident_buffers: dict | None = None,
    ):
        # respect pre-jitted steps (they expose .lower): re-wrapping would
        # give each Trainer its own compilation cache and defeat sharing
        self.train_step = train_step if hasattr(train_step, "lower") else jax.jit(train_step)
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.eval_fn = eval_fn
        self.put_batch = put_batch or (lambda b: b)
        self.fused = fused
        self.superstep = superstep
        # the fused path builds batches on device, so a custom put_batch
        # (host-side placement/sharding hook) forces the loop path
        self._custom_put = put_batch is not None
        # externally owned resident column buffers (highest precedence, then
        # the pipeline's shared ``resident`` dict, then a private device_put
        # of the host columns).  External buffers are never donated — the
        # engine donates only the train state — so N trainers can share them.
        self._buffers: dict | None = resident_buffers
        self._pending_history: tuple | None = None
        self.monitor = StragglerMonitor()
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir,
                              barrier_timeout=tcfg.barrier_timeout)
            if tcfg.checkpoint_dir else None
        )
        # multi-host liveness: beat + check at every step/segment boundary
        if tcfg.heartbeat_dir:
            from repro.distributed import multihost

            self.heartbeat = multihost.HeartbeatWriter(tcfg.heartbeat_dir)
            self.liveness = multihost.HeartbeatMonitor(
                tcfg.heartbeat_dir,
                timeout=tcfg.heartbeat_timeout,
                expected=jax.process_count(),
            )
        else:
            self.heartbeat = None
            self.liveness = None
        self.history: list[dict] = []
        # elastic-restart plan computed when a resume sees a different
        # device count than the checkpoint's writer (None otherwise)
        self.elastic: ElasticPlan | None = None
        self.guard = tcfg.guard
        self.guard_events: list[dict] = []
        self._guard_skips = 0
        self._guard_rollbacks = 0
        # steps at/before this mark had their rollback consumed: on replay
        # the deterministic fault re-fires and is tolerated as a skip
        self._tolerate_through = -1
        # loop-path step with the guard fused in (the fused path gets it
        # inside the engine's scan body instead)
        self._step = (
            jax.jit(guard_mod.guarded_step(self.train_step, self.guard))
            if self.guard is not None else self.train_step
        )

    def fused_active(self) -> bool:
        """Whether fit() will take the device-resident fused path."""
        return (
            self.fused
            and not self._custom_put
            and getattr(self.pipeline, "supports_device_epoch", False)
        )

    def _epoch_phase(self, epoch: int) -> str | None:
        """Curriculum phase of this epoch's SelectionPlan (None for custom
        pipelines that don't expose plans)."""
        plan_fn = getattr(self.pipeline, "plan_for_epoch", None)
        if plan_fn is None:
            return None
        return plan_fn(epoch).phase

    def _ckpt_extra(self) -> dict:
        """Run metadata stamped into every checkpoint manifest: what an
        elastic restart needs to compare against the resuming environment."""
        return {
            "device_count": jax.device_count(),
            "process_count": jax.process_count(),
            "data_seed": self.pipeline.seed,
            "batch_size": self.pipeline.batch_size,
        }

    def _beat_and_check(self, global_step: int) -> None:
        """Heartbeat + dead-host detection at a step/segment boundary.

        Raises ``HostLossError`` when any peer's beacon is stale — the
        process exits, the launcher restarts with the surviving hosts, and
        ``_maybe_restore`` + ``elastic_plan`` handle the re-mesh.
        """
        if self.heartbeat is None:
            return
        self.heartbeat.beat(global_step)
        self.liveness.check()

    def _save_checkpoint(self, global_step: int, state: TrainState) -> None:
        if self.tcfg.async_checkpoint:
            self.ckpt.save_async(global_step, state, extra=self._ckpt_extra())
        else:
            self.ckpt.save(global_step, state, extra=self._ckpt_extra())

    def _maybe_restore(self, state: TrainState, t0: float) -> tuple[TrainState, int]:
        """Auto-resume from the newest checkpoint that passes validation.

        Torn or corrupted checkpoints (a crash mid-save, lost pages) are
        skipped — ``latest_valid_step`` verifies manifests and checksums —
        so a resumed run always restores a state that was fully written.
        If the device count changed since the checkpoint was written, an
        ``elastic_plan`` is computed (global batch preserved via grad
        accumulation) and surfaced on ``self.elastic`` + the history.
        """
        if self.ckpt is None:
            return state, 0
        latest = self.ckpt.latest_valid_step()
        if latest is None:
            return state, 0
        state = self.ckpt.restore(latest, state)
        extra = self.ckpt.manifest(latest).get("extra", {})
        saved_devices = extra.get("device_count")
        now_devices = jax.device_count()
        saved_procs = extra.get("process_count")
        if saved_procs and saved_procs != jax.process_count() and (
            not saved_devices or saved_devices == now_devices
        ):
            # host count changed but the device count happens to match (e.g.
            # forced-device CPU meshes): still surface the topology change
            self.history.append({
                "elastic": True, "step": latest,
                "process_count": [saved_procs, jax.process_count()],
                "grad_accum": None, "mesh_shape": None,
                "note": f"process count {saved_procs} -> "
                        f"{jax.process_count()} with unchanged device count",
                "wall": round(time.time() - t0, 2),
            })
        if saved_devices and saved_devices != now_devices:
            batch = extra.get("batch_size", self.pipeline.batch_size)
            try:
                self.elastic = elastic_plan(
                    now_devices,
                    model_parallel=self.tcfg.model_parallel,
                    global_batch=batch,
                    microbatch_per_replica=max(1, batch // saved_devices),
                )
                rec = {"elastic": True, "step": latest,
                       "grad_accum": self.elastic.grad_accum,
                       "mesh_shape": list(self.elastic.mesh_shape),
                       "note": self.elastic.note}
                if saved_procs:
                    rec["process_count"] = [saved_procs, jax.process_count()]
            except ValueError as e:
                # device count the batch cannot tile — surface, don't crash
                # the resume: the state itself restored fine
                rec = {"elastic": True, "step": latest, "grad_accum": None,
                       "mesh_shape": None, "note": f"no elastic plan: {e}"}
            rec["wall"] = round(time.time() - t0, 2)
            self.history.append(rec)
        return state, latest

    # -- device-resident fused path (train.engine) --------------------------

    def _engine(self):
        return engine_mod.epoch_engine(
            self.train_step, weight_key=self.pipeline.weight_key,
            guard=self.guard,
        )

    def _resident_buffers(self) -> dict:
        if self._buffers is None:
            shared = getattr(self.pipeline, "resident", None)
            self._buffers = shared if shared is not None else {
                k: jnp.asarray(v) for k, v in self.pipeline.arrays.items()
            }
        return self._buffers

    def _fused_epoch(
        self, state: TrainState, epoch: int, start_step: int,
        global_step: int, t0: float, phase: str | None,
    ) -> tuple[TrainState, int]:
        """One epoch as a walk over scan segments; returns (state, step)."""
        idx, w = self.pipeline.device_epoch(epoch, start_step=start_step)
        buffers = self._resident_buffers()
        engine = self._engine()
        ckpt_every = self.tcfg.checkpoint_every_steps if self.ckpt else 0
        n_steps = int(idx.shape[0])
        pos = 0
        while pos < n_steps:
            seg = engine_mod.segment_length(
                self.superstep, global_step, n_steps - pos, ckpt_every
            )
            self.monitor.start()
            state, metrics = engine(
                state, buffers, idx[pos : pos + seg], w[pos : pos + seg]
            )
            slow = self.monitor.stop(global_step + seg)
            self._beat_and_check(global_step + seg)
            # rollback/abort must decide BEFORE this segment's state can be
            # checkpointed; skip_step stays sync-free (flag rides the drain)
            if self.guard is not None and self.guard.action != "skip_step":
                bad = int(np.sum(
                    np.asarray(jax.device_get(metrics[guard_mod.GUARD_KEY]))
                    > 0))
                if bad:
                    self._on_guard_bad(bad, global_step + seg, epoch, state)
            log_every = self.tcfg.log_every_steps
            # only sync the stacked metrics to host when a log boundary
            # actually falls inside this segment — log-free segments keep
            # the dispatch pipeline unblocked
            if log_every and (global_step + seg) // log_every * log_every > global_step:
                if self.tcfg.async_history:
                    # async drain: segment i's engine call above has already
                    # been dispatched, so copying segment i-1's metrics NOW
                    # overlaps that copy with i's on-device execution; i's
                    # own metrics wait one iteration as the new pending
                    # record.  Record content and order are identical to the
                    # synchronous path — only the drain timing moves.
                    self._drain_history(t0)
                    self._pending_history = (
                        metrics, seg, global_step, epoch, phase, slow
                    )
                else:
                    self._pending_history = (
                        metrics, seg, global_step, epoch, phase, slow
                    )
                    self._drain_history(t0)
            global_step += seg
            pos += seg
            if ckpt_every and global_step % ckpt_every == 0:
                self._save_checkpoint(global_step, state)
        # epoch boundary: flush the trailing pending segment so eval records
        # (and the next epoch's) land after it, exactly as the sync path
        self._drain_history(t0)
        return state, global_step

    def _drain_history(self, t0: float) -> None:
        """Replay the pending segment's stacked metrics into per-step
        history records (the device→host copy happens here)."""
        if self._pending_history is None:
            return
        metrics, seg, global_step, epoch, phase, slow = self._pending_history
        self._pending_history = None
        # per-step metrics come back stacked (seg,): replay them into
        # the same records the loop path writes.  wall/straggler are
        # segment-grain — the only per-step observables a fused
        # segment does not have.
        host = jax.device_get(metrics)
        wall = round(time.time() - t0, 2)
        log_every = self.tcfg.log_every_steps
        if self.guard is not None and guard_mod.GUARD_KEY in host:
            # skip events are observed here, off the copy the drain already
            # pays — the healthy path gains no syncs from the guard.  For
            # rollback policies the segments that reach the drain were
            # clean or tolerated, so flagged steps here are skips too.
            for i in np.where(np.asarray(host[guard_mod.GUARD_KEY]) > 0)[0]:
                self._guard_skips += 1
                self.guard_events.append({
                    "action": "skip_step",
                    "step": global_step + int(i) + 1,
                    "epoch": epoch,
                })
        for i in range(seg):
            step_i = global_step + i + 1
            if step_i % log_every:
                continue
            rec = {k: float(v[i]) for k, v in host.items()}
            rec.update(step=step_i, epoch=epoch, wall=wall, straggler=slow)
            if phase is not None:
                rec["phase"] = phase
            self.history.append(rec)

    # -- divergence guard (repro.health.guard) ------------------------------

    def _on_guard_bad(
        self, bad: int, end_step: int, epoch: int, state: TrainState
    ) -> None:
        """Host-side reaction to flagged steps in the stretch ending at
        ``end_step`` (the device already applied skip semantics)."""
        policy = self.guard
        if end_step <= self._tolerate_through:
            # replaying a rolled-back stretch: the deterministic fault
            # re-fired, exactly as expected — keep the skip and move on
            # (the drain records it as a skip event)
            return
        if policy.action == "abort":
            raise DivergenceError(
                f"training diverged: {bad} non-finite/spiking step(s) in "
                f"the stretch ending at step {end_step} (epoch {epoch}) "
                f"and GuardPolicy.action='abort'")
        self._guard_rollbacks += 1
        if self._guard_rollbacks > policy.max_rollbacks:
            raise DivergenceError(
                f"training diverged at step {end_step} after exhausting "
                f"max_rollbacks={policy.max_rollbacks} checkpoint restores")
        self.guard_events.append({
            "action": "rollback", "step": int(end_step),
            "epoch": int(epoch), "bad_steps": int(bad),
        })
        raise _GuardRollback(end_step, state)

    def _guard_restore(
        self, rb: _GuardRollback, t0: float
    ) -> tuple[TrainState, int]:
        """Restore the newest valid checkpoint and rewind history to it."""
        if self.ckpt is None:
            raise DivergenceError(
                f"guard action 'rollback' tripped at step {rb.step} but no "
                "checkpoint_dir is configured — set TrainerConfig."
                "checkpoint_dir/checkpoint_every_steps or use 'skip_step'")
        # the still-pending previous segment may precede the restore point:
        # drain it (the truncation below keeps only records <= latest)
        self._drain_history(t0)
        self.ckpt.wait()               # in-flight async saves must land
        latest = self.ckpt.latest_valid_step()
        if latest is None:
            raise DivergenceError(
                f"guard: divergence at step {rb.step} with no valid "
                "checkpoint to roll back to")
        state = self.ckpt.restore(latest, rb.state)
        self._tolerate_through = rb.step
        # data/eval records past the restore point get re-written by the
        # replay; the guard marker records stay
        self.history = [
            h for h in self.history
            if h.get("step", 0) <= latest or h.get("guard")
        ]
        self.history.append({
            "guard": "rollback", "step": int(rb.step),
            "restored_step": int(latest),
            "wall": round(time.time() - t0, 2),
        })
        return state, latest

    def guard_report(self) -> dict | None:
        """Run-level divergence-guard roll-up (None when nothing tripped).

        Mirrors ``straggler_report()``: per-step flags already ride the
        history records (``guard_bad``); this aggregates skip/rollback
        events without touching the history stream.
        """
        if not (self.guard_events or self._guard_skips
                or self._guard_rollbacks):
            return None
        return {
            "action": self.guard.action if self.guard else None,
            "skipped_steps": int(self._guard_skips),
            "rollbacks": int(self._guard_rollbacks),
            "events": [dict(e) for e in self.guard_events],
        }

    def warm_fused(self, throwaway: TrainState) -> None:
        """Compile the fused segment programs outside any timed region.

        Runs epoch 0's segment walk on ``throwaway`` — whose buffers are
        DONATED, so the caller must not reuse it — covering the (full,
        remainder) segment shapes a checkpoint-free run cycles through.
        No history, checkpoints, or monitor records are produced.
        """
        if not self.fused_active():
            return
        idx, w = self.pipeline.device_epoch(0)
        buffers = self._resident_buffers()
        engine = self._engine()
        n_steps = int(idx.shape[0])
        pos = 0
        while pos < n_steps:
            seg = engine_mod.segment_length(
                self.superstep, pos, n_steps - pos, 0
            )
            throwaway, _ = engine(
                throwaway, buffers, idx[pos : pos + seg], w[pos : pos + seg]
            )
            pos += seg
        jax.block_until_ready(throwaway)

    def fit(self, state: TrainState, *, resume: bool = True) -> TrainState:
        t0 = time.time()
        self._pending_history = None  # defensive: a prior fit() that raised
        global_step = 0
        if resume:
            state, global_step = self._maybe_restore(state, t0)
        steps_per_epoch = self.pipeline.steps_per_epoch()
        # the deterministic restart cursor: (epoch, step_in_epoch, data_seed)
        # are pure functions of (seed, step), so resuming replays the exact
        # batch stream of the uninterrupted run — on either engine path
        cursor = restart_state(
            self.pipeline.seed, global_step, max(steps_per_epoch, 1)
        )
        start_epoch, start_step = cursor["epoch"], cursor["step_in_epoch"]
        fused = self.fused_active()

        epoch = start_epoch
        while epoch < self.tcfg.epochs:
            phase = self._epoch_phase(epoch)
            run_epoch = self._fused_epoch if fused else self._loop_epoch
            try:
                state, global_step = run_epoch(
                    state, epoch,
                    start_step if epoch == start_epoch else 0,
                    global_step, t0, phase,
                )
            except _GuardRollback as rb:
                state, global_step = self._guard_restore(rb, t0)
                # re-derive the deterministic cursor at the restored step:
                # the replayed stretch sees the identical batch stream
                cursor = restart_state(
                    self.pipeline.seed, global_step, max(steps_per_epoch, 1)
                )
                start_epoch, start_step = (
                    cursor["epoch"], cursor["step_in_epoch"])
                epoch = start_epoch
                continue
            self._maybe_eval(state, epoch, global_step, t0)
            epoch += 1
        if self.ckpt is not None:
            self.ckpt.wait()
            self.ckpt.save(global_step, state, extra=self._ckpt_extra())
        return state

    def _loop_epoch(
        self, state: TrainState, epoch: int, start_step: int,
        global_step: int, t0: float, phase: str | None,
    ) -> tuple[TrainState, int]:
        """One epoch on the per-batch step loop; returns (state, step)."""
        guard_sync = (
            self.guard is not None and self.guard.action != "skip_step")
        for batch in self.pipeline.epoch(epoch, start_step=start_step):
            self.monitor.start()
            state, metrics = self._step(state, self.put_batch(batch))
            slow = self.monitor.stop(global_step)
            self._beat_and_check(global_step)
            global_step += 1
            if guard_sync and float(metrics[guard_mod.GUARD_KEY]) > 0:
                self._on_guard_bad(1, global_step, epoch, state)
            if self.tcfg.log_every_steps and global_step % self.tcfg.log_every_steps == 0:
                rec = {k: float(v) for k, v in metrics.items()}
                rec.update(step=global_step, epoch=epoch,
                           wall=round(time.time() - t0, 2), straggler=slow)
                if phase is not None:
                    rec["phase"] = phase
                self.history.append(rec)
                if rec.get(guard_mod.GUARD_KEY, 0.0) > 0:
                    self._guard_skips += 1
                    self.guard_events.append({
                        "action": "skip_step", "step": global_step,
                        "epoch": epoch,
                    })
            if (
                self.ckpt is not None
                and self.tcfg.checkpoint_every_steps
                and global_step % self.tcfg.checkpoint_every_steps == 0
            ):
                self._save_checkpoint(global_step, state)
        return state, global_step

    def straggler_report(self) -> dict | None:
        """Run-level straggler roll-up (None when nothing was flagged).

        Per-step/segment ``straggler`` flags already ride on each history
        record; this aggregates them WITHOUT touching the history stream —
        history length stays a pure function of (epochs, log_every_steps),
        never of wall-clock noise.
        """
        if not self.monitor.flagged:
            return None
        return {
            "flagged": [[int(s), float(dt)] for s, dt in self.monitor.flagged],
            "mean_step_time": float(self.monitor.mean_step_time),
        }

    def _maybe_eval(
        self, state: TrainState, epoch: int, global_step: int, t0: float
    ) -> None:
        if self.eval_fn and self.tcfg.eval_every_epochs and (
            (epoch + 1) % self.tcfg.eval_every_epochs == 0
        ):
            ev = {k: float(v) for k, v in self.eval_fn(state).items()}
            ev.update(step=global_step, epoch=epoch, eval=True,
                      wall=round(time.time() - t0, 2))
            self.history.append(ev)
