"""Device-resident training engine: scan-fused supersteps over resident data.

The per-batch training loop pays three taxes the math never asked for: one
XLA dispatch per step, a host->device transfer per batch, and the Python
bookkeeping between them.  MILO's subsets are small and known *before* the
epoch starts (the whole point of model-agnostic selection), so none of that
is necessary: the selected data can live on device for the entire run and
whole stretches of the epoch can compile into ONE program.

Two layers:

  * ``make_superstep(train_step)`` — fuses ``S`` already-assembled batches
    (stacked along a leading axis) into a single ``lax.scan`` with the
    ``TrainState`` **donated**: the optimizer update writes into the input
    state's buffers (zero-copy), and the host dispatches once per ``S``
    steps instead of once per batch.

  * ``epoch_engine(train_step)`` — the same scan, but batches are never
    assembled on the host at all: the program takes the resident feature /
    label **buffers** plus a ``(S, batch)`` block of the epoch's permuted
    plan indices and weights (one ``device_put`` per epoch, see
    ``Pipeline.device_epoch``) and gathers each batch **on device** inside
    the scan body.  Plan weights ride along under ``weight_key`` exactly as
    the host pipeline injects them.

Per-step metrics come back stacked ``(S,)`` so logging loses nothing — the
consumer (``Trainer``) replays them into per-step history records after the
superstep returns.  Checkpoint boundaries must see the *actual* state, so
the trainer cuts supersteps into segments that end exactly on
``checkpoint_every_steps`` multiples (``segment_length``); restart replay
stays a pure function of (seed, epoch, step).

Programs are cached per (train_step, weight_key, donate, guard) — a
Hyperband sweep building one ``Trainer`` per trial reuses one compiled
superstep per segment shape instead of recompiling every trial.

With a ``guard`` (``repro.health.GuardPolicy``) the divergence check is
fused *inside* the scan body: a step whose loss goes non-finite (or spikes
past ``max_loss``) becomes a deterministic zero-update on device and its
``guard_bad`` flag rides the stacked metrics — zero extra host syncs on
the healthy path.
"""
from __future__ import annotations

import weakref
from typing import Any, Callable

import jax

from repro.health.guard import GuardPolicy, guarded_step
from repro.train.train_state import TrainState

TrainStep = Callable[[TrainState, dict], tuple[TrainState, dict]]


def make_superstep(
    train_step: TrainStep,
    *,
    donate: bool = True,
    guard: GuardPolicy | None = None,
):
    """Fuse a stack of pre-assembled batches into one scan.

    Returns ``superstep(state, batches) -> (state, stacked_metrics)`` where
    every leaf of ``batches`` carries a leading step axis ``(S, ...)``.  With
    ``donate=True`` (default) the input state's buffers are donated to the
    program — invalidated on call, reused for the output state.
    """
    step = guarded_step(train_step, guard) if guard is not None else train_step

    def superstep(state: TrainState, batches: dict):
        def body(st, batch):
            return step(st, batch)

        return jax.lax.scan(body, state, batches)

    return jax.jit(superstep, donate_argnums=(0,) if donate else ())


#: train_step -> {(weight_key, donate, guard): engine}.  Keyed on the step *object*
#: on purpose: the session/bench step factories memoize their jitted steps,
#: so every Trainer built around the same step shares one engine (and its
#: per-segment-shape executables).  Weakly keyed so per-instance steps (a
#: sweep jitting its own step per trial) don't pin their engines — and
#: everything the step closure captures — for the life of the process.
_ENGINE_CACHE: weakref.WeakKeyDictionary = weakref.WeakKeyDictionary()


def epoch_engine(
    train_step: TrainStep,
    *,
    weight_key: str | None = "weights",
    donate: bool = True,
    guard: GuardPolicy | None = None,
):
    """Superstep over device-resident data.

    Returns ``engine(state, buffers, idx, w) -> (state, stacked_metrics)``:

    * ``buffers`` — dict of resident column arrays (e.g. ``{"x": (n, d),
      "y": (n,)}``), device_put once per training run,
    * ``idx`` — ``(S, batch)`` int32 plan indices in visit order,
    * ``w``  — ``(S, batch)`` float32 plan weights aligned with ``idx``.

    Each scan step gathers its batch from the buffers on device
    (``{k: buf[k][idx[t]]}``), injects ``w[t]`` under ``weight_key`` (unless
    a buffer already claims that column, mirroring the host pipeline's
    "don't clobber" rule), and applies ``train_step``.  The state is donated;
    the buffers are not.  A ``guard`` fuses the divergence check into the
    body (see module docstring); ``GuardPolicy`` is hashable, so guarded
    and unguarded engines coexist in the cache.
    """
    per_step = _ENGINE_CACHE.setdefault(train_step, {})
    engine = per_step.get((weight_key, donate, guard))
    if engine is not None:
        return engine

    # the closure must not hold the step strongly: the cached engine is the
    # cache VALUE, and a value referencing its weak KEY would keep the entry
    # alive forever.  The engine is only reachable through this cache, so by
    # the time anyone traces it the caller still holds the step.
    step_ref = weakref.ref(train_step)

    def engine_fn(state: TrainState, buffers: dict, idx, w):
        step = step_ref()
        assert step is not None, "train_step was garbage-collected"
        if guard is not None:
            step = guarded_step(step, guard)

        def body(st, step_inputs):
            bidx, bw = step_inputs
            batch = {k: buf[bidx] for k, buf in buffers.items()}
            if weight_key and weight_key not in batch:
                batch[weight_key] = bw
            return step(st, batch)

        return jax.lax.scan(body, state, (idx, w))

    engine = jax.jit(engine_fn, donate_argnums=(0,) if donate else ())
    per_step[(weight_key, donate, guard)] = engine
    return engine


def segment_length(
    superstep: int, global_step: int, remaining: int, checkpoint_every: int
) -> int:
    """Steps the next superstep may fuse without skipping a boundary.

    A segment ends at whichever comes first: the superstep size, the end of
    the epoch, or the next ``checkpoint_every_steps`` multiple (checkpoints
    need the actual state, which only exists between segments).  Logging
    needs no boundary — per-step metrics come back stacked.
    """
    if superstep < 1:
        raise ValueError(f"superstep must be >= 1, got {superstep}")
    seg = min(superstep, remaining)
    if checkpoint_every:
        seg = min(seg, checkpoint_every - global_step % checkpoint_every)
    return seg
