"""repro: MILO (model-agnostic subset selection) as a production JAX framework.

``repro.selection`` is the single front door for subset selection::

    from repro.selection import MiloSession, build_selector

Kept import-light on purpose: pulling in the selection engine (and with it
jax) is the caller's explicit choice.
"""
__version__ = "1.1.0"
