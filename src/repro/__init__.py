"""repro: MILO (model-agnostic subset selection) as a production JAX framework."""
__version__ = "1.0.0"
