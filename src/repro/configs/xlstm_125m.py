"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0 per the assignment sheet: projections live inside the recurrent
blocks.  sLSTM at every 6th position (5 mLSTM : 1 sLSTM).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", num_layers=12, d_model=768, num_heads=4,
    num_kv_heads=4, d_ff=0, vocab_size=50304,
    pattern=(("mlstm", "none"),) * 5 + (("slstm", "none"),),
    ssm_expand=2, ssm_head_dim=192, subquadratic=True, use_rope=False,
)
