"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155,
    pattern=(("attn", "moe"),), num_experts=32, experts_per_token=8,
    # §Perf iter-7: dispatch one-hot traffic scales with group_size*k*cf;
    # 256 keeps expert tiles MXU-viable (cap=80) while cutting dispatch 4x.
    moe_group_size=256,
)
