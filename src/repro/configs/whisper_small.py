"""whisper-small — enc-dec audio; conv frontend stubbed [arXiv:2212.04356].

12 encoder layers over precomputed frame embeddings; 12 decoder layers, each
a (self-attn, cross-attn) pair in the group pattern.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", num_layers=24, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
    pattern=(("attn", "dense"), ("xattn", "dense")),
    encoder_layers=12, encoder_seq=1500, use_rope=False,
)
