"""jamba-1.5-large-398b — Mamba+attn 1:7, MoE 16e top-2 [arXiv:2403.19887].

Attention every 8th layer; MoE every other layer; Mamba carries the long
context, so long_500k decode runs (subquadratic=True).
"""
from repro.configs.base import ModelConfig

_GROUP = (
    ("attn", "moe"), ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"),
    ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", num_layers=72, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=24576, vocab_size=65536,
    pattern=_GROUP, num_experts=16, experts_per_token=2, subquadratic=True,
)
