"""llama-3.2-vision-90b — cross-attn image layers [hf:meta-llama].

100 decoder layers; gated cross-attention to image patch embeddings every
5th layer.  Patch frontend stubbed: input_specs() supplies embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm", num_layers=100, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256,
    pattern=(("attn", "dense"),) * 4 + (("xattn", "dense"),),
    num_context_tokens=1601, rope_theta=500000.0,
)
