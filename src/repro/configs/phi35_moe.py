"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
    pattern=(("attn", "moe"),), num_experts=16, experts_per_token=2,
)
