"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import dataclasses

from repro.configs import (
    granite_moe,
    internlm2_1_8b,
    jamba_1_5_large,
    llama32_vision_90b,
    phi35_moe,
    stablelm_12b,
    whisper_small,
    xlstm_125m,
    yi_6b,
    yi_9b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applies

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in [
        yi_6b, internlm2_1_8b, stablelm_12b, yi_9b, whisper_small, xlstm_125m,
        llama32_vision_90b, phi35_moe, granite_moe, jamba_1_5_large,
    ]
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke(cfg: ModelConfig | str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if isinstance(cfg, str):
        cfg = get(cfg)
    return dataclasses.replace(
        cfg,
        num_layers=len(cfg.pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_group_size=64,
        capacity_factor=8.0,  # no-drop at smoke scale: decode == train exactly
        ssm_expand=2,
        ssm_head_dim=16,
        ssm_state_dim=16,
        ssm_chunk=16,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=24 if cfg.encoder_layers else cfg.encoder_seq,
        num_context_tokens=8 if cfg.num_context_tokens else 0,
        attn_block=32,
        attention_impl="naive",
        remat=False,
    )


def all_cells() -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) cell with its applicability verdict."""
    out = []
    for aname, cfg in ARCHS.items():
        for sname, shp in SHAPES.items():
            ok, why = shape_applies(cfg, shp)
            out.append((aname, sname, ok, why))
    return out
