"""Model/architecture configuration.

Each assigned architecture is a ``ModelConfig`` preset in its own module
(``repro/configs/<id>.py``) with the exact published dimensions, plus a
``smoke()`` reduction of the same family for CPU tests.  The layer stack is
described as a *group pattern* — a fixed sequence of (mixer, ffn) block types
— repeated ``n_groups`` times and executed as a ``lax.scan`` over stacked
group params (keeps HLO size O(group), not O(layers), for 100-layer models).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "attn_nc", "xattn", "mamba", "mlstm", "slstm"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # layer-stack pattern: list of (mixer, ffn); stack = pattern * n_groups
    pattern: tuple[tuple[Mixer, Ffn], ...] = (("attn", "dense"),)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024

    # SSM / xLSTM
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_state_dim: int = 128
    ssm_chunk: int = 256
    ssm_impl: str = "chunked"        # chunked (pure JAX) | pallas (TPU kernel)

    # encoder-decoder (audio) / cross-attention (vlm)
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30s of 10ms frames after conv
    num_context_tokens: int = 0      # vlm: image patch tokens (stub frontend)

    # attention details
    rope_theta: float = 10000.0
    use_rope: bool = True
    attention_impl: str = "chunked"  # naive | chunked | pallas
    attn_block: int = 512

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    remat: bool = True

    # which shapes apply (capability flags for the cell matrix)
    supports_decode: bool = True
    subquadratic: bool = False       # can run long_500k

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.pattern)}"
            )

    @property
    def n_groups(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = v * d  # embedding (tied)
        per_layer = {}
        attn_p = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        dense_ffn = 3 * d * f
        moe_ffn = d * self.num_experts + 3 * self.num_experts * d * f
        d_inner = self.ssm_expand * d
        n_ssm_heads = d_inner // self.ssm_head_dim
        mamba_p = d * 2 * d_inner + d * 2 * self.ssm_state_dim + d * n_ssm_heads + d_inner * d + d_inner
        mlstm_p = 4 * d * d_inner + 2 * d * (d_inner // self.ssm_head_dim) + d_inner * d + d_inner
        slstm_p = 5 * d * d
        mixer_params = {"attn": attn_p, "attn_nc": attn_p, "xattn": attn_p,
                        "mamba": mamba_p, "mlstm": mlstm_p, "slstm": slstm_p}
        ffn_params = {"dense": dense_ffn, "moe": moe_ffn, "none": 0}
        total_per_group = sum(mixer_params[m] + ffn_params[fn] + 2 * d for m, fn in self.pattern)
        n += total_per_group * self.n_groups + d
        if self.is_encdec:
            n += self.encoder_layers * (attn_p + dense_ffn + 2 * d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive_per_moe = 3 * (self.num_experts - self.experts_per_token) * d * f
        n_moe_layers = sum(1 for _, fn in self.pattern if fn == "moe") * self.n_groups
        return self.param_count() - n_moe_layers * inactive_per_moe


# ---------------------------------------------------------------------------
# Input shapes (the assignment's per-arch shape set)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applies(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full attention — sub-quadratic required for 500k decode"
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only architecture has no decode step"
    return True, ""
