"""MILO orchestrator (paper Alg. 1): preprocessing + per-epoch subset serving.

``MiloPreprocessor.preprocess`` runs once per (dataset, k):
  1. class-wise partition of the feature matrix,
  2. per class: Gram matrix -> SGE with graph-cut (easy subsets bank),
  3. per class: full greedy with disparity-min -> importance -> Taylor-softmax
     probabilities (WRE),
  4. merge to global indices; persist as ``MiloMetadata``.

``MiloSelector`` consumes the metadata during training: given the epoch it
returns the subset indices dictated by the easy-to-hard curriculum.  Selection
cost during training is O(k) (a Gumbel top-k at WRE epochs; a table lookup at
SGE epochs) — the decoupling that gives the paper its 3-75x speedups.

New code should go through ``repro.selection`` — ``build_selector("milo",
metadata=..., ...)`` wraps this selector in the weighted ``SelectionPlan``
protocol, and ``MiloSession`` drives preprocess/train/tune end to end.  The
``indices_for_epoch`` entry point here is kept for backward compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.greedy import (
    greedy,
    greedy_importance,
    refine as run_refine,
    sge as run_sge,
    stochastic_candidate_count,
)
from repro.core import gram_free as gram_free_mod, submodular
from repro.core.curriculum import CurriculumConfig
from repro.core.exploration import taylor_softmax, weighted_sample_without_replacement
from repro.core.metadata import MiloMetadata
from repro.core.partition import (
    Partition,
    PartitionStrategy,
    make_partition_strategy,
    merge_class_selections,
    partition_by_class,
    proportional_budgets,
)
from repro.core.similarity import gram_matrix_blocked, normalize_rows


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def _normalize_probs(p: np.ndarray) -> np.ndarray:
    """Normalize to a distribution; degenerate mass (all-zero importance from
    singleton/degenerate classes, or NaN/inf from pathological features) falls
    back to uniform so WRE sampling stays well-defined."""
    p = np.where(np.isfinite(p), p, 0.0).astype(np.float32)
    p = np.maximum(p, 0.0)
    total = float(p.sum())
    if total <= 0.0:
        return np.full(p.shape, 1.0 / len(p), np.float32)
    return p / total


@dataclasses.dataclass
class MiloPreprocessor:
    """One-shot, model-agnostic pre-processing (paper §3.1-3.2)."""

    subset_fraction: float = 0.1
    n_sge_subsets: int = 8          # size of the easy-subset bank
    eps: float = 0.01               # stochastic-greedy epsilon (paper value)
    easy_fn: str = "graph_cut"      # SGE set function (paper: graph-cut)
    hard_fn: str = "disparity_min"  # WRE set function (paper: disparity-min)
    graph_cut_lambda: float = 0.4   # paper value
    classwise: bool = True
    metric: str = "cosine"
    gram_block: int = 2048
    use_pallas: bool = False        # route Gram tiles / FL gains through Pallas
    # Gram-free hot path: set functions contract features directly
    # (O(n·d + n) per-class memory) instead of materializing the (n², ) Gram.
    # Cosine metric only — the rescaled-cosine column is an O(n·d) matvec.
    gram_free: bool = False
    # Pad every per-class problem (ground-set size AND budget) to the next
    # power of two with exact masking, so the jitted greedy engines compile
    # once per bucket instead of once per distinct class size.
    bucket_classes: bool = True
    # Run the SGE bank as one vmapped XLA program (False = legacy per-run loop)
    sge_vmapped: bool = True
    # Shard the ground-set row axis of z across all local devices
    # (core.sharded): per-device memory drops to O(n·d / ndev + n) so one
    # class can exceed a single device.  Requires gram_free; classes whose
    # (padded) size does not divide the device count run the single-device
    # path — either way trajectories are identical to shard_selection=False.
    shard_selection: bool = False
    # Lazy gain reuse for the WRE full-greedy pass (facility-location hard
    # functions only): cache the gain vector and correct it over just the
    # rows whose cover the last pick moved, with a full recompute once the
    # touched fraction exceeds lazy_threshold.  Composes with
    # shard_selection: classes routed to the mesh run the same lazy engine
    # inside shard_map (sharded_greedy_importance(lazy_budget=...)), so the
    # largest classes get both the memory split AND the fewest-FLOPs path.
    # Near-ties below float32 rounding can resolve differently from the
    # eager pass (see greedy.lazy_greedy); importance is an equally valid
    # greedy order.
    lazy_gains: bool = False
    lazy_threshold: float = 0.125
    # Right-size each lazy gather to the smallest pow2 level covering the
    # touched rows instead of the full budget-sized block (bit-identical
    # trajectories; on the sharded path this shrinks the per-step psum
    # payload on calm steps at the cost of ~log2(budget) compiled variants).
    lazy_two_level: bool = False
    # Bucketed SGE draws its per-step candidate count s from the PADDED
    # problem geometry by default (one compile per bucket, documented
    # approximation).  True derives s from the class's true (n_c, k_c) —
    # the unpadded draw size — at no extra compile cost.
    exact_sge_candidates: bool = False
    # Input firewall policy run before any selection math (None = off):
    # "raise" refuses non-finite / zero-norm rows, "repair" fixes them
    # deterministically, "quarantine" excludes them from the ground set
    # and records the indices in provenance.  See repro.health.firewall.
    firewall: str | None = None
    # Level-0 ground-set decomposition (core.partition): "by_class" is the
    # paper's split and the provably-neutral default; "random_blocks" /
    # "balanced_blocks" bound per-partition memory so ground sets far past
    # one engine invocation's capacity still preprocess.
    partition: str = "by_class"
    partition_block: int = 4096     # block size for the block strategies
    partition_seed: int = 0         # random_blocks permutation seed
    # Level-1 refine: each partition contributes min(n_c, refine_factor*k_c)
    # SGE winners per bank slot and a greedy refine over the slot's union
    # (the easy_fn objective, lazy-routed like the WRE pass) cuts it back to
    # exactly k — the two-level scheme of Mirzasoleiman et al.  1 disables
    # the refine entirely: the flat path, bit-identical to pre-hierarchy
    # builds.
    refine_factor: int = 1

    def partition_strategy(self) -> PartitionStrategy:
        """The level-0 decomposition this preprocessor applies (see
        ``core.partition``); serving replays it to warm the exact per-
        partition geometries a future request will compile."""
        return make_partition_strategy(
            self.partition, block_size=self.partition_block,
            seed=self.partition_seed,
        )

    def _sharded_set_fn(self, name: str, mesh) -> submodular.SetFunction:
        from repro.core import sharded as sharded_mod

        kwargs = {}
        if name == "graph_cut":
            kwargs["lam"] = self.graph_cut_lambda
        if name == "facility_location":
            kwargs.update(
                use_pallas=self.use_pallas,
                interpret=jax.default_backend() != "tpu",
            )
        return sharded_mod.make_sharded_gram_free(
            name, n_shards=mesh.shape[sharded_mod.AXIS], **kwargs
        )

    def _lazy_budget(self, n_run: int, fn: submodular.SetFunction) -> int | None:
        """Touched-rows budget for the WRE full-greedy pass, or None when
        lazy gains are off / the set function has no lazy hooks / the
        threshold would not save anything."""
        if not self.lazy_gains or fn.lazy is None:
            return None
        budget = max(1, int(n_run * self.lazy_threshold))
        return None if budget >= n_run else budget

    def _set_fn(self, name: str) -> submodular.SetFunction:
        if self.gram_free:
            if name == "graph_cut":
                return gram_free_mod.make_gram_free_graph_cut(self.graph_cut_lambda)
            if name == "facility_location":
                # compiled kernel on TPU; interpret mode is the CPU
                # validation path, not a production route
                return gram_free_mod.make_gram_free_facility_location(
                    use_pallas=self.use_pallas,
                    interpret=jax.default_backend() != "tpu",
                )
            return gram_free_mod.get_gram_free(name)
        if name == "graph_cut":
            return submodular.make_graph_cut(self.graph_cut_lambda)
        return submodular.get(name)

    def _class_selection(
        self,
        feats_c: np.ndarray,
        k_c: int,
        k_sge: jax.Array,
        *,
        bucket: bool,
        mesh,
        easy: submodular.SetFunction,
        hard: submodular.SetFunction,
        easy_sh: submodular.SetFunction | None,
        hard_sh: submodular.SetFunction | None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """SGE bank + WRE importance for one class partition.

        ``feats_c`` is the class's (n_c, d) feature slice; returns the
        ``(n_sge_subsets, k_c)`` local-index bank and the (n_c,) importance
        vector.  ``warmup`` replays this exact path on dummy features, so
        every engine/transform program it compiles is the one preprocess
        will hit.
        """
        n_c = len(feats_c)
        z = jnp.asarray(feats_c)
        if self.gram_free:
            # the "kernel" threaded through the greedy engines is the
            # row-normalized feature matrix itself: O(n·d), no Gram
            A = normalize_rows(z.astype(jnp.float32))
        else:
            A = gram_matrix_blocked(
                z, metric=self.metric, block=self.gram_block,
                use_pallas=self.use_pallas,
            )
        valid = None
        k_run = k_c
        n_run = n_c
        if bucket:
            # Pad the problem (ground set AND budget) to the next
            # power of two: the jit cache then keys on O(log²)
            # distinct (bucket, k_run) pairs instead of every class
            # size.  Masking is exact — padded elements start
            # pre-selected and padded rows contribute nothing (zero
            # Gram rows / +inf FL cover) — so DETERMINISTIC runs
            # (full greedy -> WRE importance) match the unpadded run
            # bit-for-bit.  The STOCHASTIC SGE draws use the padded
            # candidate geometry (s and the per-step key split come
            # from n_pad/k_run), so for a fixed seed the bank differs
            # from an unbucketed run — a different but equally valid
            # stochastic-greedy sample (see ROADMAP perf follow-ups).
            n_pad = _next_pow2(n_c)
            k_run = min(n_pad, _next_pow2(k_c))
            if n_pad > n_c:
                pad = ((0, n_pad - n_c), (0, 0)) if self.gram_free else (
                    (0, n_pad - n_c), (0, n_pad - n_c))
                A = jnp.pad(A, pad)
            valid = jnp.arange(n_pad) < n_c
            n_run = n_pad
        # exact_sge_candidates: derive the stochastic-greedy draw
        # size from the class's true geometry instead of the padded
        # bucket's (identical when unbucketed)
        s_sge = (
            stochastic_candidate_count(n_c, k_c, self.eps)
            if self.exact_sge_candidates else None
        )
        # The sharded path needs the (padded) row count to divide the
        # mesh; pow2 buckets always do on a pow2 mesh, tiny/odd
        # classes fall back to the trajectory-identical local path.
        from repro.core import sharded as sharded_mod

        shard_ok = mesh is not None and n_run % mesh.size == 0
        if shard_ok:
            subs = sharded_mod.sharded_sge(
                easy_sh, A, k_run, k_sge, n_subsets=self.n_sge_subsets,
                eps=self.eps, s=s_sge, mesh=mesh, valid=valid,
            )
        else:
            subs = run_sge(
                easy, A, k_run, k_sge, n_subsets=self.n_sge_subsets,
                eps=self.eps, vmapped=self.sge_vmapped, valid=valid,
                s=s_sge,
            )
        if shard_ok:
            # lazy + sharded compose: the mesh classes run the same
            # cached-gain engine inside shard_map instead of silently
            # falling back to eager ring gains
            imp_full = sharded_mod.sharded_greedy_importance(
                hard_sh, A, mesh=mesh, valid=valid,
                lazy_budget=self._lazy_budget(n_run, hard_sh),
                lazy_two_level=self.lazy_two_level,
            )
        else:
            imp_full = greedy_importance(
                hard, A, valid=valid,
                lazy_budget=self._lazy_budget(n_run, hard),
                lazy_two_level=self.lazy_two_level,
            )
        subs_c = np.asarray(subs, np.int64)[:, :k_c]
        imp = np.asarray(imp_full, np.float32)[:n_c]
        return subs_c, imp

    def _refine_indices(
        self, feats_u: np.ndarray, k: int, mesh, easy, easy_sh
    ) -> np.ndarray:
        """Level-1 pass: exact greedy (easy_fn objective) over the union of
        level-0 winners, lazy-routed and mesh-dispatched exactly like the
        per-partition engines.  Returns local indices into ``feats_u``."""
        from repro.core import sharded as sharded_mod

        n_u = feats_u.shape[0]
        z = jnp.asarray(feats_u)
        if self.gram_free:
            A = normalize_rows(z.astype(jnp.float32))
        else:
            A = gram_matrix_blocked(
                z, metric=self.metric, block=self.gram_block,
                use_pallas=self.use_pallas,
            )
        shard_ok = mesh is not None and n_u % mesh.size == 0
        if shard_ok:
            res = sharded_mod.sharded_refine(
                easy_sh, A, k, mesh=mesh,
                lazy_budget=self._lazy_budget(n_u, easy_sh),
                lazy_two_level=self.lazy_two_level,
            )
        else:
            res = run_refine(
                easy, A, k, lazy_budget=self._lazy_budget(n_u, easy),
                two_level=self.lazy_two_level,
            )
        return np.asarray(res.indices, np.int64)

    def _refine_bank(
        self,
        features: np.ndarray,
        parts: Sequence[Partition],
        per_class_sge: Sequence[np.ndarray],
        k: int,
        mesh,
        easy,
        easy_sh,
    ) -> np.ndarray:
        """Cut each oversampled bank slot back down to exactly k.

        Every slot's union has the same size (Σ min(n_c, rf·k_c) — the
        per-partition bank widths are slot-independent), so the refine
        program compiles once and replays across the bank.
        """
        slots = []
        for i in range(self.n_sge_subsets):
            union = merge_class_selections(
                parts, [s[i] for s in per_class_sge]
            )
            if len(union) <= k:
                slots.append(union)
                continue
            local = self._refine_indices(
                features[union], k, mesh, easy, easy_sh
            )
            slots.append(union[local])
        return np.stack(slots, axis=0)

    def _selection_mesh(self):
        """(mesh, easy_sh, hard_sh) when shard_selection routes to a real
        multi-device mesh; (None, None, None) otherwise."""
        if not self.shard_selection:
            return None, None, None
        if not self.gram_free:
            raise ValueError(
                "shard_selection=True requires gram_free=True: only the "
                "feature-matrix row axis is shardable (a materialized "
                "Gram couples both axes)"
            )
        from repro.core import sharded as sharded_mod
        from repro.distributed.sharding import selection_mesh

        sel_mesh = selection_mesh(axis=sharded_mod.AXIS)
        if sel_mesh.shape[sharded_mod.AXIS] <= 1:
            return None, None, None
        return (
            sel_mesh,
            self._sharded_set_fn(self.easy_fn, sel_mesh),
            self._sharded_set_fn(self.hard_fn, sel_mesh),
        )

    def warmup(
        self,
        buckets: Sequence[tuple[int, int]],
        d: int,
        *,
        key: jax.Array | None = None,
    ) -> int:
        """Pre-compile the engine programs for the given class geometries.

        ``buckets`` holds the true per-class ``(n_c, k_c)`` shapes an
        upcoming ``preprocess`` will see (e.g. ``[(5000, 500)] * 10`` for a
        balanced 10-class dataset); ``d`` is the feature dimension (float32,
        the dtype preprocess casts to).  Each distinct pair replays the full
        per-class selection path — bucketing, masking, engine routing,
        Taylor-softmax — on dummy features, so the jitted programs (keyed on
        the factory-memoized set functions plus shapes) are compiled before
        any real data arrives and the subsequent ``preprocess()`` triggers
        zero backend compiles.  Returns the number of class geometries run;
        outputs are discarded.
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        bucket_list = [(int(n_c), int(k_c)) for n_c, k_c in buckets]
        # mirror preprocess: bucketing only deduplicates across >1 partition
        bucket = self.bucket_classes and len(bucket_list) > 1
        easy = self._set_fn(self.easy_fn)
        hard = self._set_fn(self.hard_fn)
        mesh, easy_sh, hard_sh = self._selection_mesh()
        rng = np.random.default_rng(0)
        rf = max(1, int(self.refine_factor))
        seen: set[tuple[int, int]] = set()
        for n_c, k_c in bucket_list:
            # the per-partition engines run at the oversampled bank width
            k_sel = min(n_c, rf * k_c)
            if k_sel <= 0 or (n_c, k_sel) in seen:
                continue
            seen.add((n_c, k_sel))
            key, k_sge = jax.random.split(key)
            dummy = rng.normal(size=(n_c, d)).astype(np.float32)
            _, imp = self._class_selection(
                dummy, k_sel, k_sge, bucket=bucket, mesh=mesh,
                easy=easy, hard=hard, easy_sh=easy_sh, hard_sh=hard_sh,
            )
            # preprocess follows every class selection with a within-class
            # Taylor-softmax on the (n_c,)-shaped importance — warm it too
            jax.block_until_ready(taylor_softmax(jnp.asarray(imp)))
        if rf > 1:
            # warm the level-1 refine program on the exact union geometry
            # preprocess will hit: Σ min(n_c, rf·k_c) winner rows cut to k
            n_union = sum(min(n_c, rf * k_c)
                          for n_c, k_c in bucket_list if k_c > 0)
            k_total = sum(k_c for n_c, k_c in bucket_list if k_c > 0)
            if 0 < k_total < n_union:
                dummy = rng.normal(size=(n_union, d)).astype(np.float32)
                self._refine_indices(dummy, k_total, mesh, easy, easy_sh)
        return len(seen)

    def preprocess(
        self,
        features: np.ndarray,
        labels: np.ndarray | None,
        key: jax.Array,
        *,
        encoder_id: str = "precomputed",
        prep_seed: int | None = None,
    ) -> MiloMetadata:
        """``prep_seed`` is provenance only: the integer the caller derived
        ``key`` from, recorded in the artifact config so reuse checks can
        tell two stochastic-greedy draws apart.

        With ``firewall`` set, the ground set is screened first
        (``repro.health.validate_features``) and the resulting
        ``DataHealthReport`` is stamped into the artifact config under
        ``data_health``.  Under the ``quarantine`` policy the flagged rows
        are excluded from selection entirely: ``k`` is computed over the
        surviving rows, quarantined rows get zero WRE probability and can
        never appear in an SGE subset, and their indices are recorded in
        provenance.
        """
        features = np.asarray(features)
        report = None
        if self.firewall is not None:
            from repro.health.firewall import validate_features

            features, report = validate_features(
                features, labels, policy=self.firewall,
                subset_fraction=self.subset_fraction,
                # overbudget detection mirrors the decomposition selection
                # will actually use (classwise off -> single catch-all)
                strategy=(self.partition_strategy() if self.classwise
                          else None),
            )
        quarantined = report.quarantined_rows if report is not None else []
        if quarantined:
            m = features.shape[0]
            labels_full = (
                None if labels is None else np.asarray(labels, np.int64))
            keep = np.setdiff1d(
                np.arange(m, dtype=np.int64),
                np.asarray(quarantined, np.int64),
            )
            md = self._preprocess_clean(
                features[keep],
                None if labels_full is None else labels_full[keep],
                key, encoder_id=encoder_id, prep_seed=prep_seed,
            )
            md = self._lift_quarantined(md, keep, m, labels_full)
        else:
            md = self._preprocess_clean(
                features, labels, key,
                encoder_id=encoder_id, prep_seed=prep_seed,
            )
        if report is not None:
            md.config["firewall"] = self.firewall
            md.config["data_health"] = report.to_dict()
        return md

    @staticmethod
    def _lift_quarantined(
        md: MiloMetadata,
        keep: np.ndarray,
        m: int,
        labels_full: np.ndarray | None,
    ) -> MiloMetadata:
        """Re-index an artifact built over ``features[keep]`` back to the
        full ground set: bank indices map through ``keep``, probabilities
        and importance scatter into zeros at the quarantined rows (which
        therefore can never be drawn)."""
        probs = np.zeros((m,), np.float32)
        probs[keep] = md.wre_probs
        imp = np.zeros((m,), np.float32)
        imp[keep] = md.wre_importance
        return MiloMetadata(
            sge_subsets=keep[md.sge_subsets],
            wre_probs=probs,
            wre_importance=imp,
            class_labels=(labels_full if labels_full is not None
                          else np.zeros((m,), np.int64)),
            class_budgets=md.class_budgets,
            config=md.config,
        )

    def _preprocess_clean(
        self,
        features: np.ndarray,
        labels: np.ndarray | None,
        key: jax.Array,
        *,
        encoder_id: str = "precomputed",
        prep_seed: int | None = None,
    ) -> MiloMetadata:
        features = np.asarray(features)
        if self.gram_free and self.metric != "cosine":
            raise ValueError(
                f"gram_free preprocessing supports metric='cosine' only "
                f"(got {self.metric!r}); the gram-free set functions rebuild "
                "rescaled-cosine columns from features on the fly"
            )
        m = features.shape[0]
        k = max(1, int(round(self.subset_fraction * m)))
        strategy = self.partition_strategy()
        labels_arr = (np.zeros((m,), np.int64) if labels is None
                      else np.asarray(labels, np.int64))
        # label-free strategies (random_blocks) ignore the labels argument;
        # by_class without labels / classwise yields the single catch-all
        # partition — exactly the historical flat behaviour
        parts = strategy.partition(
            None if labels is None or not self.classwise else labels_arr, m
        )
        budgets = proportional_budgets(parts, k)
        rf = max(1, int(self.refine_factor))
        # oversampled per-partition bank widths (== budgets when rf == 1)
        sel_widths = [min(len(p.indices), rf * b)
                      for p, b in zip(parts, budgets)]

        easy = self._set_fn(self.easy_fn)
        hard = self._set_fn(self.hard_fn)
        # Bucketing exists to deduplicate compiles across many class shapes;
        # with a single partition there is exactly one shape, so padding
        # would only inflate the problem (up to 4x Gram memory, 2x steps).
        bucket = self.bucket_classes and len(parts) > 1
        mesh, easy_sh, hard_sh = self._selection_mesh()

        per_class_sge: list[np.ndarray] = []  # each (n_subsets, k_c) local idx
        wre_probs = np.zeros((m,), np.float32)
        wre_importance = np.zeros((m,), np.float32)

        for part, k_sel in zip(parts, sel_widths):
            key, k_sge = jax.random.split(key)
            n_c = len(part.indices)
            if k_sel <= 0:
                per_class_sge.append(np.zeros((self.n_sge_subsets, 0), np.int64))
                imp = np.zeros((n_c,), np.float32)
            else:
                subs_c, imp = self._class_selection(
                    features[part.indices], k_sel, k_sge, bucket=bucket,
                    mesh=mesh, easy=easy, hard=hard,
                    easy_sh=easy_sh, hard_sh=hard_sh,
                )
                per_class_sge.append(subs_c)
            wre_importance[part.indices] = imp
            # Within-class Taylor-softmax, weighted by class mass so the global
            # vector is a proper distribution with stratified expectation.
            p_local = np.asarray(taylor_softmax(jnp.asarray(imp)), np.float32)
            wre_probs[part.indices] = p_local * (n_c / m)

        wre_probs = _normalize_probs(wre_probs)
        if rf > 1:
            # level-1: each slot's oversampled union refined down to k
            sge_subsets = self._refine_bank(
                features, parts, per_class_sge, k, mesh, easy, easy_sh
            )
        else:
            sge_subsets = np.stack(
                [
                    merge_class_selections(parts, [s[i] for s in per_class_sge])
                    for i in range(self.n_sge_subsets)
                ],
                axis=0,
            )
        config = dict(
            subset_fraction=self.subset_fraction,
            k=int(sge_subsets.shape[1]),
            n_sge_subsets=self.n_sge_subsets,
            eps=self.eps,
            easy_fn=self.easy_fn,
            hard_fn=self.hard_fn,
            graph_cut_lambda=self.graph_cut_lambda,
            classwise=self.classwise,
            metric=self.metric,
            gram_free=self.gram_free,
            bucket_classes=self.bucket_classes,
            # trajectory-affecting engine knobs (checked on artifact
            # reuse); shard_selection is recorded for provenance only —
            # sharded and single-device runs select identically
            lazy_gains=self.lazy_gains,
            lazy_threshold=self.lazy_threshold,
            # provenance only, like shard_selection: two-level gathers
            # are bit-identical to single-level, so artifacts stay
            # portable across the knob
            lazy_two_level=self.lazy_two_level,
            exact_sge_candidates=self.exact_sge_candidates,
            shard_selection=self.shard_selection,
            encoder_id=encoder_id,
            prep_seed=prep_seed,
        )
        # Partition provenance is stamped only when the hierarchical path is
        # active: flat (by_class, rf == 1) configs stay key-for-key identical
        # to pre-hierarchy builds, so their config_hash — and every artifact
        # reuse check keyed on it — is unchanged (the firewall keys set the
        # same precedent).
        if strategy.name != "by_class" or rf > 1:
            config.update(strategy.config())
            config["refine_factor"] = rf
        return MiloMetadata(
            sge_subsets=sge_subsets,
            wre_probs=wre_probs,
            wre_importance=wre_importance,
            class_labels=labels_arr,
            class_budgets=np.asarray(budgets, np.int64),
            config=config,
        )


@dataclasses.dataclass
class MiloSelector:
    """Per-epoch subset server driven by the curriculum (paper Alg. 1)."""

    metadata: MiloMetadata
    curriculum: CurriculumConfig
    seed: int = 0

    def __post_init__(self):
        self._cache_epoch: int = -1
        self._cache: np.ndarray | None = None

    @property
    def k(self) -> int:
        return self.metadata.k

    def indices_for_epoch(self, epoch: int) -> np.ndarray:
        """Subset (global indices) to train on at ``epoch``.

        Deterministic in (seed, epoch) so fault-tolerant restarts replay the
        identical data order (see distributed/fault_tolerance.py).
        """
        if epoch == self._cache_epoch and self._cache is not None:
            return self._cache
        cur = self.curriculum
        if cur.phase(epoch) == "sge":
            slot = (epoch // cur.R) % self.metadata.sge_subsets.shape[0]
            idx = self.metadata.sge_subsets[slot]
        else:
            # One fresh WRE draw per R-epoch window, keyed by (seed, window).
            window = (epoch - cur.sge_epochs) // cur.R
            key = jax.random.fold_in(jax.random.PRNGKey(self.seed), window)
            idx = np.asarray(
                weighted_sample_without_replacement(
                    key, jnp.asarray(self.metadata.wre_probs), self.k
                ),
                np.int64,
            )
        self._cache_epoch, self._cache = epoch, idx
        return idx


def _hier_kernel(
    feats: np.ndarray,
    n_pad: int,
    *,
    gram_free: bool,
    metric: str,
    gram_block: int,
    use_pallas: bool,
    pre_normalized: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(engine kernel, valid mask) for one partition, padded to ``n_pad``.

    Padding keeps every partition on ONE compiled greedy program (shapes
    (n_pad, ·) regardless of the true slice size); masking is exact, so the
    first n-valid picks equal the unpadded run's.  The mask is always
    materialized — an all-true mask is bit-equivalent to ``valid=None`` and
    keeps the jit input pytree static across equal- and under-sized
    partitions.
    """
    z = jnp.asarray(feats, jnp.float32)
    n = z.shape[0]
    if gram_free:
        A = z if pre_normalized else normalize_rows(z)
        if n_pad > n:
            A = jnp.pad(A, ((0, n_pad - n), (0, 0)))
    else:
        A = gram_matrix_blocked(z, metric=metric, block=gram_block,
                                use_pallas=use_pallas)
        if n_pad > n:
            A = jnp.pad(A, ((0, n_pad - n), (0, n_pad - n)))
    return A, jnp.arange(n_pad) < n


def _two_level_select(
    features: np.ndarray,
    k: int,
    parts: Sequence[Partition],
    budgets: Sequence[int],
    rf: int,
    fn: submodular.SetFunction,
    *,
    gram_free: bool,
    metric: str = "cosine",
    gram_block: int = 2048,
    use_pallas: bool = False,
    lazy_threshold: float | None = 0.125,
    pre_normalized: bool = False,
) -> tuple[np.ndarray, dict]:
    """Shared partition-then-refine driver (deterministic greedy both levels).

    Level 0: exact greedy inside every partition, oversampled to
    ``min(n_c, rf·k_c)`` winners; level 1: ``greedy.refine`` over the union
    of winners down to exactly ``k``.  Peak memory is O(n_max·d) gram-free
    (O(n_max²) with a materialized Gram) — the partition size, not the
    ground-set size.
    """
    kern = dict(gram_free=gram_free, metric=metric, gram_block=gram_block,
                use_pallas=use_pallas, pre_normalized=pre_normalized)
    active = [(p, b) for p, b in zip(parts, budgets)
              if b > 0 and len(p.indices) > 0]
    if not active:
        return np.zeros((0,), np.int64), {
            "n_partitions": len(parts), "union_size": 0,
            "peak_partition_rows": 0, "refine_factor": rf,
        }
    k_sels = [min(len(p.indices), rf * b) for p, b in active]
    n_max = max(len(p.indices) for p, _ in active)
    k_max = max(k_sels)
    winners = []
    for (p, _), k_sel in zip(active, k_sels):
        A, valid = _hier_kernel(features[p.indices], n_max, **kern)
        res = greedy(fn, A, k_max, valid=valid, n=n_max)
        # first k_sel picks of the padded run == the unpadded run's picks
        local = np.asarray(res.indices, np.int64)[:k_sel]
        winners.append(np.asarray(p.indices, np.int64)[local])
    union = np.concatenate(winners)
    if len(union) > k:
        n_u = len(union)
        A, valid = _hier_kernel(features[union], n_u, **kern)
        lazy_budget = None
        if lazy_threshold is not None and fn.lazy is not None:
            b = max(1, int(n_u * lazy_threshold))
            lazy_budget = b if b < n_u else None
        res = run_refine(fn, A, k, valid=valid, lazy_budget=lazy_budget)
        selected = union[np.asarray(res.indices, np.int64)]
    else:
        selected = union
    info = {
        "n_partitions": len(parts),
        "union_size": int(len(union)),
        "peak_partition_rows": int(n_max),
        "refine_factor": rf,
    }
    return selected, info


def hierarchical_select(
    features: np.ndarray,
    k: int,
    *,
    labels: np.ndarray | None = None,
    partition: str | PartitionStrategy = "random_blocks",
    block_size: int = 4096,
    seed: int = 0,
    refine_factor: int = 2,
    fn_name: str = "facility_location",
    gram_free: bool = True,
    metric: str = "cosine",
    gram_block: int = 2048,
    use_pallas: bool = False,
    graph_cut_lambda: float = 0.4,
    lazy_threshold: float | None = 0.125,
    return_info: bool = False,
):
    """One-shot hierarchical subset selection (partition → greedy → refine).

    The deterministic two-level scheme: a :class:`PartitionStrategy` splits
    the ground set, exact greedy picks ``refine_factor·k_c`` winners inside
    each partition (one compiled program for the whole sweep — partitions
    are padded to the largest), and a level-1 ``greedy.refine`` over the
    union returns exactly ``k`` global indices.  With FL and enough
    oversampling the objective stays within a few percent of the exact flat
    greedy (asserted ≥ 0.95× in tests) while peak memory tracks the
    *partition* size — ground sets of 2^20+ rows select on hardware where
    the flat pass cannot even hold its init.

    Returns the (k,) int64 global indices; with ``return_info=True`` also a
    dict of the run's geometry (partition count, union size, peak partition
    rows).
    """
    features = np.asarray(features)
    m = features.shape[0]
    k = max(0, min(int(k), m))
    if k == 0:
        empty = np.zeros((0,), np.int64)
        return (empty, {"n_partitions": 0, "union_size": 0,
                        "peak_partition_rows": 0,
                        "refine_factor": refine_factor}) if return_info else empty
    strategy = (partition if isinstance(partition, PartitionStrategy)
                else make_partition_strategy(partition, block_size=block_size,
                                             seed=seed))
    parts = strategy.partition(labels, m)
    budgets = proportional_budgets(parts, k)
    rf = max(1, int(refine_factor))
    pre = MiloPreprocessor(
        easy_fn=fn_name, gram_free=gram_free, metric=metric,
        gram_block=gram_block, use_pallas=use_pallas,
        graph_cut_lambda=graph_cut_lambda,
    )
    fn = pre._set_fn(fn_name)
    selected, info = _two_level_select(
        features, k, parts, budgets, rf, fn, gram_free=gram_free,
        metric=metric, gram_block=gram_block, use_pallas=use_pallas,
        lazy_threshold=lazy_threshold,
    )
    return (selected, info) if return_info else selected


def targeted_select(
    features: np.ndarray,
    queries: np.ndarray,
    k: int,
    *,
    labels: np.ndarray | None = None,
    partition: str | PartitionStrategy = "by_class",
    block_size: int = 4096,
    seed: int = 0,
    refine_factor: int = 4,
    return_info: bool = False,
):
    """Query-conditioned (SMI-style) targeted selection over partition winners.

    The auto-labeling / active-learning shape: ``queries`` holds a handful
    of exemplar embeddings of the slice you care about, and the objective is
    query facility location — f(S) = Σ_q max_{a∈S} sim(a, q) — so the subset
    *covers the queries*, not the ground set.  Both levels use the query
    objective: per-partition winners are the rows most relevant to the
    queries, and the level-1 refine trades them off globally.  Gram-free
    cosine only (the query gains are O(n·q) feature contractions).

    Returns the (k,) int64 global indices (plus the geometry dict with
    ``return_info=True``).
    """
    features = np.asarray(features)
    m = features.shape[0]
    k = max(0, min(int(k), m))
    if k == 0:
        empty = np.zeros((0,), np.int64)
        return (empty, {"n_partitions": 0, "union_size": 0,
                        "peak_partition_rows": 0,
                        "refine_factor": refine_factor}) if return_info else empty
    zn = np.asarray(normalize_rows(jnp.asarray(features, jnp.float32)))
    zq = np.asarray(normalize_rows(jnp.asarray(queries, jnp.float32)))
    fn = gram_free_mod.make_query_facility_location(zq)
    strategy = (partition if isinstance(partition, PartitionStrategy)
                else make_partition_strategy(partition, block_size=block_size,
                                             seed=seed))
    parts = strategy.partition(labels, m)
    budgets = proportional_budgets(parts, k)
    rf = max(1, int(refine_factor))
    selected, info = _two_level_select(
        zn, k, parts, budgets, rf, fn, gram_free=True, pre_normalized=True,
        lazy_threshold=None,
    )
    return (selected, info) if return_info else selected


def preprocess_with_encoder(
    encode_fn: Callable[[np.ndarray], np.ndarray],
    inputs: np.ndarray,
    labels: np.ndarray | None,
    key: jax.Array,
    *,
    batch_size: int = 256,
    encoder_id: str = "custom",
    **pre_kwargs,
) -> MiloMetadata:
    """Encode inputs in batches with a frozen encoder, then preprocess."""
    feats = []
    for lo in range(0, len(inputs), batch_size):
        feats.append(np.asarray(encode_fn(inputs[lo : lo + batch_size])))
    features = np.concatenate(feats, axis=0)
    pre = MiloPreprocessor(**pre_kwargs)
    return pre.preprocess(features, labels, key, encoder_id=encoder_id)
