"""Similarity kernels over feature embeddings.

The paper (App. I.2) evaluates cosine similarity (additively rescaled to be
non-negative), dot-product, and RBF kernels, and settles on rescaled cosine:

    sim(r1, r2) = 0.5 + 0.5 * <r1, r2> / (|r1| |r2|)

All functions here are pure jnp and jit-friendly.  The Pallas-accelerated
blocked Gram kernel lives in ``repro.kernels.similarity``; ``gram_matrix``
dispatches to it when requested (TPU) and otherwise uses the XLA path.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["cosine", "dot", "rbf"]


def normalize_rows(z: jax.Array, eps: float = 1e-8) -> jax.Array:
    """L2-normalize row vectors."""
    norm = jnp.linalg.norm(z, axis=-1, keepdims=True)
    return z / jnp.maximum(norm, eps)


def cosine_similarity(zq: jax.Array, zk: jax.Array) -> jax.Array:
    """Rescaled cosine similarity in [0, 1] (paper Eq. 10)."""
    zq = normalize_rows(zq)
    zk = normalize_rows(zk)
    return 0.5 + 0.5 * (zq @ zk.T)


def dot_similarity(zq: jax.Array, zk: jax.Array) -> jax.Array:
    """Dot-product similarity, additively shifted to be non-negative.

    The paper performs additive scaling so all pairwise values are >= 0; as a
    jit-friendly surrogate we shift by the batch minimum.
    """
    s = zq @ zk.T
    return s - jnp.minimum(jnp.min(s), 0.0)


def rbf_similarity(
    zq: jax.Array, zk: jax.Array, *, kw: float = 0.1, mean_dist: float | jax.Array | None = None
) -> jax.Array:
    """RBF kernel with bandwidth ``kw * mean_dist`` (paper Eq. 11)."""
    # Squared euclidean distances via the expansion trick.
    qq = jnp.sum(zq * zq, axis=-1, keepdims=True)
    kk = jnp.sum(zk * zk, axis=-1, keepdims=True)
    d2 = jnp.maximum(qq - 2.0 * (zq @ zk.T) + kk.T, 0.0)
    if mean_dist is None:
        mean_dist = jnp.mean(jnp.sqrt(d2 + 1e-12))
    return jnp.exp(-d2 / (kw * mean_dist + 1e-12))


@functools.partial(jax.jit, static_argnames=("metric", "kw"))
def gram_matrix(
    zq: jax.Array,
    zk: jax.Array | None = None,
    *,
    metric: Metric = "cosine",
    kw: float = 0.1,
) -> jax.Array:
    """Full pairwise similarity matrix between ``zq`` rows and ``zk`` rows.

    Computed in float32 regardless of input dtype (greedy gain accumulation is
    sensitive to precision).
    """
    if zk is None:
        zk = zq
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    if metric == "cosine":
        return cosine_similarity(zq, zk)
    if metric == "dot":
        return dot_similarity(zq, zk)
    if metric == "rbf":
        return rbf_similarity(zq, zk, kw=kw)
    raise ValueError(f"unknown metric {metric!r}")


def gram_matrix_blocked(
    z: jax.Array,
    *,
    metric: Metric = "cosine",
    block: int = 1024,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Blocked Gram matrix for large m: streams (block x d) tiles.

    ``use_pallas=True`` routes each tile through the Pallas similarity kernel
    (``repro.kernels.similarity``); on CPU this requires ``interpret=True``.
    """
    m = z.shape[0]
    z32 = normalize_rows(z.astype(jnp.float32)) if metric == "cosine" else z.astype(jnp.float32)
    nblocks = (m + block - 1) // block
    rows = []
    for bi in range(nblocks):
        lo = bi * block
        hi = min(m, lo + block)
        zq = z32[lo:hi]
        if use_pallas and metric == "cosine":
            from repro.kernels.similarity import ops as sim_ops

            rows.append(sim_ops.similarity(zq, z32, normalized=True, interpret=interpret))
        else:
            if metric == "cosine":
                rows.append(0.5 + 0.5 * (zq @ z32.T))
            else:
                rows.append(gram_matrix(zq, z32, metric=metric))
    return jnp.concatenate(rows, axis=0)
