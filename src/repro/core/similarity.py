"""Similarity kernels over feature embeddings.

The paper (App. I.2) evaluates cosine similarity (additively rescaled to be
non-negative), dot-product, and RBF kernels, and settles on rescaled cosine:

    sim(r1, r2) = 0.5 + 0.5 * <r1, r2> / (|r1| |r2|)

All functions here are pure jnp and jit-friendly.  The Pallas-accelerated
blocked Gram kernel lives in ``repro.kernels.similarity``; ``gram_matrix``
dispatches to it when requested (TPU) and otherwise uses the XLA path.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["cosine", "dot", "rbf"]


def normalize_rows(z: jax.Array, eps: float = 1e-8) -> jax.Array:
    """L2-normalize row vectors.

    Zero-norm rows survive as exact zero vectors (``0 / eps``) rather than
    raising — deliberately: the gram-free engines use all-zero rows as
    padding sentinels (FL init pins their cover to +inf, graph-cut zeroes
    their column sums).  The cost is that a *genuine* zero-norm data row is
    silently flattened and then scores a constant 0.5 against everything
    under the rescaled cosine, distorting facility-location gains.  Screen
    real ground sets with :func:`repro.health.validate_features`, which
    uses :func:`zero_norm_rows` to detect them before any selection math.
    """
    norm = jnp.linalg.norm(z, axis=-1, keepdims=True)
    return z / jnp.maximum(norm, eps)


def zero_norm_rows(z: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Boolean row mask: rows ``normalize_rows`` would flatten to zero.

    The canonical zero-norm detector shared with the health firewall: a
    row is flagged when its L2 norm is <= ``eps`` (the same floor
    ``normalize_rows`` divides by).  Pure jnp and jit-friendly.
    """
    return jnp.linalg.norm(z, axis=-1) <= eps


def cosine_similarity(zq: jax.Array, zk: jax.Array) -> jax.Array:
    """Rescaled cosine similarity in [0, 1] (paper Eq. 10)."""
    zq = normalize_rows(zq)
    zk = normalize_rows(zk)
    return 0.5 + 0.5 * (zq @ zk.T)


def dot_similarity(
    zq: jax.Array, zk: jax.Array, *, shift: float | jax.Array | None = None
) -> jax.Array:
    """Dot-product similarity, additively shifted to be non-negative.

    The paper performs additive scaling so all pairwise values are >= 0; as a
    jit-friendly surrogate we shift by the batch minimum.  Blocked callers
    must pass the *global* minimum as ``shift`` — a per-tile minimum would
    make the assembled matrix a different function in every block.
    """
    s = zq @ zk.T
    if shift is None:
        shift = jnp.min(s)
    return s - jnp.minimum(shift, 0.0)


def rbf_similarity(
    zq: jax.Array, zk: jax.Array, *, kw: float = 0.1, mean_dist: float | jax.Array | None = None
) -> jax.Array:
    """RBF kernel with bandwidth ``kw * mean_dist`` (paper Eq. 11)."""
    # Squared euclidean distances via the expansion trick.
    qq = jnp.sum(zq * zq, axis=-1, keepdims=True)
    kk = jnp.sum(zk * zk, axis=-1, keepdims=True)
    d2 = jnp.maximum(qq - 2.0 * (zq @ zk.T) + kk.T, 0.0)
    if mean_dist is None:
        mean_dist = jnp.mean(jnp.sqrt(d2 + 1e-12))
    return jnp.exp(-d2 / (kw * mean_dist + 1e-12))


@functools.partial(jax.jit, static_argnames=("metric", "kw"))
def gram_matrix(
    zq: jax.Array,
    zk: jax.Array | None = None,
    *,
    metric: Metric = "cosine",
    kw: float = 0.1,
) -> jax.Array:
    """Full pairwise similarity matrix between ``zq`` rows and ``zk`` rows.

    Computed in float32 regardless of input dtype (greedy gain accumulation is
    sensitive to precision).
    """
    if zk is None:
        zk = zq
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    if metric == "cosine":
        return cosine_similarity(zq, zk)
    if metric == "dot":
        return dot_similarity(zq, zk)
    if metric == "rbf":
        return rbf_similarity(zq, zk, kw=kw)
    raise ValueError(f"unknown metric {metric!r}")


def gram_matrix_blocked(
    z: jax.Array,
    *,
    metric: Metric = "cosine",
    block: int = 1024,
    kw: float = 0.1,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Blocked Gram matrix for large m: streams (block x d) tiles.

    ``use_pallas=True`` routes each tile through the Pallas similarity kernel
    (``repro.kernels.similarity``); on CPU this requires ``interpret=True``.

    ``dot``'s non-negativity shift and ``rbf``'s mean-distance bandwidth are
    data-dependent *global* statistics: they are computed once over all tiles
    in a first pass and passed into every tile, so the assembled matrix is
    the same function in every block (and matches ``gram_matrix``).
    """
    m = z.shape[0]
    z32 = normalize_rows(z.astype(jnp.float32)) if metric == "cosine" else z.astype(jnp.float32)
    nblocks = (m + block - 1) // block
    tiles = [(bi * block, min(m, (bi + 1) * block)) for bi in range(nblocks)]

    if metric == "cosine":
        rows = []
        for lo, hi in tiles:
            if use_pallas:
                from repro.kernels.similarity import ops as sim_ops

                rows.append(sim_ops.similarity(z32[lo:hi], z32, normalized=True,
                                               interpret=interpret))
            else:
                rows.append(0.5 + 0.5 * (z32[lo:hi] @ z32.T))
        return jnp.concatenate(rows, axis=0)

    # dot/rbf: the shift / bandwidth are GLOBAL data-dependent statistics —
    # a per-tile statistic would make the assembled matrix a different
    # function in every block (and disagree with the one-shot gram_matrix).
    if metric == "dot":
        # the raw tiles ARE the output modulo the shift, so one sweep suffices
        raw = [z32[lo:hi] @ z32.T for lo, hi in tiles]
        shift = jnp.min(jnp.stack([jnp.min(r) for r in raw]))
        return jnp.concatenate(raw, axis=0) - jnp.minimum(shift, 0.0)
    if metric == "rbf":
        # two passes, recomputing each d2 tile in the second: the bandwidth
        # needs every tile before any output can be produced, and holding
        # all d2 tiles alongside the exp tiles would triple peak memory —
        # the one thing a blocked builder exists to bound.
        sumsq = jnp.sum(z32 * z32, axis=-1)

        def d2_tile(lo: int, hi: int) -> jax.Array:
            return jnp.maximum(
                sumsq[lo:hi, None] - 2.0 * (z32[lo:hi] @ z32.T) + sumsq[None, :], 0.0
            )

        total = sum(jnp.sum(jnp.sqrt(d2_tile(lo, hi) + 1e-12)) for lo, hi in tiles)
        mean_dist = total / (m * m)
        return jnp.concatenate(
            [jnp.exp(-d2_tile(lo, hi) / (kw * mean_dist + 1e-12)) for lo, hi in tiles],
            axis=0,
        )
    raise ValueError(f"unknown metric {metric!r}")
