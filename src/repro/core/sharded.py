"""Multi-device sharded selection: gram-free engines over a row-sharded mesh.

The gram-free path (``core.gram_free``) already cut per-class selection
memory from O(n²) to O(n·d + n); this module removes the remaining wall —
one device's memory capping ``n·d`` — by sharding the *row axis* of the
feature matrix ``z`` across a 1-D device mesh
(``distributed.sharding.selection_mesh``) and running the unchanged greedy
engines inside ``shard_map``:

  * ``z`` is sharded ``P("sel", None)``: each device holds ``n/ndev`` rows.
    This is the only O(n·d) object anywhere.
  * Every per-element vector the engines thread — the ``selected`` mask, FL's
    cover ``c``, graph-cut's ``colsum``/``cur``, disparity state — is O(n)
    and stays **replicated**, so the engines' argmax/top-k/scatter logic is
    untouched: each device computes the identical pick from identical
    replicated inputs.
  * Similarity columns ``K[:, j]`` are assembled exactly: the owner shard
    contributes ``z_j`` through a one-hot ``psum`` (all other shards add
    zeros — bit-exact), each shard contracts its own rows, and an ordered
    ``all_gather`` concatenates the chunks.  No cross-shard arithmetic
    touches these values, so graph-cut/disparity trajectories AND gains are
    bit-identical to the single-device run.
  * Facility-location full gains reduce over the ground-set axis: each shard
    accumulates partial gains with the same ``fl_gains_gram_free`` kernel the
    single-device path uses (the kernel's i-axis loop is already shard
    shaped), visiting candidate blocks via a ring ``ppermute`` so full ``z``
    is never materialized, then combines with ``psum``.  The first block of
    the ring is the shard's own ``z_local`` (no rotation needed), so a full
    gains evaluation issues exactly ``n_shards - 1`` hops — statically
    countable in the jaxpr because the schedule is unrolled over the (static)
    shard count.  The cross-shard sum reassociates float additions, so
    FL/graph-cut *gain values* can differ from the single-device path by
    ~1 ulp; selected trajectories are bit-identical on all tested fixtures
    (argmax gaps are many orders above ulp noise).
  * Facility location also exposes the ``SetFunction.lazy`` hooks, so
    ``greedy.lazy_greedy`` runs unchanged inside ``shard_map``: the cover and
    the cached gain vector are replicated, and the delta correction takes a
    *ring-free* candidate path — the touched rows are gathered exactly via
    the one-owner ``psum`` gather (a ``budget × d`` block, small by
    construction), each shard contracts them against its OWN candidate block
    through ``fl_gains_gram_free_delta``, and an ordered ``all_gather``
    concatenates the per-shard corrections.  The delta values are bit-exact
    against the single-device delta (same per-candidate reduction order);
    only the cached base gains carry the ring ``psum``'s ~1 ulp.

``sharded_greedy`` / ``sharded_lazy_greedy`` / ``sharded_stochastic_greedy``
/ ``sharded_sge`` / ``sharded_greedy_importance`` wrap the engines; they
require ``n % ndev == 0`` (the preprocessor's power-of-two buckets satisfy
this for any pow2 mesh) and fall back is the caller's choice —
``MiloPreprocessor`` runs non-divisible (tiny) classes on the single-device
path, which is trajectory-identical anyway.

The ``make_sharded_*`` factories are memoized on their (hashable) params:
two ``preprocess()`` calls with the same knobs receive the *same*
``SetFunction`` object, so ``_compiled``'s lru cache and the engines' jit
static-arg caches hit instead of recompiling every session.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.gram_free import (
    make_gram_free_disparity_min,
    make_gram_free_disparity_sum,
    make_gram_free_facility_location,
    make_gram_free_graph_cut,
)
from repro.core.greedy import (
    GreedyResult,
    LazyGreedyResult,
    _sge_bank,
    greedy,
    greedy_importance,
    lazy_greedy,
    refine,
    stochastic_candidate_count,
    stochastic_greedy,
)
from repro.core.submodular import LazyHooks, SetFunction, State
from repro.distributed import compression as comp_mod
from repro.distributed import multihost
from repro.distributed.compression import CompressionIntegrityError
from repro.distributed.sharding import SELECTION_AXIS as AXIS


# ---------------------------------------------------------------------------
# exact cross-shard primitives (no float reassociation)
# ---------------------------------------------------------------------------

def _my_offset(z_local: jax.Array, axis: str) -> jax.Array:
    return jax.lax.axis_index(axis) * z_local.shape[0]


def _gather_rows(z_local: jax.Array, idx: jax.Array, axis: str) -> jax.Array:
    """Replicated ``z[idx]`` from the row-sharded ``z``: the owning shard
    contributes the row, every other shard contributes exact zeros, so the
    ``psum`` is a bit-exact gather (one non-zero term per index)."""
    chunk = z_local.shape[0]
    off = _my_offset(z_local, axis)
    local = (idx >= off) & (idx < off + chunk)
    rows = jnp.take(z_local, jnp.clip(idx - off, 0, chunk - 1), axis=0)
    return jax.lax.psum(
        jnp.where(local[:, None], rows.astype(jnp.float32), 0.0), axis
    )


def _sim_col(z_local: jax.Array, j: jax.Array, axis: str) -> jax.Array:
    """Replicated rescaled-cosine column ``K[:, j]``: per-row dot products are
    computed on the owning shard (same d-axis reduction as the single-device
    matvec — bit-exact) and concatenated in shard order by ``all_gather``."""
    zj = _gather_rows(z_local, j[None], axis)[0]
    return jax.lax.all_gather(0.5 + 0.5 * (z_local @ zj), axis, tiled=True)


def _all_row_sumsq(z_local: jax.Array, axis: str) -> jax.Array:
    return jax.lax.all_gather(jnp.sum(z_local * z_local, axis=-1), axis,
                              tiled=True)


def _slice_mine(vec: jax.Array, z_local: jax.Array, axis: str) -> jax.Array:
    """This shard's chunk of a replicated per-row vector."""
    return jax.lax.dynamic_slice_in_dim(
        vec, _my_offset(z_local, axis), z_local.shape[0]
    )


def _compressed_psum(x: jax.Array, axis: str, *, rounds: int) -> jax.Array:
    """Error-feedback compressed cross-shard sum with integrity checksums.

    Each round every shard int8-quantizes its residual (round 0: its full
    partial), all-gathers the checksummed payloads, verifies every peer's
    checksum post-collective, and accumulates the decoded sum; the local
    quantization error feeds the next round.  ``rounds`` trades payload for
    fidelity — one round moves n bytes/shard instead of the exact psum's 4n,
    and the residual shrinks geometrically with each extra round.

    A checksum mismatch — a corrupted collective — NaN-poisons the entire
    output in-trace; the wrapper-level host check then raises
    ``CompressionIntegrityError`` instead of letting a silently-skewed gain
    pick subsets.  The escape hatch is not calling this at all
    (``compress=None``), which keeps the exact ``psum`` path bit-identical.
    """
    total = jnp.zeros_like(x, jnp.float32)
    resid = x.astype(jnp.float32)
    for _ in range(rounds):
        p = comp_mod.int8_compress_checked(resid)
        qs = jax.lax.all_gather(p.q, axis)            # (n_shards, n)
        scales = jax.lax.all_gather(p.scale, axis)    # (n_shards,)
        sums = jax.lax.all_gather(p.checksum, axis)   # (n_shards,)
        ok = jnp.all(jax.vmap(comp_mod.payload_checksum)(qs) == sums)
        decoded = jnp.sum(qs.astype(jnp.float32) * scales[:, None], axis=0)
        total = total + jnp.where(ok, decoded, jnp.nan)
        resid = resid - comp_mod.int8_decompress(
            comp_mod.Int8Compressed(p.q, p.scale))
    return total


def _raise_if_corrupt(fn: SetFunction, gains_arr: jax.Array) -> None:
    """Loud failure for the compressed path: a checksum mismatch inside the
    collective NaN-poisons the traced gains; surface it as an exception the
    moment the result reaches the host (the arrays are replicated outputs,
    so this reads no extra device memory)."""
    if "_c8" not in fn.name:
        return
    if np.isnan(np.asarray(gains_arr)).any():
        raise CompressionIntegrityError(
            f"{fn.name}: NaN in selection gains — a compressed cross-shard "
            "collective failed its payload checksum (corrupted transfer); "
            "rerun, or disable compression (compress=None) to use the "
            "exact psum path"
        )


def _place_global(mesh: Mesh, axis: str, z, valid, key=None):
    """Lay inputs out on the mesh when it spans processes.

    Single-process meshes take the unchanged direct-call path (byte-identical
    dispatch to the pre-multihost code); multi-process meshes need inputs
    committed to the global sharding before the jitted shard_map program can
    accept them — each host fills its addressable shards from its own full
    host copy, so placement moves no bytes between hosts.
    """
    if not multihost.mesh_spans_processes(mesh):
        return (z, valid) if key is None else (z, valid, key)
    zg = multihost.global_put(jnp.asarray(z), mesh, P(axis, None))
    vg = multihost.global_put(jnp.asarray(valid), mesh, P(None))
    if key is None:
        return zg, vg
    return zg, vg, multihost.global_put(jnp.asarray(key), mesh, P(None))


def _gathered_z_evaluate(base_evaluate):
    """Tests-only ``evaluate``: rebuild full z (all_gather) and delegate."""

    def evaluate(mask: jax.Array, z_local: jax.Array, *, _axis=AXIS) -> jax.Array:
        z = jax.lax.all_gather(z_local, _axis, tiled=True)
        return base_evaluate(mask, z)

    return evaluate


# ---------------------------------------------------------------------------
# sharded set functions (the engines' "K" argument is the per-device z shard)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_sharded_facility_location(
    *,
    n_shards: int,
    axis: str = AXIS,
    use_pallas: bool = False,
    interpret: bool = False,
    block_i: int = 512,
    block_j: int = 512,
    compress: str | None = None,
    compress_rounds: int = 2,
) -> SetFunction:
    """Facility location with the cover vector replicated and all gain
    reductions computed per shard through ``fl_gains_gram_free``; exposes
    ``lazy`` hooks so ``lazy_greedy`` composes with the mesh.

    ``compress="int8"`` routes the full-gains ring's O(n) cross-shard
    reduction through ``_compressed_psum`` — error-feedback int8 payloads
    with integrity checksums, ``compress_rounds`` controlling the
    payload/fidelity trade — for meshes whose shards sit across a slow
    inter-host link.  The exact one-owner gathers (``gains_at``, ``update``,
    lazy deltas) are never compressed: they are the bit-exactness-critical
    small payloads.  ``compress=None`` (default) is the escape hatch: the
    exact ``psum`` code path, bit-identical to every prior release."""
    from repro.kernels.fl_gains import ops as fl_ops

    base = make_gram_free_facility_location(
        use_pallas=use_pallas, interpret=interpret,
        block_i=block_i, block_j=block_j,
    )

    def _kernel(z_local, zc, c_loc):
        return fl_ops.fl_gains_gram_free(
            z_local, zc, c_loc, block_i=block_i, block_j=block_j,
            use_pallas=use_pallas, interpret=interpret,
        )

    def init(z_local: jax.Array) -> State:
        ssq = _all_row_sumsq(z_local, axis)
        return jnp.where(ssq > 0.0, 0.0, jnp.inf).astype(jnp.float32)

    def gains(c: State, z_local: jax.Array) -> jax.Array:
        # Ring schedule: candidate blocks visit every shard via ppermute, so
        # each shard accumulates its i-axis partial for ALL n candidates
        # while holding at most two (n/ndev, d) blocks; psum combines the
        # partials.  The t = 0 block is the shard's own z_local, so the
        # schedule needs exactly n_shards - 1 hops; unrolling over the
        # static shard count keeps that hop count a static property of the
        # program (one ppermute eqn per hop in the jaxpr) instead of hiding
        # an extra, discarded rotation inside a fori_loop.
        chunk = z_local.shape[0]
        me = jax.lax.axis_index(axis)
        c_loc = _slice_mine(c, z_local, axis)
        perm = [(i, (i - 1) % n_shards) for i in range(n_shards)]

        blk = z_local
        out = jnp.zeros((n_shards * chunk,), jnp.float32)
        for t in range(n_shards):
            if t:
                blk = jax.lax.ppermute(blk, axis, perm)
            out = jax.lax.dynamic_update_slice(
                out, _kernel(z_local, blk, c_loc),
                (((me + t) % n_shards) * chunk,),
            )
        if compress == "int8":
            return _compressed_psum(out, axis, rounds=compress_rounds)
        return jax.lax.psum(out, axis)

    def gains_at(c: State, z_local: jax.Array, cand: jax.Array) -> jax.Array:
        zc = _gather_rows(z_local, cand, axis)
        c_loc = _slice_mine(c, z_local, axis)
        return jax.lax.psum(_kernel(z_local, zc, c_loc), axis)

    def update(c: State, z_local: jax.Array, j: jax.Array) -> State:
        return jnp.maximum(c, _sim_col(z_local, j, axis))

    def delta_gains(z_local: jax.Array, rows: jax.Array, c_old: jax.Array,
                    c_new: jax.Array) -> jax.Array:
        # Ring-free candidate path: the touched rows (budget × d, small by
        # construction) are gathered exactly via the one-owner psum, each
        # shard corrects its OWN candidate block, and the ordered all_gather
        # concatenates — per-candidate reduction order matches the
        # single-device delta, so the correction itself is bit-exact.
        zr = _gather_rows(z_local, rows, axis)
        d_loc = fl_ops.fl_gains_gram_free_delta(
            zr, z_local, c_old, c_new, block_i=block_i, block_j=block_j,
            use_pallas=use_pallas, interpret=interpret,
        )
        return jax.lax.all_gather(d_loc, axis, tiled=True)

    name = "sharded_facility_location" + ("_pallas" if use_pallas else "")
    if compress == "int8":
        name += f"_c8r{compress_rounds}"
    elif compress is not None:
        raise ValueError(f"unknown compression scheme {compress!r}; "
                         "one of ('int8', None)")
    return SetFunction(name, init, gains, update,
                       _gathered_z_evaluate(base.evaluate), gains_at=gains_at,
                       lazy=LazyHooks(cover=lambda c: c,
                                      delta_gains=delta_gains))


@functools.lru_cache(maxsize=64)
def make_sharded_graph_cut(lam: float = 0.4, *, n_shards: int,
                           axis: str = AXIS) -> SetFunction:
    base = make_gram_free_graph_cut(lam)

    def init(z_local: jax.Array) -> State:
        ssq = _all_row_sumsq(z_local, axis)
        live = ssq > 0.0
        n_live = jnp.sum(live.astype(jnp.float32))
        # Σ_i z_i reduces over the sharded row axis; the psum reassociates the
        # float sum, so colsum (hence gains) can differ from the single-device
        # init by ~1 ulp — trajectories are unaffected on tested fixtures.
        zsum = jax.lax.psum(jnp.sum(z_local, axis=0), axis)
        colsum_loc = 0.5 * n_live + 0.5 * (z_local @ zsum)
        colsum = jax.lax.all_gather(colsum_loc, axis, tiled=True)
        return {
            "colsum": jnp.where(live, colsum, 0.0),
            "diag": jnp.where(live, 0.5 + 0.5 * ssq, 0.0),
            "cur": jnp.zeros((ssq.shape[0],), jnp.float32),
        }

    def update(state: State, z_local: jax.Array, j: jax.Array) -> State:
        return {
            "colsum": state["colsum"],
            "diag": state["diag"],
            "cur": state["cur"] + _sim_col(z_local, j, axis),
        }

    # gains/gains_at read replicated state only — reuse the gram-free closures
    return SetFunction("sharded_graph_cut", init, base.gains, update,
                       _gathered_z_evaluate(base.evaluate),
                       gains_at=base.gains_at)


@functools.lru_cache(maxsize=64)
def make_sharded_disparity_sum(*, n_shards: int, axis: str = AXIS) -> SetFunction:
    base = make_gram_free_disparity_sum()

    def init(z_local: jax.Array) -> State:
        return jnp.zeros((n_shards * z_local.shape[0],), jnp.float32)

    def update(cur: State, z_local: jax.Array, j: jax.Array) -> State:
        return cur + (1.0 - _sim_col(z_local, j, axis))

    return SetFunction("sharded_disparity_sum", init, base.gains, update,
                       _gathered_z_evaluate(base.evaluate),
                       gains_at=base.gains_at)


@functools.lru_cache(maxsize=64)
def make_sharded_disparity_min(*, n_shards: int, axis: str = AXIS) -> SetFunction:
    from repro.core.submodular import _DMIN_CAP

    base = make_gram_free_disparity_min()

    def init(z_local: jax.Array) -> State:
        n = n_shards * z_local.shape[0]
        return {
            "dmin": jnp.full((n,), _DMIN_CAP, jnp.float32),
            "cur": jnp.asarray(_DMIN_CAP, jnp.float32),
            "size": jnp.asarray(0, jnp.int32),
        }

    def update(state: State, z_local: jax.Array, j: jax.Array) -> State:
        dist_j = 1.0 - _sim_col(z_local, j, axis)
        new_cur = jnp.where(
            state["size"] >= 1,
            jnp.minimum(state["cur"], state["dmin"][j]),
            state["cur"],
        )
        return {
            "dmin": jnp.minimum(state["dmin"], dist_j),
            "cur": new_cur,
            "size": state["size"] + 1,
        }

    return SetFunction("sharded_disparity_min", init, base.gains, update,
                       _gathered_z_evaluate(base.evaluate),
                       gains_at=base.gains_at)


def make_sharded_gram_free(name: str, *, n_shards: int, axis: str = AXIS,
                           **kwargs) -> SetFunction:
    """Sharded counterpart of ``gram_free.get_gram_free`` (cosine only)."""
    factories = {
        "facility_location": make_sharded_facility_location,
        "graph_cut": make_sharded_graph_cut,
        "disparity_sum": make_sharded_disparity_sum,
        "disparity_min": make_sharded_disparity_min,
    }
    try:
        factory = factories[name]
    except KeyError:
        raise KeyError(
            f"no sharded gram-free variant of {name!r}; "
            f"available: {sorted(factories)}"
        ) from None
    return factory(n_shards=n_shards, axis=axis, **kwargs)


# ---------------------------------------------------------------------------
# engine wrappers: the unchanged greedy engines inside shard_map
# ---------------------------------------------------------------------------

def _check_shardable(z: jax.Array, mesh: Mesh, axis: str) -> int:
    ndev = mesh.shape[axis]
    n = z.shape[0]
    if n % ndev:
        raise ValueError(
            f"ground-set size {n} is not divisible by the {ndev}-device "
            f"{axis!r} mesh; pad the problem (bucketed preprocessing does) "
            "or run the single-device path"
        )
    return n


@functools.lru_cache(maxsize=128)
def _compiled(kind: str, fn: SetFunction, mesh: Mesh, axis: str, n: int,
              *extra):
    """One jitted shard_map program per (engine, set fn, mesh, shapes).

    ``check_rep=False``: every per-element carry is replicated by
    construction (identical replicated inputs, deterministic ops), but the
    rep checker cannot prove it through fori_loop + psum.
    """
    specs = dict(mesh=mesh, in_specs=(P(axis, None), P(None)),
                 out_specs=P(None), check_rep=False)

    if kind == "greedy":
        (k,) = extra

        def inner(zs, v):
            return greedy(fn, zs, k, valid=v, n=n)

    elif kind == "refine":
        k, lazy_budget, lazy_two_level = extra

        def inner(zs, v):
            return refine(fn, zs, k, valid=v, n=n, lazy_budget=lazy_budget,
                          two_level=lazy_two_level)

    elif kind == "lazy":
        k, budget, two_level = extra

        def inner(zs, v):
            return lazy_greedy(fn, zs, k, budget=budget, valid=v, n=n,
                               two_level=two_level)

    elif kind == "stochastic":
        k, s = extra

        def inner(zs, v, key):
            return stochastic_greedy(fn, zs, k, key, s=s,
                                                valid=v, n=n)

        specs["in_specs"] = (P(axis, None), P(None), P(None))
    elif kind == "bank":
        k, s, n_subsets = extra

        def inner(zs, v, key):
            return _sge_bank(fn, zs, k, key, s=s,
                                        n_subsets=n_subsets, valid=v, n=n)

        specs["in_specs"] = (P(axis, None), P(None), P(None))
    elif kind == "importance":
        lazy_budget, lazy_two_level = extra

        def inner(zs, v):
            return greedy_importance(fn, zs, valid=v, n=n,
                                     lazy_budget=lazy_budget,
                                     lazy_two_level=lazy_two_level)

    else:  # pragma: no cover
        raise ValueError(kind)
    return jax.jit(shard_map(inner, **specs))


def _valid_or_all(n: int, valid: jax.Array | None) -> jax.Array:
    # an all-true mask is bit-equivalent to valid=None in every engine
    # (_selected0 yields the same all-false selected mask) and keeps the
    # shard_map input pytree static
    return jnp.ones((n,), bool) if valid is None else valid


def sharded_greedy(
    fn: SetFunction, z: jax.Array, k: int, *, mesh: Mesh, axis: str = AXIS,
    valid: jax.Array | None = None,
) -> GreedyResult:
    """``greedy`` with z row-sharded over ``mesh`` (trajectory-identical)."""
    n = _check_shardable(z, mesh, axis)
    run = _compiled("greedy", fn, mesh, axis, n, k)
    z, v = _place_global(mesh, axis, z, _valid_or_all(n, valid))
    res = GreedyResult(*run(z, v))
    _raise_if_corrupt(fn, res.gains)
    return res


def sharded_lazy_greedy(
    fn: SetFunction, z: jax.Array, k: int, *, budget: int, mesh: Mesh,
    axis: str = AXIS, valid: jax.Array | None = None,
    two_level: bool = False,
) -> LazyGreedyResult:
    """``lazy_greedy`` with z row-sharded over ``mesh``.

    The cached gain vector is replicated, so the engine's argmax/touched-row
    logic runs unchanged; only the gain *evaluations* are sharded — full
    recomputes via the (n_shards - 1)-hop ring, delta corrections via the
    ring-free gathered-rows path.  ``rows_evaluated`` is the same traced
    counter the single-device engine returns (``budget`` on a lazy step,
    ``n`` on a fallback recompute), counting *ground rows contracted* — the
    per-shard split of each contraction does not change what was evaluated.

    Trajectories match the single-device ``lazy_greedy`` wherever argmax gaps
    exceed the ring psum's ~1 ulp reassociation noise — on the test fixtures
    that is every step (indices bit-identical, gains ≤ 1 ulp).

    ``two_level=True`` right-sizes each lazy gather to the smallest pow2
    level covering the touched rows (bit-identical to single-level; see
    ``greedy.lazy_greedy``) — here that shrinks the one-owner psum payload
    of the gathered touched-row block from ``budget × d`` to ``level × d``
    on calm steps."""
    n = _check_shardable(z, mesh, axis)
    run = _compiled("lazy", fn, mesh, axis, n, k, budget, two_level)
    z, v = _place_global(mesh, axis, z, _valid_or_all(n, valid))
    res = LazyGreedyResult(*run(z, v))
    _raise_if_corrupt(fn, res.gains)
    return res


def sharded_refine(
    fn: SetFunction, z: jax.Array, k: int, *, mesh: Mesh, axis: str = AXIS,
    valid: jax.Array | None = None, lazy_budget: int | None = None,
    lazy_two_level: bool = False,
) -> GreedyResult:
    """``greedy.refine`` (the hierarchical level-1 pass) over row-sharded z.

    Same lazy dispatch rule as the single-device entry point: routes through
    ``lazy_greedy`` when a budget is given and the set function has lazy
    hooks, plain ``greedy`` otherwise.  The union of level-0 winners is small
    relative to the ground set, but on pow2-padded unions that divide the
    mesh this keeps even the refine's O(union²·d) FL gains off a single
    device."""
    n = _check_shardable(z, mesh, axis)
    if not (lazy_budget is not None and fn.lazy is not None
            and 1 <= lazy_budget < n):
        lazy_budget = None
    run = _compiled("refine", fn, mesh, axis, n, k, lazy_budget,
                    lazy_two_level)
    z, v = _place_global(mesh, axis, z, _valid_or_all(n, valid))
    res = GreedyResult(*run(z, v))
    _raise_if_corrupt(fn, res.gains)
    return res


def sharded_stochastic_greedy(
    fn: SetFunction, z: jax.Array, k: int, key: jax.Array, *, s: int,
    mesh: Mesh, axis: str = AXIS, valid: jax.Array | None = None,
) -> GreedyResult:
    """``stochastic_greedy`` over row-sharded z.  The Gumbel draws use the
    replicated key and global n, so candidate sets (hence trajectories) are
    bit-identical to the single-device run."""
    n = _check_shardable(z, mesh, axis)
    run = _compiled("stochastic", fn, mesh, axis, n, k, s)
    z, v, key = _place_global(mesh, axis, z, _valid_or_all(n, valid), key)
    res = GreedyResult(*run(z, v, key))
    _raise_if_corrupt(fn, res.gains)
    return res


def sharded_sge(
    fn: SetFunction, z: jax.Array, k: int, key: jax.Array, *,
    n_subsets: int, eps: float = 0.01, s: int | None = None,
    mesh: Mesh, axis: str = AXIS, valid: jax.Array | None = None,
) -> jax.Array:
    """The full SGE bank (vmapped) over row-sharded z: one shard_map program
    whose collectives batch across the vmapped runs."""
    n = _check_shardable(z, mesh, axis)
    if s is None:
        s = stochastic_candidate_count(n, k, eps)
    run = _compiled("bank", fn, mesh, axis, n, k, s, n_subsets)
    z, v, key = _place_global(mesh, axis, z, _valid_or_all(n, valid), key)
    return run(z, v, key)


def sharded_greedy_importance(
    fn: SetFunction, z: jax.Array, *, mesh: Mesh, axis: str = AXIS,
    valid: jax.Array | None = None, lazy_budget: int | None = None,
    lazy_two_level: bool = False,
) -> jax.Array:
    """``greedy_importance`` over row-sharded z.

    ``lazy_budget`` threads straight through to the engine: when the set
    function provides lazy hooks (sharded facility location does) the full
    pass runs ``lazy_greedy`` — cached gains corrected over touched rows
    only — instead of n ring-gain evaluations; ignored otherwise, exactly as
    on the single-device path.  ``lazy_two_level`` right-sizes each lazy
    gather's psum payload (bit-identical; see ``sharded_lazy_greedy``)."""
    n = _check_shardable(z, mesh, axis)
    run = _compiled("importance", fn, mesh, axis, n, lazy_budget,
                    lazy_two_level)
    z, v = _place_global(mesh, axis, z, _valid_or_all(n, valid))
    out = run(z, v)
    _raise_if_corrupt(fn, out)
    return out
