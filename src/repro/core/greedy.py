"""Greedy submodular maximization engines (paper Alg. 2 & 3), jit-compiled.

Beyond-paper TPU adaptation: the reference implementation (submodlib) runs one
Python/C++ heap iteration per selected element on the host.  Here an *entire*
greedy run — all k steps — compiles to a single XLA program via
``lax.fori_loop``, and the full SGE bank (all ``n_subsets`` stochastic-greedy
runs) compiles to ONE program via ``vmap`` over the per-run keys.

Cost model per stochastic-greedy step: the candidate set has size
``s = (n/k)·ln(1/eps)`` and only those ``s`` gains are ever compared, so the
step evaluates them directly through ``SetFunction.gains_at`` — O(n·s) for
facility location, O(s) for graph-cut/disparity — instead of materializing
the O(n²) full gain vector and gathering.  The candidate draw uses Gumbel
top-k so no host round-trip or rejection loop is needed.

All engines accept an optional ``valid`` mask (shape ``(n,)`` bool): invalid
elements are treated as pre-selected and can never be chosen.  This is what
lets ``MiloPreprocessor`` bucket per-class problem sizes to powers of two
(exact masking, no recompile per distinct class size).

Engines:
  * ``greedy``            — lazy-free naive greedy (exact argmax each step).
  * ``stochastic_greedy`` — [Mirzasoleiman et al. '15]; candidate set of size
                            s = (n/k) * log(1/eps) per step (paper SGE inner).
  * ``sge``               — the full bank: vmapped by default, sequential for
                            A/B comparison.
  * ``greedy_importance`` — full greedy pass over the ground set recording the
                            marginal gain of every element at its inclusion
                            point (paper Alg. 3, feeds WRE).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.submodular import SetFunction, gains_at as _gains_at

_NEG = -1e30


class GreedyResult(NamedTuple):
    indices: jax.Array  # (k,) int32 selected order
    gains: jax.Array    # (k,) float32 marginal gain at inclusion


def _masked_argmax(gains: jax.Array, selected: jax.Array) -> jax.Array:
    return jnp.argmax(jnp.where(selected, _NEG, gains))


def _selected0(n: int, valid: jax.Array | None) -> jax.Array:
    """Initial selected mask: invalid (padding) elements start pre-selected so
    no engine can ever pick them — the exact-masking half of size bucketing."""
    if valid is None:
        return jnp.zeros((n,), bool)
    return ~valid


@functools.partial(jax.jit, static_argnames=("fn", "k"))
def greedy(
    fn: SetFunction, K: jax.Array, k: int, *, valid: jax.Array | None = None
) -> GreedyResult:
    """Exact naive greedy: argmax of the full gain vector each step."""
    n = K.shape[0]
    state0 = fn.init(K)

    def body(t, carry):
        state, selected, idxs, gs = carry
        gains = fn.gains(state, K)
        j = _masked_argmax(gains, selected)
        state = fn.update(state, K, j)
        return (
            state,
            selected.at[j].set(True),
            idxs.at[t].set(j.astype(jnp.int32)),
            gs.at[t].set(jnp.where(selected[j], _NEG, gains[j]).astype(jnp.float32)),
        )

    carry = (
        state0,
        _selected0(n, valid),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
    )
    _, _, idxs, gs = jax.lax.fori_loop(0, k, body, carry)
    return GreedyResult(idxs, gs)


def stochastic_candidate_count(n: int, k: int, eps: float) -> int:
    """s = ceil((n/k) * ln(1/eps)), clipped to [1, n]."""
    return max(1, min(n, math.ceil((n / max(k, 1)) * math.log(1.0 / eps))))


def _stochastic_greedy_body(fn: SetFunction, K: jax.Array, s: int, keys: jax.Array):
    """Shared per-step body for the single-run and vmapped engines."""
    n = K.shape[0]

    def body(t, carry):
        state, selected, idxs, gs = carry
        # Gumbel top-s over unselected == uniform sample w/o replacement.
        g = jax.random.gumbel(keys[t], (n,))
        logits = jnp.where(selected, _NEG, g)
        _, cand = jax.lax.top_k(logits, s)  # (s,) candidate indices
        # Candidate-gather gain evaluation: only the s sampled candidates are
        # ever compared, so only their gains are computed — O(n·s) per step
        # (FL) instead of the O(n²) full-vector path.
        cand_gains = _gains_at(fn, state, K, cand)
        # when s exceeds the unselected pool, top_k pads the candidate set
        # with already-selected elements — mask their gains so they can never
        # win the argmax (would duplicate an index in the subset)
        cand_gains = jnp.where(selected[cand], _NEG, cand_gains)
        best = cand[jnp.argmax(cand_gains)]
        state = fn.update(state, K, best)
        return (
            state,
            selected.at[best].set(True),
            idxs.at[t].set(best.astype(jnp.int32)),
            gs.at[t].set(jnp.max(cand_gains).astype(jnp.float32)),
        )

    return body


@functools.partial(jax.jit, static_argnames=("fn", "k", "s"))
def stochastic_greedy(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    s: int,
    valid: jax.Array | None = None,
) -> GreedyResult:
    """Stochastic greedy (paper Alg. 2 inner loop).

    Per step, a candidate set of size ``s`` is drawn uniformly from the
    unselected ground set via Gumbel top-k on masked uniform logits, then the
    best candidate by marginal gain (``gains_at`` on the s candidates only)
    is added.
    """
    n = K.shape[0]
    keys = jax.random.split(key, k)
    body = _stochastic_greedy_body(fn, K, s, keys)
    carry = (
        fn.init(K),
        _selected0(n, valid),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
    )
    _, _, idxs, gs = jax.lax.fori_loop(0, k, body, carry)
    return GreedyResult(idxs, gs)


@functools.partial(jax.jit, static_argnames=("fn", "k", "s", "n_subsets"))
def _sge_bank(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    s: int,
    n_subsets: int,
    valid: jax.Array | None = None,
) -> jax.Array:
    """All ``n_subsets`` stochastic-greedy runs as ONE XLA program.

    ``fn.init`` and the Gumbel key split match the sequential path exactly, so
    trajectories are identical under fixed keys; ``vmap`` shares ``K`` (and
    the init computation) across runs and batches only the per-run carries.
    """
    keys = jax.random.split(key, n_subsets)

    def one_run(kk: jax.Array) -> jax.Array:
        return stochastic_greedy(fn, K, k, kk, s=s, valid=valid).indices

    return jax.vmap(one_run)(keys)


def sge(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    n_subsets: int,
    eps: float = 0.01,
    vmapped: bool = True,
    valid: jax.Array | None = None,
) -> jax.Array:
    """Paper Alg. 2 (SGE): run stochastic greedy ``n_subsets`` times.

    Returns an ``(n_subsets, k)`` int32 array of selected indices.  Each run
    is an independent stochastic-greedy maximization; randomness of the
    candidate draws yields distinct near-optimal subsets.

    ``vmapped=True`` (default) executes the whole bank as one jitted XLA
    program; ``vmapped=False`` keeps the legacy one-dispatch-per-run loop
    (same trajectories — kept for tests and before/after benchmarks).
    """
    s = stochastic_candidate_count(K.shape[0], k, eps)
    if vmapped:
        return _sge_bank(fn, K, k, key, s=s, n_subsets=n_subsets, valid=valid)
    keys = jax.random.split(key, n_subsets)
    runs = [stochastic_greedy(fn, K, k, kk, s=s, valid=valid).indices for kk in keys]
    return jnp.stack(runs, axis=0)


@functools.partial(jax.jit, static_argnames=("fn",))
def greedy_importance(
    fn: SetFunction, K: jax.Array, *, valid: jax.Array | None = None
) -> jax.Array:
    """Paper Alg. 3: full greedy over the whole ground set.

    Returns ``g`` with ``g[e]`` = marginal gain of element ``e`` at the moment
    it was greedily included (its WRE importance score).

    With a ``valid`` mask the run still takes ``n`` (padded) steps; once the
    valid pool is exhausted the argmax degenerates to an arbitrary re-pick
    with sentinel gain ``_NEG``, so the scatter below takes a per-element max
    — any real inclusion gain beats the sentinel, and padded elements (never
    genuinely included) end up at 0.
    """
    n = K.shape[0]
    res = greedy(fn, K, n, valid=valid)
    g = jnp.full((n,), _NEG, jnp.float32)
    g = g.at[res.indices].max(res.gains)
    return jnp.where(g <= _NEG / 2, 0.0, g)
