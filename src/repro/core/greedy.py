"""Greedy submodular maximization engines (paper Alg. 2 & 3), jit-compiled.

Beyond-paper TPU adaptation: the reference implementation (submodlib) runs one
Python/C++ heap iteration per selected element on the host.  Here an *entire*
greedy run — all k steps, each with vectorized gain evaluation over every
candidate — compiles to a single XLA program via ``lax.fori_loop``.  The
stochastic-greedy candidate draw uses Gumbel top-k so no host round-trip or
rejection loop is needed.

Engines:
  * ``greedy``            — lazy-free naive greedy (exact argmax each step).
  * ``stochastic_greedy`` — [Mirzasoleiman et al. '15]; candidate set of size
                            s = (n/k) * log(1/eps) per step (paper SGE inner).
  * ``greedy_importance`` — full greedy pass over the ground set recording the
                            marginal gain of every element at its inclusion
                            point (paper Alg. 3, feeds WRE).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.submodular import SetFunction

_NEG = -1e30


class GreedyResult(NamedTuple):
    indices: jax.Array  # (k,) int32 selected order
    gains: jax.Array    # (k,) float32 marginal gain at inclusion


def _masked_argmax(gains: jax.Array, selected: jax.Array) -> jax.Array:
    return jnp.argmax(jnp.where(selected, _NEG, gains))


@functools.partial(jax.jit, static_argnames=("fn", "k"))
def greedy(fn: SetFunction, K: jax.Array, k: int) -> GreedyResult:
    """Exact naive greedy: argmax of the full gain vector each step."""
    n = K.shape[0]
    state0 = fn.init(K)

    def body(t, carry):
        state, selected, idxs, gs = carry
        gains = fn.gains(state, K)
        j = _masked_argmax(gains, selected)
        state = fn.update(state, K, j)
        return (
            state,
            selected.at[j].set(True),
            idxs.at[t].set(j.astype(jnp.int32)),
            gs.at[t].set(gains[j].astype(jnp.float32)),
        )

    carry = (
        state0,
        jnp.zeros((n,), bool),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
    )
    _, _, idxs, gs = jax.lax.fori_loop(0, k, body, carry)
    return GreedyResult(idxs, gs)


def stochastic_candidate_count(n: int, k: int, eps: float) -> int:
    """s = ceil((n/k) * ln(1/eps)), clipped to [1, n]."""
    return max(1, min(n, math.ceil((n / max(k, 1)) * math.log(1.0 / eps))))


@functools.partial(jax.jit, static_argnames=("fn", "k", "s"))
def stochastic_greedy(
    fn: SetFunction, K: jax.Array, k: int, key: jax.Array, *, s: int
) -> GreedyResult:
    """Stochastic greedy (paper Alg. 2 inner loop).

    Per step, a candidate set of size ``s`` is drawn uniformly from the
    unselected ground set via Gumbel top-k on masked uniform logits, then the
    best candidate by marginal gain is added.
    """
    n = K.shape[0]
    state0 = fn.init(K)
    keys = jax.random.split(key, k)

    def body(t, carry):
        state, selected, idxs, gs = carry
        # Gumbel top-s over unselected == uniform sample w/o replacement.
        g = jax.random.gumbel(keys[t], (n,))
        logits = jnp.where(selected, _NEG, g)
        _, cand = jax.lax.top_k(logits, s)  # (s,) candidate indices
        gains = fn.gains(state, K)          # vectorized over all n; gather s
        # when s exceeds the unselected pool, top_k pads the candidate set
        # with already-selected elements — mask their gains so they can never
        # win the argmax (would duplicate an index in the subset)
        cand_gains = jnp.where(selected[cand], _NEG, gains[cand])
        best = cand[jnp.argmax(cand_gains)]
        state = fn.update(state, K, best)
        return (
            state,
            selected.at[best].set(True),
            idxs.at[t].set(best.astype(jnp.int32)),
            gs.at[t].set(jnp.max(cand_gains).astype(jnp.float32)),
        )

    carry = (
        state0,
        jnp.zeros((n,), bool),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
    )
    _, _, idxs, gs = jax.lax.fori_loop(0, k, body, carry)
    return GreedyResult(idxs, gs)


@functools.partial(jax.jit, static_argnames=("fn",))
def greedy_importance(fn: SetFunction, K: jax.Array) -> jax.Array:
    """Paper Alg. 3: full greedy over the whole ground set.

    Returns ``g`` with ``g[e]`` = marginal gain of element ``e`` at the moment
    it was greedily included (its WRE importance score).
    """
    n = K.shape[0]
    res = greedy(fn, K, n)
    g = jnp.zeros((n,), jnp.float32)
    return g.at[res.indices].set(res.gains)


def sge(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    n_subsets: int,
    eps: float = 0.01,
) -> jax.Array:
    """Paper Alg. 2 (SGE): run stochastic greedy ``n_subsets`` times.

    Returns an ``(n_subsets, k)`` int32 array of selected indices.  Each run
    is an independent stochastic-greedy maximization; randomness of the
    candidate draws yields distinct near-optimal subsets.
    """
    s = stochastic_candidate_count(K.shape[0], k, eps)
    keys = jax.random.split(key, n_subsets)
    runs = [stochastic_greedy(fn, K, k, kk, s=s).indices for kk in keys]
    return jnp.stack(runs, axis=0)
