"""Greedy submodular maximization engines (paper Alg. 2 & 3), jit-compiled.

Beyond-paper TPU adaptation: the reference implementation (submodlib) runs one
Python/C++ heap iteration per selected element on the host.  Here an *entire*
greedy run — all k steps — compiles to a single XLA program via
``lax.fori_loop``, and the full SGE bank (all ``n_subsets`` stochastic-greedy
runs) compiles to ONE program via ``vmap`` over the per-run keys.

Cost model per stochastic-greedy step: the candidate set has size
``s = (n/k)·ln(1/eps)`` and only those ``s`` gains are ever compared, so the
step evaluates them directly through ``SetFunction.gains_at`` — O(n·s) for
facility location, O(s) for graph-cut/disparity — instead of materializing
the O(n²) full gain vector and gathering.  The candidate draw uses Gumbel
top-k so no host round-trip or rejection loop is needed.

All engines accept an optional ``valid`` mask (shape ``(n,)`` bool): invalid
elements are treated as pre-selected and can never be chosen.  This is what
lets ``MiloPreprocessor`` bucket per-class problem sizes to powers of two
(exact masking, no recompile per distinct class size).  With a ``valid``
mask, ``greedy`` guards its step body with ``lax.cond(t < n_valid, ...)``:
once the valid pool is exhausted the remaining (padded) steps skip the gain
evaluation entirely — bit-identical outputs (index 0, sentinel gain) at none
of the FL gain cost.

All engines also accept an explicit ``n`` (global ground-set size).  It
defaults to ``K.shape[0]`` and only needs to be passed when the engine runs
inside a ``shard_map`` where ``K`` is the *per-device shard* of the feature
matrix but masks/outputs must stay global-shaped (see ``core.sharded``).

Engines:
  * ``greedy``            — lazy-free naive greedy (exact argmax each step).
  * ``lazy_greedy``       — cached-gain greedy: only the ground rows whose
                            cover moved since the last pick are re-contracted
                            (``SetFunction.lazy`` hooks), with a full
                            recompute fallback past a touched-rows budget.
                            Composes with the multi-device ``sel`` mesh —
                            every carry it threads (cached gains, cover,
                            touched mask, rows counter) is replicated under
                            ``shard_map``, so ``core.sharded`` reuses this
                            engine unchanged via sharded lazy hooks.
  * ``stochastic_greedy`` — [Mirzasoleiman et al. '15]; candidate set of size
                            s = (n/k) * log(1/eps) per step (paper SGE inner).
  * ``sge``               — the full bank: vmapped by default, sequential for
                            A/B comparison.
  * ``greedy_importance`` — full greedy pass over the ground set recording the
                            marginal gain of every element at its inclusion
                            point (paper Alg. 3, feeds WRE).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.submodular import SetFunction, gains_at as _gains_at

_NEG = -1e30


class GreedyResult(NamedTuple):
    indices: jax.Array  # (k,) int32 selected order
    gains: jax.Array    # (k,) float32 marginal gain at inclusion


class LazyGreedyResult(NamedTuple):
    indices: jax.Array          # (k,) int32 selected order
    gains: jax.Array            # (k,) float32 marginal gain at inclusion
    rows_evaluated: jax.Array   # (k,) int32 ground rows contracted per step


def _masked_argmax(gains: jax.Array, selected: jax.Array) -> jax.Array:
    return jnp.argmax(jnp.where(selected, _NEG, gains))


def _selected0(n: int, valid: jax.Array | None) -> jax.Array:
    """Initial selected mask: invalid (padding) elements start pre-selected so
    no engine can ever pick them — the exact-masking half of size bucketing."""
    if valid is None:
        return jnp.zeros((n,), bool)
    return ~valid


def _guarded(step, n_valid, skip):
    """Wrap a greedy step body so post-exhaustion (padded) steps skip it.

    After ``n_valid`` picks every valid element is selected, so the unguarded
    body degenerates to argmax-of-all-sentinels: it returns index 0 with gain
    ``_NEG`` and a state update that nothing downstream reads.  The engine's
    ``skip(t, carry)`` branch writes exactly those outputs directly —
    bit-identical trajectories without paying the (for FL: O(n²)) gain
    evaluation on the ``n_pad - n_c`` wasted steps of a bucketed
    ``greedy_importance`` run.
    """
    if n_valid is None:
        return step

    def body(t, carry):
        return jax.lax.cond(
            t < n_valid, lambda c: step(t, c), lambda c: skip(t, c), carry
        )

    return body


@functools.partial(jax.jit, static_argnames=("fn", "k", "n"))
def greedy(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    *,
    valid: jax.Array | None = None,
    n: int | None = None,
) -> GreedyResult:
    """Exact naive greedy: argmax of the full gain vector each step."""
    n = K.shape[0] if n is None else n
    state0 = fn.init(K)
    n_valid = None if valid is None else jnp.sum(valid.astype(jnp.int32))

    def step(t, carry):
        state, selected, idxs, gs = carry
        gains = fn.gains(state, K)
        j = _masked_argmax(gains, selected)
        state = fn.update(state, K, j)
        return (
            state,
            selected.at[j].set(True),
            idxs.at[t].set(j.astype(jnp.int32)),
            gs.at[t].set(jnp.where(selected[j], _NEG, gains[j]).astype(jnp.float32)),
        )

    def skip(t, carry):
        state, selected, idxs, gs = carry
        return state, selected, idxs.at[t].set(0), gs.at[t].set(_NEG)

    carry = (
        state0,
        _selected0(n, valid),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
    )
    _, _, idxs, gs = jax.lax.fori_loop(0, k, _guarded(step, n_valid, skip), carry)
    return GreedyResult(idxs, gs)


def _gather_levels(budget: int) -> tuple[int, ...]:
    """Two-level gather sizes: powers of two up to ``budget`` (inclusive as
    the top level).  A lazy step gathers only the smallest level covering its
    touched-row count instead of the full budget-sized block."""
    levels = []
    size = 1
    while size < budget:
        levels.append(size)
        size <<= 1
    return tuple(levels) + (budget,)


@functools.partial(jax.jit,
                   static_argnames=("fn", "k", "budget", "n", "two_level",
                                    "verify_argmax", "verify_top"))
def lazy_greedy(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    *,
    budget: int,
    valid: jax.Array | None = None,
    n: int | None = None,
    two_level: bool = False,
    verify_argmax: bool = False,
    verify_top: int = 8,
) -> LazyGreedyResult:
    """Exact greedy with lazy gain reuse (``SetFunction.lazy`` hooks).

    The full gain vector is evaluated once at init and then *cached*: after
    adding ``j``, only ground rows whose running cover moved
    (``K_ij > c_i``) can change any element's gain, so the cached vector is
    corrected with a delta contraction over just those rows —
    O(touched · n · d) instead of the O(n² · d) full re-evaluation.  When the
    touched count exceeds ``budget`` (the stale-fraction threshold) the step
    falls back to a full recompute, which also resets the incremental
    float-rounding drift.

    ``rows_evaluated[t]`` counts the ground rows contracted at step ``t``
    (``budget`` on a lazy step, ``n`` on a fallback step) — the traced
    evaluation counter behind the benchmark's reduction claim; the full
    engine would charge ``n`` rows every step.

    The cached gains agree with freshly recomputed ones to float-rounding
    ulps (the delta itself is exact arithmetic; only the summation order
    differs), so the engine picks identically to ``greedy`` wherever the
    argmax gap exceeds ~1e-7 relative — on test fixtures that is the entire
    shortlist horizon (k up to ~n/4).  Deep into an exhaustive run
    (``greedy_importance``) many elements' gains agree to < 1 ulp and the
    drift resolves those near-ties differently: a different but equally
    valid greedy order whose gain *sequence* still matches to ulps.  Full
    recomputes (budget overflows) reset the drift.

    ``two_level=True`` right-sizes the lazy gather: instead of always
    contracting a ``budget``-sized touched-row block, the step switches to
    the smallest power-of-two level covering the rows that actually moved
    (``lax.switch`` over the ~log2(budget) pre-compiled level variants).
    Results are BIT-IDENTICAL to the single-level path — surplus slots carry
    an infinite cover, so their delta terms are exact zeros and shrinking
    the block only removes exact-zero additions — but the per-step payload
    (and, under ``shard_map``, the cross-device psum of the gathered block)
    drops to the touched count on calm steps.  ``rows_evaluated`` records
    the level actually gathered.

    ``verify_argmax=True`` adds CELF-style exact re-verification of every
    pick: the step shortlists the ``verify_top`` best *cached* gains,
    re-evaluates exactly those candidates through ``SetFunction.gains_at``,
    and picks the exact winner — ties resolved to the LOWEST ground index,
    matching ``jnp.argmax`` on the full vector, so the selected *indices*
    agree with ``greedy`` bit-for-bit even where cached-gain drift flips
    sub-ulp near-ties.  The recorded gain is the exact re-evaluated one
    (equal to greedy's to the reduction-order ulp: the candidate-gather and
    full-matrix reductions may round differently), and the shortlist's
    exact values are scattered back into the cache.  Sound whenever the true argmax sits within the shortlist —
    drift is ≤ a few ulps, so any ``verify_top`` > the near-tie multiplicity
    suffices.  Costs one O(n · verify_top) gather per step.
    """
    if fn.lazy is None:
        raise ValueError(
            f"set function {fn.name!r} provides no lazy hooks; use greedy()"
        )
    n = K.shape[0] if n is None else n
    if not 1 <= budget <= n:
        raise ValueError(
            f"budget={budget} out of range [1, {n}] (a budget of n already "
            "contracts every row — use greedy() instead)"
        )
    if verify_argmax and verify_top < 1:
        raise ValueError(f"verify_top={verify_top} must be >= 1")
    v_top = min(verify_top, n)
    lz = fn.lazy
    state0 = fn.init(K)
    g0 = fn.gains(state0, K)
    n_valid = None if valid is None else jnp.sum(valid.astype(jnp.int32))

    def step(t, carry):
        state, g, selected, idxs, gs, rows = carry
        if verify_argmax:
            # CELF re-verification: shortlist by cached gain, decide by
            # exact gain (selected shortlist fillers masked out), break
            # exact ties toward the lowest ground index — the same winner
            # greedy()'s full-vector argmax picks
            _, cand = jax.lax.top_k(jnp.where(selected, _NEG, g), v_top)
            exact = _gains_at(fn, state, K, cand)
            exact = jnp.where(selected[cand], _NEG, exact)
            best = jnp.max(exact)
            j = jnp.min(jnp.where(exact >= best, cand, n))
            gain_j = best.astype(jnp.float32)
            g = g.at[cand].set(exact.astype(g.dtype))
        else:
            j = _masked_argmax(g, selected)
            gain_j = jnp.where(selected[j], _NEG, g[j]).astype(jnp.float32)
        c_old = lz.cover(state)
        state = fn.update(state, K, j)
        c_new = lz.cover(state)
        touched = c_new > c_old
        m = jnp.sum(touched.astype(jnp.int32))

        def delta_at(size: int):
            """Lazy correction gathering a ``size``-row touched block.

            top-k on the 0/1 mask yields the touched row indices (all of
            them when m <= size); surplus slots land on untouched rows
            and are neutralized with an infinite cover (delta contributes
            exact zeros), so the correction is exact at every level.
            """

            def path(g):
                _, rows_idx = jax.lax.top_k(jnp.where(touched, 1.0, 0.0), size)
                real = touched[rows_idx]
                c_o = jnp.where(real, c_old[rows_idx], jnp.inf)
                c_n = jnp.where(real, c_new[rows_idx], jnp.inf)
                delta = lz.delta_gains(K, rows_idx, c_o, c_n)
                return g + delta, jnp.asarray(size, jnp.int32)

            return path

        def full_path(g):
            return fn.gains(state, K), jnp.asarray(n, jnp.int32)

        if two_level:
            levels = _gather_levels(budget)
            sizes = jnp.asarray(levels, jnp.int32)

            def lazy_path(g):
                lvl = jnp.searchsorted(sizes, m.astype(jnp.int32))
                return jax.lax.switch(
                    jnp.minimum(lvl, len(levels) - 1),
                    [delta_at(s) for s in levels], g,
                )

        else:
            lazy_path = delta_at(budget)

        g, used = jax.lax.cond(m <= budget, lazy_path, full_path, g)
        return (
            state,
            g,
            selected.at[j].set(True),
            idxs.at[t].set(j.astype(jnp.int32)),
            gs.at[t].set(gain_j),
            rows.at[t].set(used),
        )

    def skip(t, carry):
        state, g, selected, idxs, gs, rows = carry
        return state, g, selected, idxs.at[t].set(0), gs.at[t].set(_NEG), rows

    carry = (
        state0,
        g0,
        _selected0(n, valid),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
        jnp.zeros((k,), jnp.int32),
    )
    _, _, _, idxs, gs, rows = jax.lax.fori_loop(
        0, k, _guarded(step, n_valid, skip), carry
    )
    return LazyGreedyResult(idxs, gs, rows)


def stochastic_candidate_count(n: int, k: int, eps: float) -> int:
    """s = ceil((n/k) * ln(1/eps)), clipped to [1, n]."""
    return max(1, min(n, math.ceil((n / max(k, 1)) * math.log(1.0 / eps))))


def _stochastic_greedy_body(fn: SetFunction, K: jax.Array, s: int, keys: jax.Array,
                            n: int):
    """Shared per-step body for the single-run and vmapped engines."""

    def body(t, carry):
        state, selected, idxs, gs = carry
        # Gumbel top-s over unselected == uniform sample w/o replacement.
        g = jax.random.gumbel(keys[t], (n,))
        logits = jnp.where(selected, _NEG, g)
        _, cand = jax.lax.top_k(logits, s)  # (s,) candidate indices
        # Candidate-gather gain evaluation: only the s sampled candidates are
        # ever compared, so only their gains are computed — O(n·s) per step
        # (FL) instead of the O(n²) full-vector path.
        cand_gains = _gains_at(fn, state, K, cand)
        # when s exceeds the unselected pool, top_k pads the candidate set
        # with already-selected elements — mask their gains so they can never
        # win the argmax (would duplicate an index in the subset)
        cand_gains = jnp.where(selected[cand], _NEG, cand_gains)
        best = cand[jnp.argmax(cand_gains)]
        state = fn.update(state, K, best)
        return (
            state,
            selected.at[best].set(True),
            idxs.at[t].set(best.astype(jnp.int32)),
            gs.at[t].set(jnp.max(cand_gains).astype(jnp.float32)),
        )

    return body


@functools.partial(jax.jit, static_argnames=("fn", "k", "s", "n"))
def stochastic_greedy(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    s: int,
    valid: jax.Array | None = None,
    n: int | None = None,
) -> GreedyResult:
    """Stochastic greedy (paper Alg. 2 inner loop).

    Per step, a candidate set of size ``s`` is drawn uniformly from the
    unselected ground set via Gumbel top-k on masked uniform logits, then the
    best candidate by marginal gain (``gains_at`` on the s candidates only)
    is added.
    """
    n = K.shape[0] if n is None else n
    keys = jax.random.split(key, k)
    body = _stochastic_greedy_body(fn, K, s, keys, n)
    carry = (
        fn.init(K),
        _selected0(n, valid),
        jnp.zeros((k,), jnp.int32),
        jnp.zeros((k,), jnp.float32),
    )
    _, _, idxs, gs = jax.lax.fori_loop(0, k, body, carry)
    return GreedyResult(idxs, gs)


@functools.partial(jax.jit, static_argnames=("fn", "k", "s", "n_subsets", "n"))
def _sge_bank(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    s: int,
    n_subsets: int,
    valid: jax.Array | None = None,
    n: int | None = None,
) -> jax.Array:
    """All ``n_subsets`` stochastic-greedy runs as ONE XLA program.

    ``fn.init`` and the Gumbel key split match the sequential path exactly, so
    trajectories are identical under fixed keys; ``vmap`` shares ``K`` (and
    the init computation) across runs and batches only the per-run carries.
    """
    keys = jax.random.split(key, n_subsets)

    def one_run(kk: jax.Array) -> jax.Array:
        return stochastic_greedy(fn, K, k, kk, s=s, valid=valid, n=n).indices

    return jax.vmap(one_run)(keys)


def sge(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    key: jax.Array,
    *,
    n_subsets: int,
    eps: float = 0.01,
    vmapped: bool = True,
    valid: jax.Array | None = None,
    s: int | None = None,
    n: int | None = None,
) -> jax.Array:
    """Paper Alg. 2 (SGE): run stochastic greedy ``n_subsets`` times.

    Returns an ``(n_subsets, k)`` int32 array of selected indices.  Each run
    is an independent stochastic-greedy maximization; randomness of the
    candidate draws yields distinct near-optimal subsets.

    ``vmapped=True`` (default) executes the whole bank as one jitted XLA
    program; ``vmapped=False`` keeps the legacy one-dispatch-per-run loop
    (same trajectories — kept for tests and before/after benchmarks).

    ``s`` overrides the per-step candidate count.  By default it is derived
    from the *physical* problem size ``K.shape[0]`` — on a bucketed (padded)
    problem that is the padded size; pass the count computed from the valid
    ground-set size to keep the draw geometry of the unpadded problem
    (``MiloPreprocessor(exact_sge_candidates=True)``).
    """
    n_ = K.shape[0] if n is None else n
    if s is None:
        s = stochastic_candidate_count(n_, k, eps)
    if vmapped:
        return _sge_bank(fn, K, k, key, s=s, n_subsets=n_subsets, valid=valid, n=n)
    keys = jax.random.split(key, n_subsets)
    runs = [
        stochastic_greedy(fn, K, k, kk, s=s, valid=valid, n=n).indices
        for kk in keys
    ]
    return jnp.stack(runs, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("fn", "n", "lazy_budget", "lazy_two_level",
                                    "lazy_verify"))
def greedy_importance(
    fn: SetFunction,
    K: jax.Array,
    *,
    valid: jax.Array | None = None,
    n: int | None = None,
    lazy_budget: int | None = None,
    lazy_two_level: bool = False,
    lazy_verify: bool = False,
) -> jax.Array:
    """Paper Alg. 3: full greedy over the whole ground set.

    Returns ``g`` with ``g[e]`` = marginal gain of element ``e`` at the moment
    it was greedily included (its WRE importance score).

    With a ``valid`` mask the run still takes ``n`` (padded) steps; the
    post-exhaustion steps are skipped by the ``lax.cond`` guard and emit the
    sentinel gain ``_NEG``, so the scatter below takes a per-element max —
    any real inclusion gain beats the sentinel, and padded elements (never
    genuinely included) end up at 0.

    ``lazy_budget`` routes the pass through ``lazy_greedy`` when the set
    function provides lazy hooks (facility location does); ignored otherwise.
    ``lazy_two_level`` right-sizes each lazy gather to the smallest pow2
    level covering the touched rows (bit-identical; see ``lazy_greedy``).
    ``lazy_verify`` turns on CELF exact argmax re-verification, pinning the
    lazy pass to ``greedy``'s trajectory through sub-ulp near-ties.
    """
    n_ = K.shape[0] if n is None else n
    if lazy_budget is not None and fn.lazy is not None:
        res = lazy_greedy(fn, K, n_, budget=lazy_budget, valid=valid, n=n_,
                          two_level=lazy_two_level, verify_argmax=lazy_verify)
    else:
        res = greedy(fn, K, n_, valid=valid, n=n_)
    g = jnp.full((n_,), _NEG, jnp.float32)
    g = g.at[res.indices].max(res.gains)
    return jnp.where(g <= _NEG / 2, 0.0, g)


def refine(
    fn: SetFunction,
    K: jax.Array,
    k: int,
    *,
    valid: jax.Array | None = None,
    n: int | None = None,
    lazy_budget: int | None = None,
    two_level: bool = False,
    verify_argmax: bool = False,
) -> GreedyResult:
    """Level-1 refine: exact greedy over a union of level-0 winners.

    The entry point the hierarchical (partition-then-refine) pipeline calls
    after merging per-partition selections: ``K`` holds only the union rows
    (typically ``refine_factor * k`` of them), so an exact pass is cheap even
    when the original ground set was not.  Routes through ``lazy_greedy``
    when a budget is given and the set function has lazy hooks — the same
    dispatch rule ``greedy_importance`` uses — and degrades to plain
    ``greedy`` otherwise, so disparity/graph-cut refines work too.
    """
    n_ = K.shape[0] if n is None else n
    if (lazy_budget is not None and fn.lazy is not None
            and 1 <= lazy_budget < n_):
        res = lazy_greedy(fn, K, k, budget=lazy_budget, valid=valid, n=n_,
                          two_level=two_level, verify_argmax=verify_argmax)
        return GreedyResult(res.indices, res.gains)
    return greedy(fn, K, k, valid=valid, n=n_)
