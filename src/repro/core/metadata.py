"""Persisted MILO metadata (paper Alg. 1 ``storemetadata``/``loadmetadata``).

The whole point of model-agnostic selection is that this artifact is computed
once per (dataset, subset-size) and shared across every downstream model and
tuning trial.  Stored as a single ``.npz`` whose ``header`` field is a JSON
document carrying a format version and a content hash of the preprocessing
config, so a consumer can verify it is loading the artifact it expects
(``load(..., expected_config=...)`` / ``expected_hash=...``) before training
a second model from it at zero selection cost.  Writes are atomic (temp file
+ rename) so a crashed preprocessing job can never leave a half-written
artifact behind.  Version-1 artifacts (bare ``config`` field, no header) are
still readable.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any

import numpy as np

ARTIFACT_FORMAT = "milo-metadata"
ARTIFACT_VERSION = 2


class MetadataMismatchError(ValueError):
    """Loaded artifact does not match the expected preprocessing config."""


def config_hash(config: dict[str, Any]) -> str:
    """Stable short hash of a preprocessing config (canonical-JSON sha256)."""
    blob = json.dumps(config, sort_keys=True, separators=(",", ":"), default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _json_to_npz_field(obj: Any) -> np.ndarray:
    return np.frombuffer(json.dumps(obj).encode(), dtype=np.uint8)


def _npz_field_to_json(arr: np.ndarray) -> Any:
    return json.loads(bytes(arr.tobytes()).decode())


@dataclasses.dataclass
class MiloMetadata:
    """Pre-processing output for one (dataset, k) pair."""

    sge_subsets: np.ndarray      # (n_subsets, k) int64 global indices
    wre_probs: np.ndarray        # (m,) float32, sums to 1
    wre_importance: np.ndarray   # (m,) float32 raw greedy gains
    class_labels: np.ndarray     # (m,) int64 (zeros if unlabeled)
    class_budgets: np.ndarray    # (c,) int64 per-class budget (== [k] if global)
    config: dict[str, Any]       # provenance: set fns, eps, fraction, encoder id

    @property
    def k(self) -> int:
        return int(self.sge_subsets.shape[1])

    @property
    def m(self) -> int:
        return int(self.wre_probs.shape[0])

    def config_hash(self) -> str:
        return config_hash(self.config)

    def header(self) -> dict[str, Any]:
        """The JSON header persisted alongside the arrays."""
        return {
            "format": ARTIFACT_FORMAT,
            "version": ARTIFACT_VERSION,
            "config": self.config,
            "config_hash": self.config_hash(),
            "k": self.k,
            "m": self.m,
            "n_sge_subsets": int(self.sge_subsets.shape[0]),
        }

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(
                    f,
                    sge_subsets=self.sge_subsets,
                    wre_probs=self.wre_probs,
                    wre_importance=self.wre_importance,
                    class_labels=self.class_labels,
                    class_budgets=self.class_budgets,
                    header=_json_to_npz_field(self.header()),
                )
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @classmethod
    def load(
        cls,
        path: str,
        *,
        expected_config: dict[str, Any] | None = None,
        expected_hash: str | None = None,
    ) -> "MiloMetadata":
        """Load an artifact, optionally verifying its preprocessing config.

        ``expected_config`` uses partial-dict semantics: every (key, value)
        pair given must match the stored config.  ``expected_hash`` must equal
        the stored config's hash exactly.  A mismatch raises
        ``MetadataMismatchError`` — the guard that stops a training run from
        silently consuming subsets produced under different settings.
        """
        with np.load(path) as z:
            if "header" in z:
                hdr = _npz_field_to_json(z["header"])
                if hdr.get("format") != ARTIFACT_FORMAT:
                    raise MetadataMismatchError(
                        f"{path}: not a {ARTIFACT_FORMAT} artifact"
                    )
                if int(hdr.get("version", 0)) > ARTIFACT_VERSION:
                    raise MetadataMismatchError(
                        f"{path}: artifact version {hdr['version']} is newer "
                        f"than supported version {ARTIFACT_VERSION}"
                    )
                cfg = hdr["config"]
                stored_hash = hdr.get("config_hash")
                if stored_hash and stored_hash != config_hash(cfg):
                    raise MetadataMismatchError(
                        f"{path}: header config_hash {stored_hash} does not match "
                        "its config — artifact corrupted or tampered"
                    )
            else:  # version-1 artifact: bare config field, no header
                cfg = _npz_field_to_json(z["config"])
            h = config_hash(cfg)
            if expected_hash is not None and expected_hash != h:
                raise MetadataMismatchError(
                    f"{path}: config hash {h} != expected {expected_hash}"
                )
            if expected_config is not None:
                bad = {
                    key: (cfg.get(key), val)
                    for key, val in expected_config.items()
                    if cfg.get(key) != val
                }
                if bad:
                    raise MetadataMismatchError(
                        f"{path}: config mismatch on {bad} (stored, expected)"
                    )
            return cls(
                sge_subsets=z["sge_subsets"],
                wre_probs=z["wre_probs"],
                wre_importance=z["wre_importance"],
                class_labels=z["class_labels"],
                class_budgets=z["class_budgets"],
                config=cfg,
            )


def is_preprocessed(path: str) -> bool:
    return os.path.exists(path)
