"""Persisted MILO metadata (paper Alg. 1 ``storemetadata``/``loadmetadata``).

The whole point of model-agnostic selection is that this artifact is computed
once per (dataset, subset-size) and shared across every downstream model and
tuning trial.  Stored as a single ``.npz`` with a JSON config sidecar field;
writes are atomic (temp file + rename) so a crashed preprocessing job can
never leave a half-written artifact behind.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any

import numpy as np


@dataclasses.dataclass
class MiloMetadata:
    """Pre-processing output for one (dataset, k) pair."""

    sge_subsets: np.ndarray      # (n_subsets, k) int64 global indices
    wre_probs: np.ndarray        # (m,) float32, sums to 1
    wre_importance: np.ndarray   # (m,) float32 raw greedy gains
    class_labels: np.ndarray     # (m,) int64 (zeros if unlabeled)
    class_budgets: np.ndarray    # (c,) int64 per-class budget (== [k] if global)
    config: dict[str, Any]       # provenance: set fns, eps, fraction, encoder id

    @property
    def k(self) -> int:
        return int(self.sge_subsets.shape[1])

    @property
    def m(self) -> int:
        return int(self.wre_probs.shape[0])

    def save(self, path: str) -> None:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(
                    f,
                    sge_subsets=self.sge_subsets,
                    wre_probs=self.wre_probs,
                    wre_importance=self.wre_importance,
                    class_labels=self.class_labels,
                    class_budgets=self.class_budgets,
                    config=np.frombuffer(json.dumps(self.config).encode(), dtype=np.uint8),
                )
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @classmethod
    def load(cls, path: str) -> "MiloMetadata":
        with np.load(path) as z:
            cfg = json.loads(bytes(z["config"].tobytes()).decode())
            return cls(
                sge_subsets=z["sge_subsets"],
                wre_probs=z["wre_probs"],
                wre_importance=z["wre_importance"],
                class_labels=z["class_labels"],
                class_budgets=z["class_budgets"],
                config=cfg,
            )


def is_preprocessed(path: str) -> bool:
    return os.path.exists(path)
