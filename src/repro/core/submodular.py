"""Set functions from the paper (App. D), in incremental-gain form.

Each set function is expressed as pure functions over a fixed similarity
matrix ``K`` (shape ``(n, n)``, values in [0, 1]):

    init(K)                  -> state                   (pytree of arrays)
    gains(state, K)          -> (n,) marginal gains f(S u j) - f(S) for every j
    gains_at(state, K, cand) -> (s,) marginal gains for candidate indices only
    update(state, K, j)      -> state after adding j to S

This formulation turns greedy maximization into a jit-compiled
``lax.fori_loop`` with *vectorized* gain evaluation — the TPU-native
replacement for submodlib's per-element CPU heaps (see DESIGN.md §2).

``gains_at`` is the stochastic-greedy hot path: a step that samples ``s``
candidates only ever needs those ``s`` gains, so evaluating them directly
(a column gather for facility location, a state gather for the others) is
O(n·s) or O(s) instead of the O(n²) full-vector evaluation.  It must satisfy
``gains_at(state, K, cand) == gains(state, K)[cand]`` elementwise; every
implementation below does so bit-exactly.

Functions:
  * facility_location  (representation, submodular monotone)
  * graph_cut          (representation, submodular monotone for lam <= 0.5)
  * disparity_sum      (diversity, non-submodular; greedy gives 1/4 approx)
  * disparity_min      (diversity, non-submodular; greedy gives 1/2 approx)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

State = Any

# Large-but-finite stand-in for +inf so disparity-min stays NaN-free.
_DMIN_CAP = 2.0


class LazyHooks(NamedTuple):
    """Capabilities the lazy-gain greedy engine needs (``greedy.lazy_greedy``).

    A set function whose full gain evaluation reduces over the ground-set
    axis (facility location) can expose these to let the engine *cache* the
    gain vector and correct it incrementally: after adding ``j``, only rows
    whose cover moved (``K_ij > c_i``) change any element's gain.

    ``cover(state) -> (n,)``: the running per-row cover vector ``c``.
    ``delta_gains(K, rows, c_old_rows, c_new_rows) -> (n,)``: the gain
    correction summed over just ``rows`` — for each candidate ``e``,
    ``sum_i relu(K_ie - c_new_i) - relu(K_ie - c_old_i)`` over the given
    rows.  Rows with an infinite cover in BOTH vectors contribute exact
    zeros, which is how the engine neutralizes budget-padding slots.
    """

    cover: Callable[[State], jax.Array]
    delta_gains: Callable[[jax.Array, jax.Array, jax.Array, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class SetFunction:
    """Incremental set-function interface (see module docstring)."""

    name: str
    init: Callable[[jax.Array], State]
    gains: Callable[[State, jax.Array], jax.Array]
    update: Callable[[State, jax.Array, jax.Array], State]
    # Evaluate f(S) from scratch for a boolean mask — used by tests/property
    # checks, not by the greedy loop.
    evaluate: Callable[[jax.Array, jax.Array], jax.Array]
    # Candidate-gather gains (stochastic-greedy hot path).  None falls back
    # to gathering from the full gains vector — correct but O(n²) for
    # facility location, so every shipped set function provides one.
    gains_at: Callable[[State, jax.Array, jax.Array], jax.Array] | None = None
    # Lazy-gain hooks (exact-greedy hot path).  None means the function's
    # gains are cheap state lookups (graph-cut, disparity) or it simply
    # opts out; the engines fall back to per-step full evaluation.
    lazy: LazyHooks | None = None


def gains_at(fn: SetFunction, state: State, K: jax.Array, cand: jax.Array) -> jax.Array:
    """``fn.gains(state, K)[cand]`` without the full evaluation when possible."""
    if fn.gains_at is not None:
        return fn.gains_at(state, K, cand)
    return fn.gains(state, K)[cand]


# ---------------------------------------------------------------------------
# Facility location:  f(S) = sum_i max_{j in S} K_ij
# state: c[i] = max_{j in S} K_ij  (0 for empty S since K >= 0)
# gain(j) = sum_i relu(K_ij - c_i)
# ---------------------------------------------------------------------------

def _fl_init(K: jax.Array) -> State:
    return jnp.zeros((K.shape[0],), K.dtype)


def _fl_gains(c: State, K: jax.Array) -> jax.Array:
    return jnp.sum(jax.nn.relu(K - c[:, None]), axis=0)


def _fl_gains_at(c: State, K: jax.Array, cand: jax.Array) -> jax.Array:
    # Column gather: O(n·s) work instead of O(n²).  Same reduction over the
    # same column values as _fl_gains, so the result is bit-exact.
    return jnp.sum(jax.nn.relu(K[:, cand] - c[:, None]), axis=0)


def _fl_update(c: State, K: jax.Array, j: jax.Array) -> State:
    return jnp.maximum(c, K[:, j])


def _fl_eval(mask: jax.Array, K: jax.Array) -> jax.Array:
    sel = jnp.where(mask[None, :], K, -jnp.inf)
    best = jnp.max(sel, axis=1)
    return jnp.sum(jnp.where(jnp.any(mask), best, 0.0))


def _fl_delta_gains(
    K: jax.Array, rows: jax.Array, c_old: jax.Array, c_new: jax.Array
) -> jax.Array:
    # Row gather: only the (b, n) block of rows whose cover moved is read.
    Kb = K[rows, :].astype(jnp.float32)
    return jnp.sum(
        jax.nn.relu(Kb - c_new[:, None]) - jax.nn.relu(Kb - c_old[:, None]),
        axis=0,
    )


_FL_LAZY = LazyHooks(cover=lambda c: c, delta_gains=_fl_delta_gains)


facility_location = SetFunction(
    name="facility_location",
    init=_fl_init,
    gains=_fl_gains,
    update=_fl_update,
    evaluate=_fl_eval,
    gains_at=_fl_gains_at,
    lazy=_FL_LAZY,
)


# ---------------------------------------------------------------------------
# Graph cut: f(S) = sum_{i in D} sum_{j in S} K_ij - lam * sum_{i,j in S} K_ij
# state: (colsum (static), cur[j] = sum_{i in S} K_ij)
# gain(j) = colsum_j - lam * (2 cur_j + K_jj)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_graph_cut(lam: float = 0.4) -> SetFunction:
    def init(K: jax.Array) -> State:
        return {"colsum": jnp.sum(K, axis=0), "cur": jnp.zeros((K.shape[0],), K.dtype)}

    def gains(state: State, K: jax.Array) -> jax.Array:
        return state["colsum"] - lam * (2.0 * state["cur"] + jnp.diagonal(K))

    def gains_at(state: State, K: jax.Array, cand: jax.Array) -> jax.Array:
        # K[cand, cand] is the pointwise diagonal gather — O(s), not O(n).
        return state["colsum"][cand] - lam * (2.0 * state["cur"][cand] + K[cand, cand])

    def update(state: State, K: jax.Array, j: jax.Array) -> State:
        return {"colsum": state["colsum"], "cur": state["cur"] + K[:, j]}

    def evaluate(mask: jax.Array, K: jax.Array) -> jax.Array:
        m = mask.astype(K.dtype)
        return jnp.sum(K @ m) - lam * (m @ K @ m)

    return SetFunction("graph_cut", init, gains, update, evaluate, gains_at=gains_at)


graph_cut = make_graph_cut(0.4)


# ---------------------------------------------------------------------------
# Disparity-sum: f(S) = sum_{i,j in S} (1 - K_ij)
# state: cur[j] = sum_{i in S} (1 - K_ij);  gain(j) = 2 * cur_j  (diag is 0)
# ---------------------------------------------------------------------------

def _ds_init(K: jax.Array) -> State:
    return jnp.zeros((K.shape[0],), K.dtype)


def _ds_gains(cur: State, K: jax.Array) -> jax.Array:
    return 2.0 * cur


def _ds_gains_at(cur: State, K: jax.Array, cand: jax.Array) -> jax.Array:
    return 2.0 * cur[cand]


def _ds_update(cur: State, K: jax.Array, j: jax.Array) -> State:
    return cur + (1.0 - K[:, j])


def _ds_eval(mask: jax.Array, K: jax.Array) -> jax.Array:
    m = mask.astype(K.dtype)
    return m @ (1.0 - K) @ m - jnp.sum(m * (1.0 - jnp.diagonal(K)))


disparity_sum = SetFunction(
    "disparity_sum", _ds_init, _ds_gains, _ds_update, _ds_eval, gains_at=_ds_gains_at
)


# ---------------------------------------------------------------------------
# Disparity-min: f(S) = min_{i != j in S} (1 - K_ij)
# state: (dmin[j] = min_{i in S} (1 - K_ij), cur = f(S), size)
# Greedy argmax on gains == farthest-point traversal.
# ---------------------------------------------------------------------------

def _dm_init(K: jax.Array) -> State:
    n = K.shape[0]
    return {
        "dmin": jnp.full((n,), _DMIN_CAP, K.dtype),
        "cur": jnp.asarray(_DMIN_CAP, K.dtype),
        "size": jnp.asarray(0, jnp.int32),
    }


def _dm_gains(state: State, K: jax.Array) -> jax.Array:
    new_f = jnp.minimum(state["cur"], state["dmin"])
    return new_f - state["cur"]


def _dm_gains_at(state: State, K: jax.Array, cand: jax.Array) -> jax.Array:
    return jnp.minimum(state["cur"], state["dmin"][cand]) - state["cur"]


def _dm_update(state: State, K: jax.Array, j: jax.Array) -> State:
    dist_j = 1.0 - K[:, j]
    new_cur = jnp.where(state["size"] >= 1, jnp.minimum(state["cur"], state["dmin"][j]), state["cur"])
    dmin = jnp.minimum(state["dmin"], dist_j)
    return {"dmin": dmin, "cur": new_cur, "size": state["size"] + 1}


def _dm_eval(mask: jax.Array, K: jax.Array) -> jax.Array:
    n = K.shape[0]
    d = 1.0 - K
    pair = mask[:, None] & mask[None, :] & ~jnp.eye(n, dtype=bool)
    return jnp.min(jnp.where(pair, d, _DMIN_CAP))


disparity_min = SetFunction(
    "disparity_min", _dm_init, _dm_gains, _dm_update, _dm_eval, gains_at=_dm_gains_at
)


@functools.lru_cache(maxsize=64)
def make_facility_location_pallas(*, interpret: bool = False,
                                  block_i: int = 512, block_j: int = 512) -> SetFunction:
    """Facility location with the Pallas ``fl_gains`` kernel as the gain
    engine (the O(n²)-per-step hot loop of greedy selection; DESIGN.md §6).

    TPU deployment path; ``interpret=True`` validates on CPU (slow — tests
    use small n).  Semantics identical to ``facility_location``
    (tests/test_kernels.py proves greedy-trajectory equality).
    """
    from repro.kernels.fl_gains import ops as fl_ops

    def gains(c: State, K: jax.Array) -> jax.Array:
        return fl_ops.fl_gains(K, c, block_i=block_i, block_j=block_j,
                               interpret=interpret)

    def gains_at(c: State, K: jax.Array, cand: jax.Array) -> jax.Array:
        # gather the s candidate columns, then run the kernel on (n, s)
        return fl_ops.fl_gains(K[:, cand], c, block_i=block_i, block_j=block_j,
                               interpret=interpret)

    return SetFunction("facility_location_pallas", _fl_init, gains, _fl_update,
                       _fl_eval, gains_at=gains_at, lazy=_FL_LAZY)


REGISTRY = {
    "facility_location": facility_location,
    "graph_cut": graph_cut,
    "disparity_sum": disparity_sum,
    "disparity_min": disparity_min,
}


def get(name: str, **kwargs) -> SetFunction:
    if name == "graph_cut" and kwargs:
        return make_graph_cut(**kwargs)
    return REGISTRY[name]
