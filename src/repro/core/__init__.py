"""MILO core: model-agnostic subset selection (the paper's contribution)."""
from repro.core.curriculum import CurriculumConfig
from repro.core.exploration import (
    SGEBank,
    WREDistribution,
    build_wre,
    taylor_softmax,
    weighted_sample_without_replacement,
)
from repro.core.gram_free import (
    get_gram_free,
    make_gram_free_disparity_min,
    make_gram_free_disparity_sum,
    make_gram_free_facility_location,
    make_gram_free_graph_cut,
)
from repro.core.greedy import (
    GreedyResult,
    LazyGreedyResult,
    greedy,
    greedy_importance,
    lazy_greedy,
    sge,
    stochastic_greedy,
)
from repro.core.sharded import (
    make_sharded_gram_free,
    sharded_greedy,
    sharded_greedy_importance,
    sharded_lazy_greedy,
    sharded_sge,
    sharded_stochastic_greedy,
)
from repro.core.metadata import MiloMetadata, is_preprocessed
from repro.core.milo import MiloPreprocessor, MiloSelector, preprocess_with_encoder
from repro.core.similarity import gram_matrix, gram_matrix_blocked
from repro.core.submodular import (
    SetFunction,
    disparity_min,
    disparity_sum,
    facility_location,
    graph_cut,
    make_graph_cut,
)

__all__ = [
    "CurriculumConfig",
    "GreedyResult",
    "MiloMetadata",
    "MiloPreprocessor",
    "MiloSelector",
    "SGEBank",
    "SetFunction",
    "WREDistribution",
    "build_wre",
    "disparity_min",
    "disparity_sum",
    "facility_location",
    "get_gram_free",
    "gram_matrix",
    "gram_matrix_blocked",
    "graph_cut",
    "greedy",
    "greedy_importance",
    "is_preprocessed",
    "LazyGreedyResult",
    "lazy_greedy",
    "make_sharded_gram_free",
    "sharded_greedy",
    "sharded_greedy_importance",
    "sharded_lazy_greedy",
    "sharded_sge",
    "sharded_stochastic_greedy",
    "make_gram_free_disparity_min",
    "make_gram_free_disparity_sum",
    "make_gram_free_facility_location",
    "make_gram_free_graph_cut",
    "make_graph_cut",
    "preprocess_with_encoder",
    "sge",
    "stochastic_greedy",
    "taylor_softmax",
    "weighted_sample_without_replacement",
]
