"""Partition strategies for two-level (partition-then-refine) selection.

Building the m x m similarity kernel is memory-prohibitive for large m; the
paper partitions the dataset by class label (§3.2), runs selection within
each class, and merges.  For a balanced dataset with c classes this cuts
kernel memory by c².  Budgets are apportioned proportionally to partition
sizes (largest-remainder rounding so the total is exactly k).

The paper's class-wise split is one instance of a more general decomposition:
a :class:`PartitionStrategy` maps the ground set to disjoint
:class:`Partition`\\ s, level-0 selection runs independently inside each one
(the existing bucketed engines, compile-once-per-bucket), and — when a
partition is still too large for one engine invocation, or the caller wants
the two-level refine of [Mirzasoleiman et al.] — a level-1 greedy pass over
the union of per-partition winners restores global quality at sub-linear
memory in the ground-set size.  Strategies:

``by_class``
    The paper's split (default).  Bit-identical to the historical
    ``partition_by_class`` behaviour, including the single catch-all
    partition when no labels are given.
``random_blocks``
    Seeded random permutation chopped into near-equal blocks of at most
    ``block_size`` rows.  Label-free, so it scales selection to ground sets
    (n ≥ 2^20) where even one class overflows device memory; pair with
    ``refine_factor > 1`` so the level-1 refine can trade winners across
    block boundaries.
``balanced_blocks``
    Class-wise first, then any class larger than ``block_size`` is split
    into near-equal sub-blocks (each keeping the class label) — the
    within-class sub-partitioning for hugely imbalanced datasets.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Sequence

import numpy as np


class Partition(NamedTuple):
    """One ground-set shard: global indices of its members."""

    label: int
    indices: np.ndarray  # (n_c,) int64 global indices


def partition_by_class(labels: np.ndarray) -> list[Partition]:
    labels = np.asarray(labels)
    parts = []
    for lab in np.unique(labels):
        parts.append(Partition(int(lab), np.nonzero(labels == lab)[0]))
    return parts


class PartitionStrategy:
    """How to decompose a ground set into disjoint level-0 partitions.

    ``partition(labels, m)`` returns disjoint :class:`Partition`\\ s covering
    ``range(m)``; ``labels`` is None when the caller selects label-free
    (``classwise=False`` or no labels exist).  ``config()`` returns the
    JSON-safe provenance dict stamped into hierarchical artifacts — only the
    keys the strategy actually depends on, so flat (``by_class``) artifacts
    can omit partition provenance entirely without ambiguity.
    """

    name: str = ""

    def partition(self, labels: np.ndarray | None, m: int) -> list[Partition]:
        raise NotImplementedError

    def config(self) -> dict[str, Any]:
        return {"partition": self.name}


@dataclasses.dataclass(frozen=True)
class ByClass(PartitionStrategy):
    """The paper's class-wise split; one catch-all partition without labels."""

    name = "by_class"

    def partition(self, labels: np.ndarray | None, m: int) -> list[Partition]:
        if labels is None:
            return [Partition(0, np.arange(m, dtype=np.int64))]
        return partition_by_class(np.asarray(labels, np.int64))


@dataclasses.dataclass(frozen=True)
class RandomBlocks(PartitionStrategy):
    """Seeded random near-equal blocks of at most ``block_size`` rows."""

    block_size: int = 4096
    seed: int = 0

    name = "random_blocks"

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")

    def partition(self, labels: np.ndarray | None, m: int) -> list[Partition]:
        if m <= 0:
            return []
        perm = np.random.default_rng(self.seed).permutation(m).astype(np.int64)
        n_blocks = max(1, math.ceil(m / self.block_size))
        # sorted within each block: selection is order-invariant over the
        # slice, and ascending gathers keep the feature reads contiguous
        return [Partition(b, np.sort(chunk))
                for b, chunk in enumerate(np.array_split(perm, n_blocks))]

    def config(self) -> dict[str, Any]:
        return {"partition": self.name, "partition_block": self.block_size,
                "partition_seed": self.seed}


@dataclasses.dataclass(frozen=True)
class BalancedBlocks(PartitionStrategy):
    """Class-wise split, then classes above ``block_size`` rows are chopped
    into near-equal sub-blocks that keep the class label — the class purity
    of ``by_class`` with the bounded per-partition memory of blocks."""

    block_size: int = 4096

    name = "balanced_blocks"

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")

    def partition(self, labels: np.ndarray | None, m: int) -> list[Partition]:
        out: list[Partition] = []
        for p in ByClass().partition(labels, m):
            n_p = len(p.indices)
            if n_p <= self.block_size:
                out.append(p)
                continue
            n_blocks = math.ceil(n_p / self.block_size)
            out.extend(Partition(p.label, chunk)
                       for chunk in np.array_split(p.indices, n_blocks))
        return out

    def config(self) -> dict[str, Any]:
        return {"partition": self.name, "partition_block": self.block_size}


#: registry of strategy names accepted by ``make_partition_strategy``
PARTITION_STRATEGIES = ("by_class", "random_blocks", "balanced_blocks")


def make_partition_strategy(
    name: str, *, block_size: int = 4096, seed: int = 0
) -> PartitionStrategy:
    """Build a strategy from its config-string form (the session/artifact
    representation).  ``block_size``/``seed`` are ignored by strategies that
    do not use them, mirroring which keys ``config()`` stamps."""
    if name == "by_class":
        return ByClass()
    if name == "random_blocks":
        return RandomBlocks(block_size=block_size, seed=seed)
    if name == "balanced_blocks":
        return BalancedBlocks(block_size=block_size)
    raise ValueError(
        f"unknown partition strategy {name!r}; available: {PARTITION_STRATEGIES}"
    )


def proportional_budgets(parts: Sequence[Partition], k: int) -> list[int]:
    """Largest-remainder apportionment of budget k across partitions.

    Guarantees: sum == k, each budget <= partition size, budget >= 1 for any
    non-empty partition when k >= len(parts).
    """
    sizes = np.array([len(p.indices) for p in parts], dtype=np.float64)
    m = sizes.sum()
    if m == 0:
        return [0] * len(parts)
    k = min(k, int(m))
    quotas = sizes * (k / m)
    floors = np.floor(quotas).astype(np.int64)
    floors = np.minimum(floors, sizes.astype(np.int64))
    remainder = k - int(floors.sum())
    # Distribute leftovers by largest fractional part, respecting capacity.
    frac = quotas - np.floor(quotas)
    order = np.argsort(-frac)
    budgets = floors.copy()
    for idx in order:
        if remainder <= 0:
            break
        if budgets[idx] < sizes[idx]:
            budgets[idx] += 1
            remainder -= 1
    # If capacity-limited partitions blocked some leftovers, spill anywhere.
    i = 0
    while remainder > 0 and i < len(parts):
        room = int(sizes[i]) - int(budgets[i])
        take = min(room, remainder)
        budgets[i] += take
        remainder -= take
        i += 1
    # Floor of 1: largest-remainder alone can starve tiny partitions next to
    # a dominant one (sizes [1,1,1,97], k=4 -> [0,0,0,4]), breaking the
    # documented min-1 guarantee.  Whenever the (clamped) budget can cover
    # every non-empty partition, move single units from the largest budgets
    # (which must hold >= 2 by pigeonhole while any starved partition
    # remains) to the starved ones.  Apportionments that already satisfy the
    # floor — every historical fixture — pass through bit-identically.
    nonempty = sizes > 0
    if k >= int(nonempty.sum()):
        for idx in np.nonzero(nonempty & (budgets == 0))[0]:
            donor = int(np.argmax(np.where(budgets >= 2, budgets, -1)))
            budgets[donor] -= 1
            budgets[idx] += 1
    return [int(b) for b in budgets]


def merge_class_selections(
    parts: Sequence[Partition], local_selections: Sequence[np.ndarray]
) -> np.ndarray:
    """Map per-partition local indices back to global dataset indices."""
    out = [np.asarray(p.indices)[np.asarray(sel)] for p, sel in zip(parts, local_selections)]
    return np.concatenate(out) if out else np.zeros((0,), np.int64)
