"""Class-wise data partitioning (paper §3.2).

Building the m x m similarity kernel is memory-prohibitive for large m; the
paper partitions the dataset by class label, runs selection within each class,
and merges.  For a balanced dataset with c classes this cuts kernel memory by
c².  Budgets are apportioned proportionally to class sizes (largest-remainder
rounding so the total is exactly k).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np


class Partition(NamedTuple):
    """One class shard: global indices of its members."""

    label: int
    indices: np.ndarray  # (n_c,) int64 global indices


def partition_by_class(labels: np.ndarray) -> list[Partition]:
    labels = np.asarray(labels)
    parts = []
    for lab in np.unique(labels):
        parts.append(Partition(int(lab), np.nonzero(labels == lab)[0]))
    return parts


def proportional_budgets(parts: Sequence[Partition], k: int) -> list[int]:
    """Largest-remainder apportionment of budget k across partitions.

    Guarantees: sum == k, each budget <= partition size, budget >= 1 for any
    non-empty partition when k >= len(parts).
    """
    sizes = np.array([len(p.indices) for p in parts], dtype=np.float64)
    m = sizes.sum()
    if m == 0:
        return [0] * len(parts)
    k = min(k, int(m))
    quotas = sizes * (k / m)
    floors = np.floor(quotas).astype(np.int64)
    floors = np.minimum(floors, sizes.astype(np.int64))
    remainder = k - int(floors.sum())
    # Distribute leftovers by largest fractional part, respecting capacity.
    frac = quotas - np.floor(quotas)
    order = np.argsort(-frac)
    budgets = floors.copy()
    for idx in order:
        if remainder <= 0:
            break
        if budgets[idx] < sizes[idx]:
            budgets[idx] += 1
            remainder -= 1
    # If capacity-limited partitions blocked some leftovers, spill anywhere.
    i = 0
    while remainder > 0 and i < len(parts):
        room = int(sizes[i]) - int(budgets[i])
        take = min(room, remainder)
        budgets[i] += take
        remainder -= take
        i += 1
    return [int(b) for b in budgets]


def merge_class_selections(
    parts: Sequence[Partition], local_selections: Sequence[np.ndarray]
) -> np.ndarray:
    """Map per-class local indices back to global dataset indices."""
    out = [np.asarray(p.indices)[np.asarray(sel)] for p, sel in zip(parts, local_selections)]
    return np.concatenate(out) if out else np.zeros((0,), np.int64)
