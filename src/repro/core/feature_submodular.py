"""Kernel-free (feature-based) submodular selection — the paper's stated
future work (§5: "we will investigate feature-based submodular functions to
avoid the need for similarity kernel construction").

Instead of the m×m Gram matrix, every sample is represented by its
similarity row to L ≪ m *landmarks* (k-means++ centers chosen on device):

    Φ[i, l] = 0.5 + 0.5 · cos(z_i, c_l)            (m × L, not m × m)

Facility location is then evaluated against the landmark set as the ground
set being covered:  f(S) = Σ_l max_{j∈S} Φ[j, l]  — a Nyström-style
approximation whose gains cost O(L) per candidate instead of O(m), giving
O(m·L·k) total selection (vs O(m²·k)) and O(m·L) memory.  For class-wise
partitioning this removes the paper's main memory complaint outright.

Graph-cut gets the analogous treatment: colsum_j ≈ (m/L) Σ_l Φ[j, l] and the
S×S penalty uses the landmark inner products as a low-rank kernel surrogate
K̂ = Φ Φᵀ / L.

Quality: tests/test_feature_submodular.py shows the landmark-FL greedy
recovers ≥90% of the exact-FL objective at L = 4·k on clustered data.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.similarity import normalize_rows
from repro.core.submodular import SetFunction


def kmeans_pp_landmarks(key: jax.Array, z: jax.Array, n_landmarks: int,
                        *, n_iters: int = 8) -> jax.Array:
    """k-means++ init + a few Lloyd iterations, fully on device."""
    m, d = z.shape
    z = z.astype(jnp.float32)

    def pp_step(carry, k_i):
        centers, dist2 = carry
        i, kk = k_i
        # sample next center proportional to squared distance
        p = dist2 / jnp.maximum(jnp.sum(dist2), 1e-12)
        idx = jax.random.categorical(kk, jnp.log(jnp.maximum(p, 1e-30)))
        c = z[idx]
        centers = centers.at[i].set(c)
        nd = jnp.sum((z - c) ** 2, axis=-1)
        return (centers, jnp.minimum(dist2, nd)), None

    k0, k1 = jax.random.split(key)
    first = z[jax.random.randint(k0, (), 0, m)]
    centers0 = jnp.zeros((n_landmarks, d), jnp.float32).at[0].set(first)
    d0 = jnp.sum((z - first) ** 2, axis=-1)
    keys = jax.random.split(k1, n_landmarks - 1)
    (centers, _), _ = jax.lax.scan(
        pp_step, (centers0, d0), (jnp.arange(1, n_landmarks), keys)
    )

    def lloyd(centers, _):
        d2 = jnp.sum((z[:, None] - centers[None]) ** 2, axis=-1)  # (m, L)
        assign = jnp.argmin(d2, axis=-1)
        onehot = jax.nn.one_hot(assign, n_landmarks, dtype=jnp.float32)
        counts = jnp.maximum(onehot.sum(0), 1.0)
        new = (onehot.T @ z) / counts[:, None]
        # keep empty clusters where they were
        new = jnp.where((onehot.sum(0) > 0)[:, None], new, centers)
        return new, None

    centers, _ = jax.lax.scan(lloyd, centers, None, length=n_iters)
    return centers


@functools.partial(jax.jit, static_argnames=("n_landmarks",))
def landmark_features(key: jax.Array, z: jax.Array, n_landmarks: int) -> jax.Array:
    """Φ (m, L): rescaled-cosine similarity of every sample to each landmark."""
    centers = kmeans_pp_landmarks(key, z, n_landmarks)
    zn = normalize_rows(z.astype(jnp.float32))
    cn = normalize_rows(centers)
    return 0.5 + 0.5 * (zn @ cn.T)


# --- feature-based facility location ---------------------------------------
# state c[l] = max_{j in S} Φ[j, l]; gains(j) = Σ_l relu(Φ[j, l] - c[l]).
# NOTE: the "K" argument threaded through the greedy engines is Φ here.

def _ffl_init(phi: jax.Array):
    return jnp.zeros((phi.shape[1],), phi.dtype)


def _ffl_gains(c, phi: jax.Array) -> jax.Array:
    return jnp.sum(jax.nn.relu(phi - c[None, :]), axis=1)


def _ffl_update(c, phi: jax.Array, j: jax.Array):
    return jnp.maximum(c, phi[j])


def _ffl_eval(mask: jax.Array, phi: jax.Array) -> jax.Array:
    sel = jnp.where(mask[:, None], phi, -jnp.inf)
    best = jnp.max(sel, axis=0)
    return jnp.sum(jnp.where(jnp.any(mask), best, 0.0))


feature_facility_location = SetFunction(
    name="feature_facility_location",
    init=_ffl_init,
    gains=_ffl_gains,
    update=_ffl_update,
    evaluate=_ffl_eval,
)


# --- feature-based graph cut -------------------------------------------------

def make_feature_graph_cut(lam: float = 0.4) -> SetFunction:
    """Graph-cut on the low-rank surrogate K̂ = Φ Φᵀ / L."""

    def init(phi):
        L = phi.shape[1]
        colsum = phi @ (jnp.sum(phi, axis=0) / L)       # Σ_i K̂[i, j]
        return {"colsum": colsum, "acc": jnp.zeros((phi.shape[1],), phi.dtype)}

    def gains(state, phi):
        L = phi.shape[1]
        diag = jnp.sum(phi * phi, axis=1) / L
        cur = phi @ state["acc"] / L                    # Σ_{i in S} K̂[i, j]
        return state["colsum"] - lam * (2.0 * cur + diag)

    def update(state, phi, j):
        return {"colsum": state["colsum"], "acc": state["acc"] + phi[j]}

    def evaluate(mask, phi):
        L = phi.shape[1]
        s = phi.T @ mask.astype(phi.dtype)              # Σ_{j in S} Φ[j]
        total = jnp.sum(phi, axis=0)
        return (total @ s) / L - lam * (s @ s) / L

    return SetFunction("feature_graph_cut", init, gains, update, evaluate)


feature_graph_cut = make_feature_graph_cut(0.4)


class FeatureSelection(NamedTuple):
    indices: jax.Array
    phi: jax.Array


def feature_greedy_select(
    key: jax.Array, z: jax.Array, k: int, *, n_landmarks: int | None = None,
    fn: SetFunction = feature_facility_location,
):
    """End-to-end kernel-free selection: landmarks -> Φ -> jit greedy."""
    from repro.core.greedy import greedy

    if n_landmarks is None:
        n_landmarks = max(16, min(4 * k, z.shape[0] // 2))
    phi = landmark_features(key, jnp.asarray(z), n_landmarks)
    res = greedy(fn, phi, k)
    return FeatureSelection(res.indices, phi)
