"""Easy-to-hard curriculum schedule (paper §3.1.3 / Alg. 1).

Epochs [0, kappa*T) train on SGE(graph-cut) subsets — representative, "easy".
Epochs [kappa*T, T) train on WRE(disparity-min) samples — diverse, "hard",
with easy samples still drawn occasionally (mitigates forgetting).
A new subset is taken every R epochs (paper finds R = 1 best).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Phase = Literal["sge", "wre"]


@dataclasses.dataclass(frozen=True)
class CurriculumConfig:
    total_epochs: int
    kappa: float = 1.0 / 6.0  # fraction of epochs on SGE (paper-tuned optimum)
    R: int = 1                # re-selection interval in epochs

    def __post_init__(self):
        if not (0.0 <= self.kappa <= 1.0):
            raise ValueError(f"kappa must be in [0,1], got {self.kappa}")
        if self.R < 1:
            raise ValueError("R must be >= 1")

    @property
    def sge_epochs(self) -> int:
        return int(round(self.kappa * self.total_epochs))

    def phase(self, epoch: int) -> Phase:
        return "sge" if epoch < self.sge_epochs else "wre"

    def needs_new_subset(self, epoch: int) -> bool:
        """True when a fresh subset must be materialized at this epoch."""
        if epoch == 0 or epoch == self.sge_epochs:
            return True  # phase boundary always re-selects
        if self.phase(epoch) == "sge":
            return epoch % self.R == 0
        return (epoch - self.sge_epochs) % self.R == 0
