"""Gram-free set functions: selection directly over features, no (n×n) Gram.

The classwise Gram matrix is MILO preprocessing's memory wall: O(n²) per
class caps the ground-set size long before compute does.  Every set function
in ``core.submodular`` only ever touches the kernel through three access
patterns — a column ``K[:, j]`` (update), a diagonal entry ``K_jj`` (gains),
and for graph-cut a one-time column sum — and under the paper's rescaled
cosine metric

    K_ij = 0.5 + 0.5 · <z_i, z_j>          (z row-normalized)

each of those is an O(n·d) feature contraction.  The factories below rebuild
all four paper set functions in that form: the ``K`` argument threaded
through the greedy engines is the row-normalized feature matrix ``z`` of
shape (n, d), and peak memory is O(n·d + n) instead of O(n²).

Facility location is the one function whose *gain evaluation* still reduces
over the whole ground set; its hot path is the fused Pallas kernel
``kernels.fl_gains.fl_gains_gram_free`` which computes similarity tiles on
the MXU in VMEM and never writes them back.

Padding contract (size bucketing): all-zero feature rows are treated as
padding — facility location pins their cover to +inf at init so they
contribute nothing, and the greedy engines' ``valid`` mask keeps them from
ever being selected.  (A genuinely all-zero embedding is degenerate under
cosine similarity to begin with.)  Because "all-zero" is a *sentinel* here,
a genuinely zero-norm data row reaching this layer is silently treated as
padding — screen real inputs upstream with
``repro.health.validate_features`` (which flags zero-norm rows via
``similarity.zero_norm_rows``) rather than relaxing this contract.

Numerics: trajectories match the Gram-materializing path exactly on the
facility-location column reductions (same values, same reduction order); the
graph-cut column sum is computed in closed form (0.5·n + 0.5·z·Σz) so its
float rounding can differ from a materialized row sum by ~1 ulp — tests
assert trajectory equality on fixtures and allclose on gains.

Every factory is memoized on its (hashable) params: the greedy engines jit
with the ``SetFunction`` as a static argument, and a frozen dataclass of
closures hashes by closure identity — rebuilding the function each
``preprocess()`` call would therefore recompile every engine every session.
Returning the same object for the same params keeps those jit caches (and
``core.sharded._compiled``'s lru cache) warm across calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.submodular import LazyHooks, SetFunction, State, _DMIN_CAP


def _sim_col(z: jax.Array, j: jax.Array) -> jax.Array:
    """Similarity column K[:, j] computed on the fly: O(n·d)."""
    return 0.5 + 0.5 * (z @ z[j])


def _sim_at(z: jax.Array, cand: jax.Array) -> jax.Array:
    """Candidate similarity block K[:, cand]: (n, s) in O(n·d·s)."""
    return 0.5 + 0.5 * (z @ z[cand].T)


def _row_sumsq(z: jax.Array) -> jax.Array:
    return jnp.sum(z * z, axis=-1)


def _sim_matrix(z: jax.Array) -> jax.Array:
    """Full Gram (tests/``evaluate`` only — never on the selection hot path).

    Rows/cols of padding (all-zero) features are zeroed to match the
    zero-padded materialized Gram the bucketed gram path uses.
    """
    live = _row_sumsq(z) > 0.0
    sim = 0.5 + 0.5 * (z @ z.T)
    return jnp.where(live[:, None] & live[None, :], sim, 0.0)


# ---------------------------------------------------------------------------
# Facility location:  state c[i] = max_{j in S} K_ij  (+inf on padding rows)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_gram_free_facility_location(
    *,
    use_pallas: bool = False,
    interpret: bool = False,
    block_i: int = 512,
    block_j: int = 512,
) -> SetFunction:
    """Facility location over features; Pallas-fused gains when requested."""
    from repro.kernels.fl_gains import ops as fl_ops

    def init(z: jax.Array) -> State:
        c0 = jnp.zeros((z.shape[0],), jnp.float32)
        return jnp.where(_row_sumsq(z) > 0.0, c0, jnp.inf)

    def gains(c: State, z: jax.Array) -> jax.Array:
        return fl_ops.fl_gains_gram_free(
            z, z, c, block_i=block_i, block_j=block_j,
            use_pallas=use_pallas, interpret=interpret,
        )

    def gains_at(c: State, z: jax.Array, cand: jax.Array) -> jax.Array:
        return fl_ops.fl_gains_gram_free(
            z, z[cand], c, block_i=block_i, block_j=block_j,
            use_pallas=use_pallas, interpret=interpret,
        )

    def update(c: State, z: jax.Array, j: jax.Array) -> State:
        return jnp.maximum(c, _sim_col(z, j))

    def evaluate(mask: jax.Array, z: jax.Array) -> jax.Array:
        K = _sim_matrix(z)
        sel = jnp.where(mask[None, :], K, -jnp.inf)
        best = jnp.max(sel, axis=1)
        return jnp.sum(jnp.where(jnp.any(mask), best, 0.0))

    def delta_gains(z: jax.Array, rows: jax.Array, c_old: jax.Array,
                    c_new: jax.Array) -> jax.Array:
        return fl_ops.fl_gains_gram_free_delta(
            z[rows], z, c_old, c_new, block_i=block_i, block_j=block_j,
            use_pallas=use_pallas, interpret=interpret,
        )

    name = "gram_free_facility_location" + ("_pallas" if use_pallas else "")
    return SetFunction(name, init, gains, update, evaluate, gains_at=gains_at,
                       lazy=LazyHooks(cover=lambda c: c, delta_gains=delta_gains))


# ---------------------------------------------------------------------------
# Graph cut: colsum in closed form, cur accumulated column-wise as usual
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_gram_free_graph_cut(lam: float = 0.4) -> SetFunction:
    def init(z: jax.Array) -> State:
        sumsq = _row_sumsq(z)
        live = sumsq > 0.0
        n_live = jnp.sum(live.astype(jnp.float32))
        # Σ_i K_ij = 0.5·n_live + 0.5·<z_j, Σ_i z_i>  (padding rows are zero
        # vectors so they drop out of both terms)
        colsum = 0.5 * n_live + 0.5 * (z @ jnp.sum(z, axis=0))
        colsum = jnp.where(live, colsum, 0.0)
        # K_jj from the same normalized features the gram path would square
        diag = jnp.where(live, 0.5 + 0.5 * sumsq, 0.0)
        return {
            "colsum": colsum,
            "diag": diag,
            "cur": jnp.zeros((z.shape[0],), jnp.float32),
        }

    def gains(state: State, z: jax.Array) -> jax.Array:
        return state["colsum"] - lam * (2.0 * state["cur"] + state["diag"])

    def gains_at(state: State, z: jax.Array, cand: jax.Array) -> jax.Array:
        return state["colsum"][cand] - lam * (
            2.0 * state["cur"][cand] + state["diag"][cand]
        )

    def update(state: State, z: jax.Array, j: jax.Array) -> State:
        return {
            "colsum": state["colsum"],
            "diag": state["diag"],
            "cur": state["cur"] + _sim_col(z, j),
        }

    def evaluate(mask: jax.Array, z: jax.Array) -> jax.Array:
        K = _sim_matrix(z)
        m = mask.astype(K.dtype)
        return jnp.sum(K @ m) - lam * (m @ K @ m)

    return SetFunction("gram_free_graph_cut", init, gains, update, evaluate,
                       gains_at=gains_at)


# ---------------------------------------------------------------------------
# Disparity-sum / disparity-min: state-only gains, O(n·d) column updates
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def make_gram_free_disparity_sum() -> SetFunction:
    def init(z: jax.Array) -> State:
        return jnp.zeros((z.shape[0],), jnp.float32)

    def gains(cur: State, z: jax.Array) -> jax.Array:
        return 2.0 * cur

    def gains_at(cur: State, z: jax.Array, cand: jax.Array) -> jax.Array:
        return 2.0 * cur[cand]

    def update(cur: State, z: jax.Array, j: jax.Array) -> State:
        return cur + (1.0 - _sim_col(z, j))

    def evaluate(mask: jax.Array, z: jax.Array) -> jax.Array:
        K = _sim_matrix(z)
        m = mask.astype(K.dtype)
        return m @ (1.0 - K) @ m - jnp.sum(m * (1.0 - jnp.diagonal(K)))

    return SetFunction("gram_free_disparity_sum", init, gains, update, evaluate,
                       gains_at=gains_at)


@functools.lru_cache(maxsize=64)
def make_gram_free_disparity_min() -> SetFunction:
    def init(z: jax.Array) -> State:
        n = z.shape[0]
        return {
            "dmin": jnp.full((n,), _DMIN_CAP, jnp.float32),
            "cur": jnp.asarray(_DMIN_CAP, jnp.float32),
            "size": jnp.asarray(0, jnp.int32),
        }

    def gains(state: State, z: jax.Array) -> jax.Array:
        return jnp.minimum(state["cur"], state["dmin"]) - state["cur"]

    def gains_at(state: State, z: jax.Array, cand: jax.Array) -> jax.Array:
        return jnp.minimum(state["cur"], state["dmin"][cand]) - state["cur"]

    def update(state: State, z: jax.Array, j: jax.Array) -> State:
        dist_j = 1.0 - _sim_col(z, j)
        new_cur = jnp.where(
            state["size"] >= 1,
            jnp.minimum(state["cur"], state["dmin"][j]),
            state["cur"],
        )
        return {
            "dmin": jnp.minimum(state["dmin"], dist_j),
            "cur": new_cur,
            "size": state["size"] + 1,
        }

    def evaluate(mask: jax.Array, z: jax.Array) -> jax.Array:
        K = _sim_matrix(z)
        n = K.shape[0]
        d = 1.0 - K
        pair = mask[:, None] & mask[None, :] & ~jnp.eye(n, dtype=bool)
        return jnp.min(jnp.where(pair, d, _DMIN_CAP))

    return SetFunction("gram_free_disparity_min", init, gains, update, evaluate,
                       gains_at=gains_at)


# ---------------------------------------------------------------------------
# Query-conditioned facility location (targeted / SMI-style selection)
# ---------------------------------------------------------------------------

# manual memo (lru_cache can't key on arrays): (shape, dtype, bytes) -> fn.
# Bounded: targeted sessions reuse a handful of query banks, not thousands.
_QUERY_FL_CACHE: dict = {}
_QUERY_FL_CACHE_MAX = 16


def make_query_facility_location(z_query) -> SetFunction:
    """Facility location over a *query* set instead of the ground set.

    SMI-style targeted selection: f(S) = Σ_q max_{a in S} sim(a, q), so the
    per-element gain is Σ_q relu(sim(a, q) − cover_q) — the state is the
    per-query cover (q,), not the per-ground-row cover (n,).  ``z_query``
    must be row-normalized (same contract as the ground features); it is
    closed over as a jit constant, which is fine at the intended scale
    (queries are a handful of exemplars, the ground set is the big side).

    Padding ground rows (all-zero) get similarity exactly 0.5 to every
    query, which could look like positive gain at init — so gains are
    computed against a cover initialized at 0.5, making padding rows' gains
    exactly 0 (and the greedy engines' ``valid`` mask excludes them anyway).
    """
    import numpy as np

    zq = np.ascontiguousarray(np.asarray(z_query, np.float32))
    key = (zq.shape, zq.tobytes())
    hit = _QUERY_FL_CACHE.get(key)
    if hit is not None:
        return hit

    zq_j = jnp.asarray(zq)

    def init(z: jax.Array) -> State:
        # cover starts at 0.5 == sim(zero-row, q): padding contributes 0 gain
        return jnp.full((zq_j.shape[0],), 0.5, jnp.float32)

    def _sim_q(z: jax.Array) -> jax.Array:
        return 0.5 + 0.5 * (z @ zq_j.T)  # (n, q)

    def gains(c: State, z: jax.Array) -> jax.Array:
        return jnp.sum(jnp.maximum(_sim_q(z) - c[None, :], 0.0), axis=1)

    def gains_at(c: State, z: jax.Array, cand: jax.Array) -> jax.Array:
        return gains(c, z[cand])

    def update(c: State, z: jax.Array, j: jax.Array) -> State:
        return jnp.maximum(c, 0.5 + 0.5 * (zq_j @ z[j]))

    def evaluate(mask: jax.Array, z: jax.Array) -> jax.Array:
        sim = jnp.where(mask[:, None], _sim_q(z), -jnp.inf)  # (n, q)
        best = jnp.max(sim, axis=0)
        return jnp.sum(jnp.where(jnp.any(mask), best, 0.0))

    fn = SetFunction("query_facility_location", init, gains, update, evaluate,
                     gains_at=gains_at)
    if len(_QUERY_FL_CACHE) >= _QUERY_FL_CACHE_MAX:
        _QUERY_FL_CACHE.pop(next(iter(_QUERY_FL_CACHE)))
    _QUERY_FL_CACHE[key] = fn
    return fn


def get_gram_free(name: str, **kwargs) -> SetFunction:
    """Gram-free counterpart of ``submodular.get`` (cosine metric only)."""
    factories = {
        "facility_location": make_gram_free_facility_location,
        "graph_cut": make_gram_free_graph_cut,
        "disparity_sum": make_gram_free_disparity_sum,
        "disparity_min": make_gram_free_disparity_min,
    }
    try:
        return factories[name](**kwargs)
    except KeyError:
        raise KeyError(
            f"no gram-free variant of {name!r}; available: {sorted(factories)}"
        ) from None
