"""Data-exploration strategies: SGE subset banks and WRE distributions.

WRE (paper §3.1.2): greedy importance scores -> second-order Taylor-softmax
(Eq. 5) -> multinomial distribution p over the dataset; every R epochs a new
subset of size k is drawn from p *without replacement*.

Sampling without replacement uses the Efraimidis–Spirakis exponentiated race
in Gumbel form: ``top_k(log p + Gumbel)`` — a single fused device op (see
DESIGN.md §2), mathematically identical to sequential weighted draws.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


def taylor_softmax(g: jax.Array, axis: int = -1) -> jax.Array:
    """Second-order Taylor-softmax (paper Eq. 5): p_i ∝ 1 + g_i + g_i²/2.

    Strictly positive for all real g (min value 0.5 at g = -1), so it is
    well-defined for the negative marginal gains produced by disparity-min.
    """
    w = 1.0 + g + 0.5 * g * g
    return w / jnp.sum(w, axis=axis, keepdims=True)


@functools.partial(jax.jit, static_argnames=("k",))
def _wswor(key: jax.Array, p: jax.Array, k: int) -> jax.Array:
    # Zero-probability entries are masked to -inf, not floored: flooring at
    # 1e-30 let masked/degenerate elements win top-k slots whenever k
    # exceeded the nonzero support.  -inf + Gumbel stays -inf, so a masked
    # element can never be drawn; positive entries keep the exact
    # log(max(p, 1e-30)) value the old formula produced, so valid draws are
    # bit-for-bit unchanged.
    logp = jnp.where(p > 0.0, jnp.log(jnp.maximum(p, 1e-30)), -jnp.inf)
    z = logp + jax.random.gumbel(key, p.shape)
    _, idx = jax.lax.top_k(z, k)
    return idx.astype(jnp.int32)


def weighted_sample_without_replacement(
    key: jax.Array, p: jax.Array, k: int
) -> jax.Array:
    """Draw k distinct indices with probabilities ∝ p (Gumbel top-k).

    Requires ``k`` ≤ the nonzero support of ``p``: sampling without
    replacement cannot produce more distinct indices than there are elements
    with positive mass.  The guard runs host-side on concrete inputs (the
    selector's normal call pattern); inside a trace the masked Gumbel race
    still guarantees zero-probability indices lose to every positive one.
    """
    if not isinstance(p, jax.core.Tracer):
        support = int(jnp.count_nonzero(jnp.asarray(p) > 0.0))
        if k > support:
            raise ValueError(
                f"cannot draw k={k} distinct indices from a distribution "
                f"with only {support} nonzero-probability elements"
            )
    return _wswor(key, p, k)


class WREDistribution(NamedTuple):
    """Multinomial sampling distribution over the dataset (global indices)."""

    probs: jax.Array        # (m,) float32, sums to 1
    importance: jax.Array   # (m,) raw greedy gains (diagnostics / metadata)

    def sample(self, key: jax.Array, k: int) -> jax.Array:
        return weighted_sample_without_replacement(key, self.probs, k)


class SGEBank(NamedTuple):
    """Pre-selected subset bank from SGE (global indices)."""

    subsets: jax.Array  # (n_subsets, k) int32

    @property
    def n_subsets(self) -> int:
        return int(self.subsets.shape[0])

    def subset_for_epoch(self, epoch: int, R: int) -> jax.Array:
        """Rotate through the bank every R epochs."""
        return self.subsets[(epoch // max(R, 1)) % self.n_subsets]


def build_wre(importance: jax.Array) -> WREDistribution:
    imp = importance.astype(jnp.float32)
    return WREDistribution(probs=taylor_softmax(imp), importance=imp)
