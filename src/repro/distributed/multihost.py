"""Multi-host runtime: initialization, coordination barriers, host liveness.

This is the layer that breaks the single-process wall (ROADMAP item 1).
Everything above it — the ``sel`` mesh, the fused training engine, the
checkpointer — is already mesh-agnostic; what they need from here is small
and sharp:

  * ``initialize()`` — an idempotent, env-driven wrapper around
    ``jax.distributed.initialize``.  On the CPU backend it selects the gloo
    collectives implementation *before* initialization (the only point at
    which that config is writable), so two local CPU processes can run real
    cross-process ``psum``/``ppermute``/``all_gather`` — the CI smoke
    topology.  Launch N processes with::

        MILO_COORDINATOR=localhost:<port> MILO_NUM_PROCESSES=N \
            MILO_PROCESS_ID=<i> python ...

  * ``RuntimeBarrier`` — a named barrier over the jax coordination service
    (no device collectives, so it works outside any mesh/jit context).  A
    timeout means a peer did not arrive — the canonical dead-host signal —
    and is raised as ``HostLossError``, never a bare runtime error.
  * ``FileBarrier`` — the same contract over marker files, for in-process
    *simulated* multi-host tests (two ``CheckpointManager``s on threads).
    Marker files persist after the barrier passes, so names must be unique
    per rendezvous (the checkpointer's include the step); real runs use the
    coordination service, which has no such constraint.
  * ``HeartbeatWriter`` / ``HeartbeatMonitor`` — host liveness as fsync-free
    atomic JSON files on shared storage, with an injectable clock so
    staleness is testable without sleeping.  ``check()`` raises
    ``HostLossError`` naming the stale hosts; the restart then feeds the
    surviving host count into ``fault_tolerance.elastic_plan`` and resumes
    from the last *globally*-valid checkpoint.
  * ``global_put`` — place a host-replicated array onto a (possibly
    multi-process) mesh; every process fills its addressable shards from
    its own full copy, so no cross-host transfer happens at placement time.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import time
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.fault_tolerance import HostLossError

_HOST_RE = re.compile(r"^host_(\d+)\.json$")


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def is_initialized() -> bool:
    """Whether ``jax.distributed.initialize`` has run in this process."""
    try:
        from jax._src import distributed as _dist

        return _dist.global_state.client is not None
    except Exception:  # pragma: no cover - jax internals moved
        return False


def initialize(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> bool:
    """Idempotent ``jax.distributed.initialize`` with env-driven defaults.

    Reads ``MILO_COORDINATOR`` / ``MILO_NUM_PROCESSES`` / ``MILO_PROCESS_ID``
    when arguments are omitted; a no-op (returns False) when neither
    arguments nor env vars ask for multi-process execution, or when the
    runtime is already initialized.  On the CPU backend the gloo collectives
    implementation is selected first — cross-process collectives on CPU
    require it, and the flag is only writable before initialization.
    """
    if is_initialized():
        return False
    coordinator_address = coordinator_address or os.environ.get("MILO_COORDINATOR")
    if num_processes is None:
        env_n = os.environ.get("MILO_NUM_PROCESSES")
        num_processes = int(env_n) if env_n else None
    if process_id is None:
        env_i = os.environ.get("MILO_PROCESS_ID")
        process_id = int(env_i) if env_i else None
    if coordinator_address is None or num_processes is None or num_processes < 2:
        return False
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # non-CPU build without the option: harmless
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return True


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def is_coordinator() -> bool:
    """Process 0 coordinates: it publishes global checkpoint manifests and
    owns garbage collection.  Single-process runs are their own coordinator."""
    return jax.process_index() == 0


# ---------------------------------------------------------------------------
# barriers
# ---------------------------------------------------------------------------

class RuntimeBarrier:
    """Named barrier over the jax coordination service.

    ``wait(name)`` blocks until every process has called ``wait`` with the
    same name; a timeout — the canonical "a peer died" observable — raises
    ``HostLossError``.  Requires ``initialize()`` to have run.
    """

    def __init__(self, timeout: float = 120.0):
        self.timeout = float(timeout)

    def wait(self, name: str) -> None:
        from jax._src import distributed as _dist

        client = _dist.global_state.client
        if client is None:
            raise RuntimeError(
                "RuntimeBarrier requires jax.distributed to be initialized "
                "(multihost.initialize())"
            )
        try:
            client.wait_at_barrier(name, timeout_in_ms=int(self.timeout * 1000))
        except jax.errors.JaxRuntimeError as e:
            raise HostLossError(
                f"barrier {name!r} not reached by all "
                f"{jax.process_count()} hosts within {self.timeout}s — "
                f"a peer is unreachable or dead ({e})"
            ) from e


@dataclasses.dataclass
class FileBarrier:
    """Marker-file barrier for in-process *simulated* multi-host tests.

    Each participant drops ``<root>/<name>.<index>`` and polls until all
    ``count`` markers exist.  Markers persist after the rendezvous, so every
    barrier name must be unique per logical rendezvous (the checkpointer's
    names embed the step number).  Real multi-process runs use
    ``RuntimeBarrier`` instead — the coordination service needs no shared
    filesystem semantics and cannot be confused by stale markers from a
    crashed earlier attempt.
    """

    root: str
    index: int
    count: int
    timeout: float = 30.0
    poll: float = 0.005
    clock: Callable[[], float] = time.monotonic
    sleep: Callable[[float], None] = time.sleep

    def wait(self, name: str) -> None:
        os.makedirs(self.root, exist_ok=True)
        mine = os.path.join(self.root, f"{name}.{self.index}")
        with open(mine, "w") as f:
            f.write(str(self.index))
        deadline = self.clock() + self.timeout
        while True:
            missing = [
                i for i in range(self.count)
                if not os.path.exists(os.path.join(self.root, f"{name}.{i}"))
            ]
            if not missing:
                return
            if self.clock() > deadline:
                raise HostLossError(
                    f"barrier {name!r}: hosts {missing} absent after "
                    f"{self.timeout}s",
                    hosts=missing,
                )
            self.sleep(self.poll)


def default_barrier(timeout: float = 120.0) -> RuntimeBarrier | None:
    """The barrier real multi-process runs coordinate on (None when this is
    a plain single-process run with no coordination service)."""
    return RuntimeBarrier(timeout) if is_initialized() else None


# ---------------------------------------------------------------------------
# host liveness: heartbeat files with an injectable clock
# ---------------------------------------------------------------------------

class HeartbeatWriter:
    """Writes this host's liveness beacon: ``<dir>/host_<i>.json``.

    Atomic (temp file + rename) so a monitor never parses a torn beat; NOT
    fsync'd — a heartbeat is a freshness signal, not durable state, and an
    fsync per training step would be a straggler generator.
    """

    def __init__(
        self,
        directory: str,
        proc_index: int | None = None,
        *,
        clock: Callable[[], float] = time.time,
    ):
        self.directory = directory
        self.index = jax.process_index() if proc_index is None else proc_index
        self.clock = clock
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, f"host_{self.index}.json")

    def beat(self, step: int | None = None) -> None:
        payload = {"process_index": self.index, "time": self.clock()}
        if step is not None:
            payload["step"] = int(step)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)


class HeartbeatMonitor:
    """Reads every host's beacon and flags the stale/missing ones.

    ``expected`` hosts with no beacon file at all count as stale from the
    monitor's construction (age = now - created) — a host that never wrote a
    beat is indistinguishable from one that died before its first.  The
    injectable ``clock`` makes staleness a pure function of test inputs.
    """

    def __init__(
        self,
        directory: str,
        *,
        timeout: float = 60.0,
        expected: int | None = None,
        clock: Callable[[], float] = time.time,
    ):
        self.directory = directory
        self.timeout = float(timeout)
        self.expected = expected
        self.clock = clock
        self._created = clock()

    def _beats(self) -> dict[int, dict[str, Any]]:
        out: dict[int, dict[str, Any]] = {}
        try:
            names = os.listdir(self.directory)
        except FileNotFoundError:
            return out
        for fn in names:
            m = _HOST_RE.match(fn)
            if not m:
                continue
            try:
                with open(os.path.join(self.directory, fn)) as f:
                    out[int(m.group(1))] = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue  # mid-replace read: treat as absent this poll
        return out

    def ages(self) -> dict[int, float]:
        """Seconds since each known/expected host's last beat."""
        now = self.clock()
        beats = self._beats()
        hosts = set(beats)
        if self.expected is not None:
            hosts |= set(range(self.expected))
        return {
            i: (now - beats[i]["time"]) if i in beats else (now - self._created)
            for i in sorted(hosts)
        }

    def stale_hosts(self) -> list[int]:
        return [i for i, age in self.ages().items() if age > self.timeout]

    def check(self) -> None:
        """Raise ``HostLossError`` naming every stale host."""
        stale = self.stale_hosts()
        if stale:
            ages = self.ages()
            detail = ", ".join(f"host {i}: {ages[i]:.1f}s" for i in stale)
            raise HostLossError(
                f"host(s) {stale} stale past the {self.timeout}s heartbeat "
                f"timeout ({detail}) — re-mesh via elastic_plan and resume "
                "from the last globally-valid checkpoint",
                hosts=stale,
            )

    def snapshot(self) -> dict[str, Any]:
        """JSON-safe liveness summary for ``MiloServer.health()``."""
        ages = self.ages()
        stale = [i for i, age in ages.items() if age > self.timeout]
        return {
            "expected": self.expected,
            "timeout": self.timeout,
            "ages": {str(i): round(age, 3) for i, age in ages.items()},
            "stale": stale,
        }


# ---------------------------------------------------------------------------
# global array placement
# ---------------------------------------------------------------------------

def mesh_spans_processes(mesh: Mesh) -> bool:
    """Whether the mesh's devices live in more than one process."""
    return len({d.process_index for d in mesh.devices.flat}) > 1


def global_put(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    """Place a host-replicated array onto a (possibly multi-process) mesh.

    Every process holds the full ``x`` (replicated host data is the
    contract for selection inputs — each host loads/derives the same ground
    set) and fills only its *addressable* shards, so placement moves no
    bytes across hosts.  Works for sharded and replicated specs alike.
    """
    sharding = NamedSharding(mesh, spec)
    return jax.make_array_from_callback(x.shape, sharding, lambda idx: x[idx])
