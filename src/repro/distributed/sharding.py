"""Sharding rules: logical-axis -> mesh-axis mapping (MaxText-style).

Mesh axes:
  ``pod``   — outer pure-DP axis (cross-DCI gradient all-reduce),
  ``data``  — FSDP: params & optimizer state sharded, all-gather on use,
  ``model`` — TP/EP: heads, ffn, vocab, experts.

Rules are *divisibility-aware*: if a tensor dim is not divisible by the mesh
axis size (e.g. granite's vocab 49155 over model=16, whisper's 12 heads over
model=16) that dim is replicated instead — the framework never relies on
uneven GSPMD padding for weights.  This is what makes every (arch x mesh)
cell in the assignment lower cleanly.

Param-name driven: we map leaf *path names* in the params pytree to logical
specs; batch/sequence specs for activations are provided per shape kind.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape.get(a, 1) for a in axis]))
    return mesh.shape.get(axis, 1)


def maybe(mesh: Mesh, dim_size: int, axis):
    """axis if present in the mesh and dim divides evenly, else None."""
    if isinstance(axis, (tuple, list)):
        axis = tuple(a for a in axis if a in mesh.axis_names)
        if not axis:
            return None
    elif axis is not None and axis not in mesh.axis_names:
        return None
    return axis if dim_size % _axis_size(mesh, axis) == 0 else None


def _leaf_spec(mesh: Mesh, path: str, shape: tuple[int, ...]) -> P:
    """Logical sharding for a parameter leaf, keyed by its tree path.

    Conventions (dims after any leading scan/group/stack axes):
      embed (V, D)            -> (model, data)
      attention wq (D, H, K)  -> (data, model, None)
      attention wk/wv         -> (data, model?, None)   (kv heads often < TP)
      attention wo (H, K, D)  -> (model, None, data)
      mlp w_gate/w_up (D, F)  -> (data, model)
      mlp w_down (F, D)       -> (model, data)
      moe experts (E, D, F)   -> (model, data, None) / w_down (E, F, D)
      ssm w_in (D, E2)        -> (data, model) etc.
      norms / biases / gates  -> replicated
    """
    # strip leading stack axes (groups / encoder layers / expert stacks handled
    # by name)
    nd = len(shape)
    lead = ()
    core = shape
    if "groups" in path or ("encoder" in path and "layers" in path):
        lead = (None,)
        core = shape[1:]
        nd -= 1

    def spec(*axes):
        fixed = tuple(maybe(mesh, core[i], a) for i, a in enumerate(axes))
        return P(*(lead + fixed))

    if path.endswith("embed"):
        return P(maybe(mesh, shape[0], "model"), maybe(mesh, shape[1], "data"))

    name = path.rsplit("/", 1)[-1]
    if name in ("norm1", "norm2", "norm", "final_norm", "a_log", "dt_bias"):
        return P(*(lead + (None,) * nd))

    if name in ("wq", "wk", "wv"):
        if nd == 3:               # attention (D, H, K)
            return spec("data", "model", None)
        return spec("data", "model")  # mlstm 2-D projections (D, d_inner)
    if name == "wo" and nd == 3:
        return spec("model", None, "data")
    if name == "router":
        return spec("data", None)
    if name in ("w_gate", "w_up"):
        if nd == 3:  # (E, D, F) expert-stacked
            return spec("model", "data", None)
        return spec("data", "model")
    if name == "w_down":
        if nd == 3:  # (E, F, D)
            return spec("model", None, "data")
        return spec("model", "data")
    if name in ("w_in", "w_bc", "w_z", "w_i", "w_f", "w_o", "w_dt"):
        return spec("data", "model")
    if name in ("w_out",):
        return spec("model", "data")
    if name in ("w_fgate", "w_igate"):
        return spec("data", None)
    # default: replicate
    return P(*(lead + (None,) * nd))


def _tree_paths(tree: Any) -> Any:
    """Map each leaf to its '/'-joined key path string."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def keystr(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        return "/".join(parts)

    return treedef.unflatten([keystr(kp) for kp, _ in paths])


def param_shardings(mesh: Mesh, params: Any) -> Any:
    """NamedSharding pytree matching a params (or abstract params) pytree."""
    paths = _tree_paths(params)
    return jax.tree.map(
        lambda leaf, p: NamedSharding(mesh, _leaf_spec(mesh, p, leaf.shape)),
        params,
        paths,
    )


# --------------------------------------------------------------------------
# selection preprocessing: ground-set-row mesh
# --------------------------------------------------------------------------

#: mesh axis name carrying the selection ground-set (row) axis
SELECTION_AXIS = "sel"


def selection_mesh(n_devices: int | None = None, *, axis: str = SELECTION_AXIS) -> Mesh:
    """1-D device mesh for sharding the selection ground-set row axis.

    The gram-free selection engines (``core.sharded``) shard the (n, d)
    feature matrix over this axis so one class's ground set can exceed a
    single device's memory; everything else they carry is O(n) and stays
    replicated.  ``n_devices`` truncates to a prefix of ``jax.devices()``
    (useful to keep the shard count a divisor of the padded class sizes);
    the default uses every device.  On CPU, force a multi-device mesh
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

    **Multi-host:** ``jax.devices()`` is the *global* device list, so after
    ``distributed.multihost.initialize()`` this mesh spans every process and
    the same ``shard_map`` programs run their ring ``ppermute``/``psum``
    across hosts — no engine changes.  The engine wrappers detect a
    process-spanning mesh (``multihost.mesh_spans_processes``) and commit
    inputs to the global sharding via ``multihost.global_put`` (each host
    fills its addressable shards from its own replicated host copy); the
    replicated ``out_specs=P(None)`` results are host-readable on every
    process.  A 2-process × 1-device mesh compiles the same logical program
    as a 1-process × 2-device mesh, which is what makes the two runs'
    selection trajectories bit-identical (the multihost test suite pins
    this).
    """
    devs = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devs):
            raise ValueError(
                f"n_devices={n_devices} out of range [1, {len(devs)}]"
            )
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (axis,))


# --------------------------------------------------------------------------
# activations / inputs
# --------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes carrying the batch dim: ('pod','data') when pod exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_spec(mesh: Mesh, batch: int, extra_dims: int) -> P:
    axes = batch_axes(mesh)
    b_axis = axes if batch % _axis_size(mesh, axes) == 0 else (
        "data" if batch % _axis_size(mesh, "data") == 0 else None
    )
    return P(b_axis, *([None] * extra_dims))


def cache_spec(mesh: Mesh, batch: int, seq: int, heads: int) -> P:
    """KV-cache (B, S, H, D): shard batch if divisible, else sequence (SP)."""
    axes = batch_axes(mesh)
    if batch % _axis_size(mesh, axes) == 0:
        return P(axes, None, maybe(mesh, heads, "model"), None)
    if batch % _axis_size(mesh, "data") == 0 and _axis_size(mesh, "data") > 1 and batch > 1:
        return P("data", None, maybe(mesh, heads, "model"), None)
    # sequence parallelism: long-context decode with tiny batch
    return P(None, maybe(mesh, seq, "data"), maybe(mesh, heads, "model"), None)


def ssm_state_spec(mesh: Mesh, batch: int, heads: int) -> P:
    """SSM state (B, H, N, P): batch over data if divisible else heads/model."""
    axes = batch_axes(mesh)
    if batch % _axis_size(mesh, axes) == 0:
        return P(axes, maybe(mesh, heads, "model"), None, None)
    return P(None, maybe(mesh, heads, "model"), None, None)


# --------------------------------------------------------------------------
# in-model activation constraints (ambient-mesh aware; no-op without a mesh)
# --------------------------------------------------------------------------

def _ambient_mesh():
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def constrain(x, *dim_axes):
    """with_sharding_constraint against the ambient mesh.

    ``dim_axes``: one entry per dim — "batch" (pod+data), a mesh axis name,
    or None.  Divisibility-checked; silently a no-op outside a mesh context
    (smoke tests / single device).  This pins the Megatron/FSDP activation
    layout so GSPMD cannot "helpfully" replicate the batch axis to avoid
    weight all-gathers (observed: it will, and it costs 16x redundant
    compute).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    assert len(dim_axes) == x.ndim, (dim_axes, x.shape)
    spec = []
    for dim, ax in zip(x.shape, dim_axes):
        if ax is None:
            spec.append(None)
            continue
        if ax == "batch":
            ax = batch_axes(mesh)
            if dim % _axis_size(mesh, ax) != 0:
                ax = "data" if dim % _axis_size(mesh, "data") == 0 else None
        else:
            if ax not in mesh.axis_names or dim % _axis_size(mesh, ax) != 0:
                ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, P(*spec))
