"""Fault tolerance & elasticity utilities.

Pieces (composed by the Trainer):
  * ``StragglerMonitor`` — per-step wall-time EWMA with z-score flagging of
    slow steps (on real fleets: per-host step times gathered through a
    lightweight all-gather; here: the local signal and the policy).
  * ``restart_state`` — deterministic recovery: the trainer's RNG, the MILO
    selector's epoch window, and the data-pipeline cursor are all pure
    functions of (seed, step), so resuming from checkpoint step N replays
    the exact same sample order with zero coordination.
  * ``elastic_plan`` — given old/new device counts, decides the new mesh
    shape and whether global batch is preserved via grad-accumulation
    (device loss => more microbatches, not a silently smaller batch).
"""
from __future__ import annotations

import dataclasses
import time


class HostLossError(RuntimeError):
    """A peer host is dead or unreachable (missed heartbeats, an unreached
    coordination barrier, or a host manifest that never arrived during a
    two-phase distributed checkpoint).

    ``hosts`` names the processes believed lost when known.  The recovery
    contract: the launcher restarts with the surviving host count,
    ``elastic_plan`` re-meshes deterministically, and the run resumes from
    the last *globally*-valid checkpoint (``latest_valid_step`` skips any
    step missing a host's shards).
    """

    def __init__(self, message: str, *, hosts: tuple[int, ...] | list[int] = ()):
        super().__init__(message)
        self.hosts = tuple(int(h) for h in hosts)


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than mean + z * std."""

    alpha: float = 0.1
    z_threshold: float = 3.0
    warmup_steps: int = 5

    def __post_init__(self):
        self._mean = 0.0
        self._var = 0.0
        self._n = 0
        self._last_start: float | None = None
        self.flagged: list[tuple[int, float]] = []

    def start(self) -> None:
        self._last_start = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Record the step; return True if it is a straggler."""
        assert self._last_start is not None, "stop() without start()"
        dt = time.perf_counter() - self._last_start
        self._last_start = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        self._n += 1
        if self._n <= self.warmup_steps:
            # Welford running mean/variance over the warmup window.  The old
            # ``(mean + dt) / 2`` halved every previous observation's weight
            # each step — an exponentially-biased average that let one slow
            # early step dominate the baseline the z-score compares against.
            d = dt - self._mean
            self._mean += d / self._n
            self._var += (d * (dt - self._mean) - self._var) / self._n
            return False
        slow = False
        std = self._var ** 0.5
        if std > 0 and (dt - self._mean) / std > self.z_threshold:
            slow = True
            self.flagged.append((step, dt))
        d = dt - self._mean
        self._mean += self.alpha * d
        self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        return slow

    @property
    def mean_step_time(self) -> float:
        return self._mean


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    grad_accum: int           # microbatches per step to preserve global batch
    note: str


def elastic_plan(
    n_devices: int,
    *,
    model_parallel: int,
    global_batch: int,
    microbatch_per_replica: int,
) -> ElasticPlan:
    """Choose (data, model) mesh + grad-accum for the devices we actually have.

    model_parallel is fixed by the architecture's memory footprint; the data
    axis absorbs whatever devices remain.  If the surviving data axis cannot
    cover the global batch in one shot, we keep the *global batch constant*
    by accumulating gradients over more microbatches (semantics-preserving
    elasticity — loss curves stay comparable across restarts).
    """
    if n_devices % model_parallel:
        raise ValueError(
            f"{n_devices} devices not divisible by model_parallel={model_parallel}"
        )
    data = n_devices // model_parallel
    per_step = data * microbatch_per_replica
    if global_batch % per_step:
        # shrink microbatch until it divides
        mb = microbatch_per_replica
        while mb > 1 and global_batch % (data * mb):
            mb -= 1
        per_step = data * mb
        if global_batch % per_step:
            raise ValueError(
                f"global batch {global_batch} cannot be tiled on {data}-way data axis"
            )
    accum = global_batch // per_step
    return ElasticPlan(
        mesh_shape=(data, model_parallel),
        grad_accum=accum,
        note=f"{n_devices} devices -> mesh (data={data}, model={model_parallel}), "
             f"{accum} microbatch(es) to hold global_batch={global_batch}",
    )


def restart_state(seed: int, step: int, steps_per_epoch: int) -> dict:
    """Deterministic cursor for resume: everything derives from (seed, step).

    ``data_seed`` is the epoch's permutation seed exactly as
    ``data.pipeline.Pipeline._permuted`` derives it (``seed * 1_000_003 +
    epoch``) — the two MUST agree, or a restart driven by this cursor would
    replay a different batch order than the run it is resuming.  The old
    independent derivation (``seed + epoch * 1_000_003``) disagreed with the
    pipeline for every ``seed > 0``.
    """
    if steps_per_epoch < 1:
        raise ValueError(f"steps_per_epoch must be >= 1, got {steps_per_epoch}")
    epoch = step // steps_per_epoch
    return {
        "epoch": epoch,
        "step_in_epoch": step % steps_per_epoch,
        "data_seed": seed * 1_000_003 + epoch,
    }
