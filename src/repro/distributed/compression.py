"""Gradient compression for the slow (cross-pod / DCI) axis.

Two schemes, both with *error feedback* so compression noise does not bias
the optimizer ([Seide'14, Karimireddy'19]):

  * int8 stochastic-uniform quantization (8x over f32, 4x over bf16),
  * top-k magnitude sparsification (configurable density).

The trainer applies compression only to the cross-pod all-reduce: grads are
reduce-scattered at full precision inside a pod (fast ICI), compressed for
the pod axis, decompressed, and applied.  All ops are jit-compatible.

``CheckedPayload`` adds an integrity layer for payloads that actually cross
a wire: the int8 tensor carries a position-weighted int32 checksum computed
*before* the collective and re-verified *after* it, so a corrupted transfer
(bit flips, torn buffers) is detected instead of silently skewing every
gain downstream.  Inside a trace the mismatch poisons the decompressed
value with NaN (``decompress_checked``); on the host,
``check_payload`` raises ``CompressionIntegrityError``.  The sharded
selection engines (``core.sharded``) use this for their cross-host ring
psums — with an exactness escape hatch (``compress=None``) that leaves the
collective bit-identical to the uncompressed path.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Int8Compressed(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # per-tensor scale ()


def int8_compress(x: jax.Array, key: jax.Array | None = None) -> Int8Compressed:
    """Symmetric per-tensor int8 quantization (stochastic if key given)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    y = x32 / scale
    if key is not None:
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return Int8Compressed(q, scale)


def int8_decompress(c: Int8Compressed, dtype=jnp.float32) -> jax.Array:
    return (c.q.astype(jnp.float32) * c.scale).astype(dtype)


def topk_compress(x: jax.Array, density: float) -> tuple[jax.Array, jax.Array]:
    """Keep the top ``density`` fraction by magnitude; returns (values, idx)."""
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * density))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(vals: jax.Array, idx: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    out = jnp.zeros((n,), jnp.float32).at[idx].set(vals)
    return out.reshape(shape).astype(dtype)


class CompressionIntegrityError(RuntimeError):
    """A compressed payload's checksum disagrees with its contents — the
    collective (or storage) corrupted it in flight."""


class CheckedPayload(NamedTuple):
    q: jax.Array         # int8 payload
    scale: jax.Array     # per-tensor scale ()
    checksum: jax.Array  # () int32 position-weighted fold of ``q``


# odd multiplier (Knuth) so equal-magnitude flips at different positions
# cannot cancel; int32 arithmetic wraps, which is exactly what we want
_CHECKSUM_MULT = 2654435761 & 0x7FFFFFFF


def payload_checksum(q: jax.Array) -> jax.Array:
    """Deterministic int32 checksum of an int8 payload (jit-compatible).

    Position-weighted so both value flips and transpositions change the
    fold; pure integer math, so the pre-send and post-receive computations
    are bit-identical on every backend.
    """
    flat = q.reshape(-1).astype(jnp.int32)
    weights = (jnp.arange(flat.shape[0], dtype=jnp.int32) * _CHECKSUM_MULT) | 1
    return jnp.sum(flat * weights, dtype=jnp.int32)


def int8_compress_checked(x: jax.Array, key: jax.Array | None = None) -> CheckedPayload:
    """``int8_compress`` plus the integrity checksum, stamped pre-send."""
    c = int8_compress(x, key)
    return CheckedPayload(c.q, c.scale, payload_checksum(c.q))


def payload_ok(p: CheckedPayload) -> jax.Array:
    """Traced bool: does the payload still match its checksum?"""
    return payload_checksum(p.q) == p.checksum


def decompress_checked(p: CheckedPayload, dtype=jnp.float32) -> jax.Array:
    """Decompress with in-trace integrity enforcement.

    On checksum mismatch every element becomes NaN — corruption cannot skew
    results by a plausible-looking epsilon; it wrecks them visibly, and the
    host-side consumer (``core.sharded``'s wrappers, the health guard)
    raises on the non-finite output.
    """
    val = int8_decompress(Int8Compressed(p.q, p.scale), dtype)
    return jnp.where(payload_ok(p), val, jnp.full_like(val, jnp.nan))


def check_payload(p: CheckedPayload) -> None:
    """Host-side (eager) integrity check; raises ``CompressionIntegrityError``."""
    if not bool(payload_ok(p)):
        raise CompressionIntegrityError(
            "compressed payload failed its integrity checksum "
            f"(stored {int(p.checksum)}, recomputed {int(payload_checksum(p.q))})"
        )


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching grads


def init_error_feedback(grads: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads))


def compress_with_feedback(
    grads: Any,
    ef: ErrorFeedbackState,
    *,
    scheme: str = "int8",
    density: float = 0.01,
    key: jax.Array | None = None,
) -> tuple[Any, ErrorFeedbackState]:
    """Compress+decompress each leaf, accumulating the residual locally.

    Returns the *decompressed* gradient (what the collective would deliver)
    and the new residual state.  In deployment the compressed payload is what
    crosses the DCI; the math here is exactly what every pod applies.
    """

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if scheme == "int8":
            c = int8_compress(g32, key)
            out = int8_decompress(c)
        elif scheme == "topk":
            vals, idx = topk_compress(g32, density)
            out = topk_decompress(vals, idx, g32.shape)
        else:
            raise ValueError(scheme)
        return out.astype(g.dtype), g32 - out

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(ef.residual)
    outs, resids = [], []
    for g, r in zip(flat_g, flat_r):
        o, nr = one(g, r)
        outs.append(o)
        resids.append(nr)
    return (
        jax.tree_util.tree_unflatten(tdef, outs),
        ErrorFeedbackState(jax.tree_util.tree_unflatten(tdef, resids)),
    )
