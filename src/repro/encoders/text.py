"""Sentence-transformer-style text encoder (paper: all-distilroberta-v1).

Mean-pooled final-layer token embeddings, as in SBERT — the paper's text
feature representation.  Architecture in JAX; weights are deployment
artifacts (offline container), with the proxy path covering validation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, init_embedding, layer_norm


@dataclasses.dataclass(frozen=True)
class TextEncoderConfig:
    vocab_size: int = 50265
    max_len: int = 512
    d_model: int = 768
    num_layers: int = 6      # distilroberta
    num_heads: int = 12
    d_ff: int = 3072


def init_text_encoder(key: jax.Array, cfg: TextEncoderConfig) -> dict:
    ke, kp, kl = jax.random.split(key, 3)

    def init_layer(lk):
        k1, k2, k3, k4 = jax.random.split(lk, 4)
        return {
            "ln1_s": jnp.ones((cfg.d_model,)), "ln1_b": jnp.zeros((cfg.d_model,)),
            "wqkv": init_dense(k1, cfg.d_model, 3 * cfg.d_model, jnp.float32),
            "wo": init_dense(k2, cfg.d_model, cfg.d_model, jnp.float32),
            "ln2_s": jnp.ones((cfg.d_model,)), "ln2_b": jnp.zeros((cfg.d_model,)),
            "w1": init_dense(k3, cfg.d_model, cfg.d_ff, jnp.float32),
            "w2": init_dense(k4, cfg.d_ff, cfg.d_model, jnp.float32),
        }

    return {
        "tok": init_embedding(ke, cfg.vocab_size, cfg.d_model, jnp.float32),
        "pos": jax.random.normal(kp, (1, cfg.max_len, cfg.d_model)) * 0.02,
        "layers": jax.vmap(init_layer)(jax.random.split(kl, cfg.num_layers)),
        "ln_f_s": jnp.ones((cfg.d_model,)), "ln_f_b": jnp.zeros((cfg.d_model,)),
    }


def text_encode(params: dict, tokens: jax.Array, cfg: TextEncoderConfig,
                mask: jax.Array | None = None) -> jax.Array:
    """tokens: (B, S) int32 -> (B, d_model) mean-pooled embeddings."""
    b, s = tokens.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    x = jnp.take(params["tok"], tokens, axis=0) + params["pos"][:, :s]

    def body(x, lp):
        h = layer_norm(x, lp["ln1_s"], lp["ln1_b"])
        d = x.shape[-1]
        nh = cfg.num_heads
        qkv = dense(h, lp["wqkv"]).reshape(b, s, 3, nh, d // nh)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / ((d // nh) ** 0.5)
        logits = jnp.where(mask[:, None, None, :] > 0, logits, -1e30)
        a = jax.nn.softmax(logits, -1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, d)
        x = x + dense(attn, lp["wo"])
        h = layer_norm(x, lp["ln2_s"], lp["ln2_b"])
        x = x + dense(jax.nn.gelu(dense(h, lp["w1"])), lp["w2"])
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = layer_norm(x, params["ln_f_s"], params["ln_f_b"])
    denom = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
    return (x * mask[..., None]).sum(1) / denom  # SBERT mean pooling
