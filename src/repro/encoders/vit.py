"""DINO-style ViT feature encoder (paper's vision encoder, in JAX).

The paper uses DINO-ViT-B/16's final-layer CLS embedding as the frozen
feature representation.  We implement the architecture; pretrained weights
are a deployment artifact (this container is offline) — the proxy-encoder
path (paper App. H.2) covers validation, and tests exercise shape/semantics
with random weights.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, layer_norm


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image_size: int = 224
    patch_size: int = 16
    d_model: int = 768
    num_layers: int = 12
    num_heads: int = 12
    d_ff: int = 3072

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2


def init_vit(key: jax.Array, cfg: ViTConfig) -> dict:
    kp, kc, kpos, kl, kn = jax.random.split(key, 5)
    patch_dim = 3 * cfg.patch_size ** 2

    def init_layer(lk):
        k1, k2, k3, k4 = jax.random.split(lk, 4)
        return {
            "ln1_s": jnp.ones((cfg.d_model,)), "ln1_b": jnp.zeros((cfg.d_model,)),
            "wqkv": init_dense(k1, cfg.d_model, 3 * cfg.d_model, jnp.float32),
            "wo": init_dense(k2, cfg.d_model, cfg.d_model, jnp.float32),
            "ln2_s": jnp.ones((cfg.d_model,)), "ln2_b": jnp.zeros((cfg.d_model,)),
            "w1": init_dense(k3, cfg.d_model, cfg.d_ff, jnp.float32),
            "w2": init_dense(k4, cfg.d_ff, cfg.d_model, jnp.float32),
        }

    return {
        "patch_proj": init_dense(kp, patch_dim, cfg.d_model, jnp.float32),
        "cls": jax.random.normal(kc, (1, 1, cfg.d_model)) * 0.02,
        "pos": jax.random.normal(kpos, (1, cfg.n_patches + 1, cfg.d_model)) * 0.02,
        "layers": jax.vmap(init_layer)(jax.random.split(kl, cfg.num_layers)),
        "ln_f_s": jnp.ones((cfg.d_model,)), "ln_f_b": jnp.zeros((cfg.d_model,)),
    }


def _mha(p, x, n_heads):
    b, s, d = x.shape
    qkv = dense(x, p["wqkv"]).reshape(b, s, 3, n_heads, d // n_heads)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / ((d // n_heads) ** 0.5)
    a = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, s, d)
    return dense(out, p["wo"])


def vit_encode(params: dict, images: jax.Array, cfg: ViTConfig) -> jax.Array:
    """images: (B, H, W, 3) float -> (B, d_model) CLS embeddings."""
    b = images.shape[0]
    p = cfg.patch_size
    n = cfg.image_size // p
    patches = images.reshape(b, n, p, n, p, 3).transpose(0, 1, 3, 2, 4, 5).reshape(b, n * n, -1)
    x = dense(patches, params["patch_proj"])
    x = jnp.concatenate([jnp.broadcast_to(params["cls"], (b, 1, cfg.d_model)), x], axis=1)
    x = x + params["pos"]

    def body(x, lp):
        h = layer_norm(x, lp["ln1_s"], lp["ln1_b"])
        x = x + _mha(lp, h, cfg.num_heads)
        h = layer_norm(x, lp["ln2_s"], lp["ln2_b"])
        x = x + dense(jax.nn.gelu(dense(h, lp["w1"])), lp["w2"])
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = layer_norm(x, params["ln_f_s"], params["ln_f_b"])
    return x[:, 0]  # CLS
