"""Proxy feature encoder (paper App. H.2): a small model trained to
convergence on the target dataset; penultimate activations become the
feature space for MILO's similarity kernel.

Used when the zero-shot pretrained encoders underperform (checked by linear
probing), and in this offline container as the *validated* encoder path for
every reproduction benchmark.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, init_dense


@dataclasses.dataclass
class ProxyEncoder:
    """Two-layer MLP classifier; features = penultimate layer."""

    d_in: int
    n_classes: int
    d_hidden: int = 128
    epochs: int = 60
    lr: float = 0.05
    seed: int = 0

    def fit(self, x: np.ndarray, y: np.ndarray) -> "ProxyEncoder":
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        params = {
            "w1": init_dense(k1, self.d_in, self.d_hidden, jnp.float32),
            "b1": jnp.zeros((self.d_hidden,)),
            "w2": init_dense(k2, self.d_hidden, self.n_classes, jnp.float32),
            "b2": jnp.zeros((self.n_classes,)),
        }
        xj, yj = jnp.asarray(x), jnp.asarray(y)

        def loss(p):
            h = jnp.tanh(dense(xj, p["w1"]) + p["b1"])
            logits = dense(h, p["w2"]) + p["b2"]
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, yj[:, None], 1))

        @jax.jit
        def step(p):
            l, g = jax.value_and_grad(loss)(p)
            return jax.tree.map(lambda a, b: a - self.lr * b, p, g), l

        for _ in range(self.epochs):
            params, _ = step(params)
        self.params = params
        return self

    def encode(self, x: np.ndarray) -> np.ndarray:
        h = jnp.tanh(dense(jnp.asarray(x), self.params["w1"]) + self.params["b1"])
        return np.asarray(h)

    def linear_probe_accuracy(self, x: np.ndarray, y: np.ndarray) -> float:
        h = jnp.tanh(dense(jnp.asarray(x), self.params["w1"]) + self.params["b1"])
        logits = dense(h, self.params["w2"]) + self.params["b2"]
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))
