"""Sharded, atomic, async, *crash-safe* checkpointing with reshard-on-restore.

Design (1000+-node posture, §5 of DESIGN.md):
  * A checkpoint is a directory ``step_<N>/`` holding one ``shard_<i>.npz``
    per host plus a ``manifest.json`` (tree structure, global shapes, dtypes,
    step, per-file sha256 checksums, and free-form ``extra`` run metadata),
    written LAST.
  * Writes go to ``step_<N>.tmp/`` and are atomically renamed; shard and
    manifest files are fsync'd *before* the rename so a machine crash can
    never publish a directory whose data pages were still in the page cache
    (rename is metadata — without the fsync a torn shard can become visible
    under a completed-looking name).
  * Every file carries a sha256 in the manifest.  ``validate_step`` replays
    them (plus shard-count and manifest-parse checks) and raises
    ``CheckpointCorruptionError`` on any damage; ``latest_valid_step`` walks
    newest-first and returns the first checkpoint that passes, so restart
    logic transparently skips truncated / corrupted / half-lost steps.
    ``restore`` validates by default before reading.
  * ``save_async`` snapshots device arrays to host memory synchronously
    (cheap) and does file I/O on a background thread so the training loop
    keeps stepping.  ``wait()`` re-raises the worker's exception — an async
    save failure is a failed save, not a warning.  In-flight steps are
    registered before the thread starts and excluded from garbage
    collection, so a concurrent ``save``'s GC can never delete a checkpoint
    whose write has not finished (the GC/async race).
  * ``restore`` takes a *target sharding* pytree: arrays are re-laid-out onto
    whatever mesh the restarted job has (elastic up/down-scaling: the new
    mesh may have a different device count).
  * ``keep_last`` old checkpoints are garbage-collected after a successful
    save; the keep window counts in-flight steps so a burst of overlapping
    saves cannot over-delete.

On a single-process CPU container every array is fully addressable so there
is exactly one shard file; that path is byte-for-byte the pre-multihost
format-2 protocol.

**Multi-host (two-phase coordinated commit).**  With ``process_count > 1``
every host participates in one distributed checkpoint per step:

  1. *Rendezvous + staging*: all hosts meet at a named barrier, then the
     coordinator (process 0) alone resets ``step_<N>.tmp/`` and a second
     barrier releases the writers — a crashed earlier attempt's stale
     staging can never mix with this one.
  2. *Phase 1 — local durability*: every host fsyncs its own
     ``shard_<i>.npz`` plus a per-host manifest ``host_<i>.json`` carrying
     its shard checksums (atomic rename, so the coordinator never parses a
     torn one).
  3. *Phase 2 — validate + atomic publish*: the coordinator waits for all
     host manifests (a host that never delivers ⇒ ``HostLossError``),
     re-hashes every shard against its host's checksum, merges them into
     ONE global ``manifest.json`` (format 3, ``num_shards =
     process_count``), fsyncs it, and atomically renames the directory.
     Non-coordinators block until the publication appears (a coordinator
     that never publishes ⇒ ``HostLossError``).

  A crash of any host at any instant therefore publishes a complete global
  checkpoint or nothing: before the rename there is no ``step_<N>/`` at
  all; after it the manifest provably covers every host's shard.
  ``latest_valid_step`` validates the global manifest's checksums and shard
  count, so a step missing (or holding a torn copy of) ANY host's shards is
  skipped on every host.  GC runs on the coordinator only.  Real
  multi-process runs coordinate over the jax coordination service
  (``multihost.RuntimeBarrier``); in-process simulated tests inject a
  ``multihost.FileBarrier``.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.distributed.fault_tolerance import HostLossError

_STEP_RE = re.compile(r"^step_(\d+)$")

#: manifest format carrying per-file checksums + extra run metadata
MANIFEST_FORMAT = 2

#: format 3 = a coordinator-published global manifest merging per-host
#: shard checksums (two-phase multi-host commit); single-host checkpoints
#: keep writing format 2 so their manifests are byte-compatible with PR 7
MULTIHOST_MANIFEST_FORMAT = 3


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint directory exists but fails validation (torn shard,
    unparseable manifest, missing file, checksum mismatch)."""


def _sha256_file(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def _fsync_write(path: str, write_fn) -> None:
    """Write ``path`` through ``write_fn(file)`` and fsync it to disk."""
    with open(path, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())


def _flatten(tree: Any) -> tuple[list[str], list[Any]]:
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for kp, leaf in paths:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        names.append("/".join(parts) if parts else "_root")
        leaves.append(leaf)
    return names, leaves


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        keep_last: int = 3,
        process_index: int | None = None,
        process_count: int | None = None,
        barrier: Any | None = None,
        barrier_timeout: float = 120.0,
        poll_interval: float = 0.02,
    ):
        """``process_index``/``process_count`` default to the jax runtime's
        (overridable so the two-phase protocol is testable in one process);
        ``barrier`` is any object with ``wait(name)`` — defaults to the
        coordination-service barrier when ``jax.distributed`` is live.
        ``barrier_timeout`` bounds every wait a dead peer could hang:
        barriers, the coordinator's host-manifest collection, and the
        non-coordinators' publication poll — each raises ``HostLossError``
        on expiry."""
        self.directory = directory
        self.keep_last = keep_last
        self.process_index = (
            jax.process_index() if process_index is None else int(process_index)
        )
        self.process_count = max(
            1, jax.process_count() if process_count is None else int(process_count)
        )
        self.barrier_timeout = float(barrier_timeout)
        self.poll_interval = float(poll_interval)
        self._barrier = barrier
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        # guards _inflight and serializes GC decisions across the async
        # worker and concurrent synchronous saves
        self._lock = threading.Lock()
        self._inflight: set[int] = set()

    def _get_barrier(self) -> Any:
        if self._barrier is None:
            from repro.distributed import multihost

            self._barrier = multihost.default_barrier(self.barrier_timeout)
            if self._barrier is None:
                raise RuntimeError(
                    f"process_count={self.process_count} needs a coordination "
                    "barrier: initialize jax.distributed "
                    "(multihost.initialize()) or inject barrier= explicitly"
                )
        return self._barrier

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> str:
        """Synchronous atomic save; returns the checkpoint path.

        ``extra`` is free-form JSON-able run metadata stored in the manifest
        (e.g. the saving run's device count, for elastic-restart planning).
        """
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        with self._lock:
            self._inflight.add(step)
        try:
            return self._write(step, host_tree, extra)
        finally:
            with self._lock:
                self._inflight.discard(step)

    def save_async(self, step: int, tree: Any, *, extra: dict | None = None) -> None:
        """Snapshot to host now, write on a background thread.

        The step is registered in-flight *before* the thread starts, so a
        concurrent save's garbage collection can never delete it mid-write.
        """
        self.wait()  # one in-flight async save at a time
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        with self._lock:
            self._inflight.add(step)

        def work():
            try:
                self._write(step, host_tree, extra)
            except BaseException as e:  # re-raised on next wait()
                self._error = e
            finally:
                with self._lock:
                    self._inflight.discard(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight async save and RE-RAISE its exception, if any.

        A swallowed write error would let training continue believing a
        checkpoint exists; the failure must surface on the training thread.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any, extra: dict | None = None) -> str:
        if self.process_count > 1:
            return self._write_multihost(step, host_tree, extra)
        names, leaves = _flatten(host_tree)
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        shard_name = "shard_0.npz"
        shard_path = os.path.join(tmp, shard_name)
        _fsync_write(shard_path, lambda f: np.savez(
            f, **{n: l for n, l in zip(names, leaves)}))
        manifest = {
            "format": MANIFEST_FORMAT,
            "step": step,
            "time": time.time(),
            "num_shards": 1,
            "leaves": {n: {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
                       for n, l in zip(names, leaves)},
            # checksums cover every data file; the manifest itself is the
            # completion marker (written+fsync'd last, then the dir rename)
            "checksums": {shard_name: _sha256_file(shard_path)},
            "extra": dict(extra) if extra else {},
        }
        _fsync_write(os.path.join(tmp, "manifest.json"),
                     lambda f: f.write(json.dumps(manifest).encode()))
        self._publish(tmp, final)
        self._gc()
        return final

    def _publish(self, tmp: str, final: str) -> None:
        """Atomically rename the staging dir into place, durably."""
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # fsync the parent directory so the rename itself is durable
        dirfd = os.open(self.directory, os.O_RDONLY)
        try:
            os.fsync(dirfd)
        finally:
            os.close(dirfd)

    # -- multi-host two-phase commit ----------------------------------------

    def _write_multihost(self, step: int, host_tree: Any,
                         extra: dict | None = None) -> str:
        names, leaves = _flatten(host_tree)
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        bar = self._get_barrier()
        coordinator = self.process_index == 0
        # rendezvous BEFORE touching the staging dir: once every host is
        # here, nobody can still be writing into a previous attempt's tmp,
        # so the coordinator's reset cannot race a live writer
        bar.wait(f"ckpt_{step}_enter")
        if coordinator:
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
        bar.wait(f"ckpt_{step}_staged")
        # phase 1: every host fsyncs its own shard + checksummed host
        # manifest (atomic rename — the coordinator never parses a torn one)
        shard_name = f"shard_{self.process_index}.npz"
        shard_path = os.path.join(tmp, shard_name)
        _fsync_write(shard_path, lambda f: np.savez(
            f, **{n: l for n, l in zip(names, leaves)}))
        host_manifest = {
            "process_index": self.process_index,
            "checksums": {shard_name: _sha256_file(shard_path)},
            "leaves": {n: {"shape": list(np.shape(l)),
                           "dtype": str(np.asarray(l).dtype)}
                       for n, l in zip(names, leaves)},
        }
        hm_final = os.path.join(tmp, f"host_{self.process_index}.json")
        _fsync_write(hm_final + ".tmp",
                     lambda f: f.write(json.dumps(host_manifest).encode()))
        os.replace(hm_final + ".tmp", hm_final)
        if not coordinator:
            # phase 2 (follower): wait for the coordinator's publication —
            # its absence past the deadline means the coordinator died
            self._await_publication(final, step)
            return final
        # phase 2 (coordinator): collect every host's manifest, re-hash
        # every shard against its host's checksum, publish ONE global
        # manifest — so the rename only ever exposes a complete checkpoint
        host_manifests = self._collect_host_manifests(tmp)
        checksums: dict[str, str] = {}
        leaves_meta: dict[str, Any] = {}
        for hm in host_manifests:
            for fn, want in hm["checksums"].items():
                got = _sha256_file(os.path.join(tmp, fn))
                if got != want:
                    raise CheckpointCorruptionError(
                        f"{tmp}: host {hm['process_index']} shard {fn} "
                        f"checksum mismatch before publish "
                        f"(host manifest {want[:12]}…, file {got[:12]}…)"
                    )
                checksums[fn] = want
            leaves_meta.update(hm["leaves"])
        manifest = {
            "format": MULTIHOST_MANIFEST_FORMAT,
            "step": step,
            "time": time.time(),
            "num_shards": self.process_count,
            "hosts": sorted(hm["process_index"] for hm in host_manifests),
            "leaves": leaves_meta,
            "checksums": checksums,
            "extra": dict(extra) if extra else {},
        }
        _fsync_write(os.path.join(tmp, "manifest.json"),
                     lambda f: f.write(json.dumps(manifest).encode()))
        self._publish(tmp, final)
        self._gc()  # coordinator-only: followers never delete checkpoints
        return final

    def _collect_host_manifests(self, tmp: str) -> list[dict]:
        """Coordinator: poll until every host's manifest exists and parses.

        A host that never delivers within ``barrier_timeout`` is presumed
        dead — ``HostLossError`` names it, nothing is published, and the
        previous checkpoint remains the newest valid step everywhere.
        """
        deadline = time.monotonic() + self.barrier_timeout
        want = set(range(self.process_count))
        have: dict[int, dict] = {}
        while True:
            for i in sorted(want - set(have)):
                path = os.path.join(tmp, f"host_{i}.json")
                try:
                    with open(path) as f:
                        have[i] = json.load(f)
                except (FileNotFoundError, json.JSONDecodeError, OSError):
                    continue
            if set(have) == want:
                return [have[i] for i in sorted(have)]
            if time.monotonic() > deadline:
                missing = sorted(want - set(have))
                raise HostLossError(
                    f"distributed checkpoint: host manifest(s) from "
                    f"{missing} never arrived within {self.barrier_timeout}s "
                    "— publishing nothing",
                    hosts=missing,
                )
            time.sleep(self.poll_interval)

    def _await_publication(self, final: str, step: int) -> None:
        """Follower: block until the coordinator's atomic publish appears."""
        deadline = time.monotonic() + self.barrier_timeout
        while True:
            try:
                with open(os.path.join(final, "manifest.json")) as f:
                    if int(json.load(f).get("step", -1)) == step:
                        return
            except (FileNotFoundError, NotADirectoryError,
                    json.JSONDecodeError, OSError):
                pass
            if time.monotonic() > deadline:
                raise HostLossError(
                    f"distributed checkpoint step {step}: coordinator never "
                    f"published within {self.barrier_timeout}s — presumed "
                    "dead",
                    hosts=[0],
                )
            time.sleep(self.poll_interval)

    def _gc(self) -> None:
        if not self.keep_last:
            return
        with self._lock:
            inflight = set(self._inflight)
        steps = self.all_steps()
        # the keep window is computed over completed AND in-flight steps so
        # overlapping saves cannot over-delete, and an in-flight step is
        # never a deletion candidate whatever its age
        known = sorted(set(steps) | inflight)
        keep = set(known[-self.keep_last:]) | inflight
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                              ignore_errors=True)

    # -- validation ---------------------------------------------------------

    def manifest(self, step: int) -> dict:
        """Parse and return the manifest of checkpoint ``step`` (raises
        ``CheckpointCorruptionError`` if missing or unparseable)."""
        path = os.path.join(self.directory, f"step_{step}", "manifest.json")
        try:
            with open(path) as f:
                return json.load(f)
        except FileNotFoundError:
            raise CheckpointCorruptionError(f"{path}: manifest missing")
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as e:
            raise CheckpointCorruptionError(f"{path}: manifest unreadable ({e})")

    def validate_step(self, step: int) -> dict:
        """Full integrity check of one checkpoint; returns its manifest.

        Raises ``CheckpointCorruptionError`` when the manifest is torn, a
        shard file is missing, or a file's sha256 disagrees with the
        manifest — every way a crash, a lost page, or silent media
        corruption can damage a published checkpoint.  Format-1 manifests
        (no checksums) validate on shard presence alone.
        """
        path = os.path.join(self.directory, f"step_{step}")
        manifest = self.manifest(step)
        shards = [f for f in os.listdir(path)
                  if f.startswith("shard_") and f.endswith(".npz")]
        want_shards = int(manifest.get("num_shards", 1))
        if len(shards) < want_shards:
            raise CheckpointCorruptionError(
                f"{path}: {len(shards)} shard file(s) present, manifest "
                f"promises {want_shards}"
            )
        for fn, want in manifest.get("checksums", {}).items():
            fpath = os.path.join(path, fn)
            if not os.path.exists(fpath):
                raise CheckpointCorruptionError(f"{path}: {fn} missing")
            got = _sha256_file(fpath)
            if got != want:
                raise CheckpointCorruptionError(
                    f"{path}: checksum mismatch on {fn} "
                    f"(manifest {want[:12]}…, file {got[:12]}…)"
                )
        return manifest

    def is_valid_step(self, step: int) -> bool:
        try:
            self.validate_step(step)
            return True
        except CheckpointCorruptionError:
            return False

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        """Steps with a manifest on disk — *candidates*, not guarantees;
        use ``latest_valid_step``/``validate_step`` before trusting one."""
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def latest_valid_step(self) -> int | None:
        """Newest step that passes full validation; torn / corrupted /
        partially deleted checkpoints are skipped, so restart logic always
        lands on a checkpoint that will actually restore."""
        for step in reversed(self.all_steps()):
            if self.is_valid_step(step):
                return step
        return None

    def restore(
        self,
        step: int,
        target: Any,
        shardings: Any | None = None,
        *,
        verify: bool = True,
    ) -> Any:
        """Restore into the structure of ``target``; re-shard if asked.

        ``target`` provides the pytree structure (values ignored);
        ``shardings`` (same structure, NamedSharding leaves) lays leaves out
        on the current mesh — which may differ from the saving mesh
        (elastic restart).  With ``verify`` (default) the checkpoint's
        checksums are validated first, so corruption surfaces as
        ``CheckpointCorruptionError`` instead of a garbage state.
        """
        path = os.path.join(self.directory, f"step_{step}")
        names, _ = _flatten(target)
        if verify:
            manifest = self.validate_step(step)
        else:
            manifest = self.manifest(step)
        data = {}
        for fn in os.listdir(path):
            if fn.startswith("shard_") and fn.endswith(".npz"):
                with np.load(os.path.join(path, fn)) as z:
                    for n in z.files:
                        arr = z[n]
                        want = manifest["leaves"].get(n, {}).get("dtype")
                        if want and str(arr.dtype) != want:
                            # np.savez stores ml_dtypes (bfloat16, fp8) as raw
                            # void bytes; reinterpret per the manifest dtype.
                            import ml_dtypes

                            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
                        data[n] = arr
        missing = [n for n in names if n not in data]
        if missing:
            raise CheckpointCorruptionError(
                f"{path}: leaves missing from shard files: {missing[:4]}"
                f"{'…' if len(missing) > 4 else ''}"
            )
        leaves = [data[n] for n in names]
        restored = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), leaves
        )
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings
            )
        return restored
