"""Sharded, atomic, async checkpointing with reshard-on-restore.

Design (1000+-node posture, §5 of DESIGN.md):
  * A checkpoint is a directory ``step_<N>/`` holding one ``shard_<i>.npz``
    per host plus a ``manifest.json`` (tree structure, global shapes, dtypes,
    step, and a completion marker written LAST).
  * Writes go to ``step_<N>.tmp/`` and are atomically renamed — a crash can
    never yield a half-readable checkpoint, and restart logic simply takes
    the newest directory with a valid manifest.
  * ``save_async`` snapshots device arrays to host memory synchronously
    (cheap) and does file I/O on a background thread so the training loop
    keeps stepping.
  * ``restore`` takes a *target sharding* pytree: arrays are re-laid-out onto
    whatever mesh the restarted job has (elastic up/down-scaling: the new
    mesh may have a different device count).
  * ``keep_last`` old checkpoints are garbage-collected after a successful
    save.

On a single-process CPU container every array is fully addressable so there
is exactly one shard file; the shard-per-host layout and the manifest format
are what a multi-host deployment needs (each host writes
``shard_<process_index>.npz`` covering its addressable subset).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: Any) -> tuple[list[str], list[Any]]:
    paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for kp, leaf in paths:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            elif hasattr(k, "name"):
                parts.append(str(k.name))
            else:
                parts.append(str(k))
        names.append("/".join(parts) if parts else "_root")
        leaves.append(leaf)
    return names, leaves


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any) -> str:
        """Synchronous atomic save; returns the checkpoint path."""
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot to host now, write on a background thread."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            try:
                self._write(step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any) -> str:
        names, leaves = _flatten(host_tree)
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        shard_id = jax.process_index() if jax.process_count() > 1 else 0
        np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"),
                 **{n: l for n, l in zip(names, leaves)})
        manifest = {
            "step": step,
            "time": time.time(),
            "num_shards": max(1, jax.process_count()),
            "leaves": {n: {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
                       for n, l in zip(names, leaves)},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            m = _STEP_RE.match(d)
            if m and os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any | None = None) -> Any:
        """Restore into the structure of ``target``; re-shard if asked.

        ``target`` provides the pytree structure (values ignored);
        ``shardings`` (same structure, NamedSharding leaves) lays leaves out
        on the current mesh — which may differ from the saving mesh
        (elastic restart).
        """
        path = os.path.join(self.directory, f"step_{step}")
        names, _ = _flatten(target)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = {}
        for fn in os.listdir(path):
            if fn.startswith("shard_") and fn.endswith(".npz"):
                with np.load(os.path.join(path, fn)) as z:
                    for n in z.files:
                        arr = z[n]
                        want = manifest["leaves"].get(n, {}).get("dtype")
                        if want and str(arr.dtype) != want:
                            # np.savez stores ml_dtypes (bfloat16, fp8) as raw
                            # void bytes; reinterpret per the manifest dtype.
                            import ml_dtypes

                            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
                        data[n] = arr
        leaves = [data[n] for n in names]
        restored = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), leaves
        )
        if shardings is not None:
            restored = jax.tree.map(
                lambda a, s: jax.device_put(a, s), restored, shardings
            )
        return restored
