"""Targeted (query-conditioned) selection over partition winners.

The auto-labeling / active-learning shape: you hold a handful of exemplar
embeddings of a slice you care about (here: one Gaussian mode of a mixture)
and want the k ground-set rows that best *cover the queries*, not the whole
dataset.  The ``milo_targeted`` registry selector runs query facility
location — f(S) = Σ_q max_{a∈S} sim(a, q) — through the same two-level
partition→greedy→refine pipeline as ``milo_hier``, so it scales to ground
sets where a flat query sweep would not fit.

The script contrasts it with untargeted hierarchical selection: the
targeted subset concentrates on the query mode (high hit-rate), the
untargeted one spreads over all modes.

Run:  PYTHONPATH=src python examples/targeted_selection.py
"""
import numpy as np

from repro.data.datasets import GaussianMixtureDataset
from repro.selection import build_selector


def _coverage(feats: np.ndarray, idx: np.ndarray, queries: np.ndarray) -> float:
    """Mean over queries of the best cosine similarity inside the subset —
    the (rescaled) query-FL objective the targeted selector maximizes."""
    def unit(a):
        return a / np.linalg.norm(a, axis=1, keepdims=True)
    sim = 0.5 + 0.5 * unit(feats[idx].astype(np.float64)) @ unit(
        queries.astype(np.float64)).T
    return float(sim.max(axis=0).mean())


def main():
    ds = GaussianMixtureDataset(n=4000, n_classes=8, dim=32, seed=0)
    feats, labs = ds.features(), ds.y

    # the slice we care about: 20 exemplars of class 3.  Keep k below the
    # query count so every pick buys query coverage — query FL saturates
    # once each query has a near-duplicate in the subset, and picks past
    # that point are zero-gain ties
    target = 3
    rng = np.random.default_rng(0)
    q_idx = rng.choice(np.where(labs == target)[0], size=20, replace=False)
    queries = feats[q_idx]
    k = 10

    targeted = build_selector(
        "milo_targeted", features=feats, queries=queries, k=k,
        labels=labs, partition="by_class", refine_factor=4,
    )
    idx_t = targeted.plan(0).indices
    hit = float(np.mean(labs[idx_t] == target))
    print(f"milo_targeted: k={k} queries={len(queries)} "
          f"partitions={targeted.info['n_partitions']} "
          f"union={targeted.info['union_size']}")
    print(f"  query coverage={_coverage(feats, idx_t, queries):.4f}  "
          f"fraction in query class {target}: {hit:.2f}")

    untargeted = build_selector(
        "milo_hier", features=feats, k=k, labels=labs,
        partition="by_class", refine_factor=2,
    )
    idx_u = untargeted.plan(0).indices
    base = float(np.mean(labs[idx_u] == target))
    print(f"milo_hier (untargeted): "
          f"query coverage={_coverage(feats, idx_u, queries):.4f}  "
          f"fraction in query class {target}: {base:.2f}")
    assert _coverage(feats, idx_t, queries) > _coverage(feats, idx_u, queries)
    assert hit > base, "targeted selection must concentrate on the query slice"
    print("ok: targeted plan covers the query slice")


if __name__ == "__main__":
    main()
