"""Quickstart: MILO end-to-end in ~40 lines.

1. Build a dataset + frozen-encoder features.
2. One-time preprocessing -> MiloMetadata (the shareable artifact).
3. Train a classifier on the easy-to-hard curriculum.
4. Train a SECOND model from the SAME metadata — zero extra selection cost:
   the model-agnostic claim in action.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from benchmarks.common import train_with_selector
from repro.core import CurriculumConfig, MiloPreprocessor, MiloSelector
from repro.data.datasets import GaussianMixtureDataset
from repro.data.pipeline import FullSelector


def main():
    ds = GaussianMixtureDataset(n=1500, n_classes=6, dim=24, seed=0)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    tx, ty = ds.features()[te], ds.y[te]

    # --- 1x preprocessing ---------------------------------------------------
    t0 = time.time()
    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=6)
    md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))
    md.save("/tmp/milo_quickstart.npz")
    print(f"preprocessed {len(tr)} samples -> k={md.k} in {time.time()-t0:.1f}s")

    # --- full-data skyline ----------------------------------------------------
    full = train_with_selector(feats, labs, FullSelector(len(tr)), epochs=40,
                               test_x=tx, test_y=ty)
    print(f"FULL       acc={full['final_acc']:.4f}  time={full['train_time']:.1f}s")

    # --- model 1 on MILO subsets ---------------------------------------------
    sel = MiloSelector(md, CurriculumConfig(total_epochs=40, kappa=1 / 6, R=1))
    m1 = train_with_selector(feats, labs, sel, epochs=40, test_x=tx, test_y=ty)
    print(f"MILO (10%) acc={m1['final_acc']:.4f}  time={m1['train_time']:.1f}s  "
          f"speedup={full['train_time']/m1['train_time']:.1f}x")

    # --- model 2 reuses the metadata (different seed/model init) -------------
    sel2 = MiloSelector(md, CurriculumConfig(total_epochs=40, kappa=1 / 6, R=1), seed=1)
    m2 = train_with_selector(feats, labs, sel2, epochs=40, test_x=tx, test_y=ty, seed=1)
    print(f"MILO again acc={m2['final_acc']:.4f}  (selection cost: 0 — amortized)")


if __name__ == "__main__":
    main()
