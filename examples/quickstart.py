"""Quickstart: MILO end-to-end through the ``MiloSession`` facade.

1. Build a dataset + frozen-encoder features.
2. ``session.preprocess`` — one-time pass producing the shareable artifact.
3. ``session.train`` — a classifier on the easy-to-hard curriculum.
4. Train a SECOND model from the SAME artifact, loaded from disk by a fresh
   session — zero extra selection cost: the model-agnostic claim in action.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.data.datasets import GaussianMixtureDataset
from repro.selection import MiloSession, MiloSessionConfig

ARTIFACT = "/tmp/milo_quickstart.npz"


def main():
    ds = GaussianMixtureDataset(n=4000, n_classes=6, dim=32, seed=0)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    tx, ty = ds.features()[te], ds.y[te]

    cfg = MiloSessionConfig(
        subset_fraction=0.1, n_sge_subsets=6, total_epochs=40,
        hidden=256, sub_steps=8,          # big enough to be compute-, not
        metadata_path=ARTIFACT,           # overhead-bound at CPU scale
    )
    session = MiloSession(cfg)

    # --- 1x preprocessing ----------------------------------------------------
    t0 = time.time()
    md = session.preprocess(feats, labs, force=True)
    print(f"preprocessed {len(tr)} samples -> k={md.k} in {time.time()-t0:.1f}s "
          f"(artifact {ARTIFACT}, config hash {md.config_hash()})")

    # --- full-data skyline ---------------------------------------------------
    full = session.train(feats, labs, test_x=tx, test_y=ty, selector="full")
    print(f"FULL       acc={full.final_acc:.4f}  time={full.train_time:.1f}s")

    # --- model 1 on MILO subsets ---------------------------------------------
    m1 = session.train(feats, labs, test_x=tx, test_y=ty)
    print(f"MILO (10%) acc={m1.final_acc:.4f}  time={m1.train_time:.1f}s  "
          f"speedup={full.train_time/m1.train_time:.1f}x")

    # --- model 2: a FRESH session loads the saved artifact -------------------
    session2 = MiloSession(cfg)
    session2.preprocess(feats, labs)          # loads; does not recompute
    assert session2.loaded_from_artifact, "artifact must be reused, not rebuilt"
    m2 = session2.train(feats, labs, test_x=tx, test_y=ty, seed=1)
    print(f"MILO again acc={m2.final_acc:.4f}  (selection cost: 0 — amortized; "
          f"artifact loaded from disk)")


if __name__ == "__main__":
    main()
