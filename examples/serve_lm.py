"""Serving example: batched decode with the slot-pool engine.

Loads (initializes) an assigned-arch smoke model, submits a burst of
requests larger than the slot pool, and streams completions — the serving
counterpart of the training driver.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch yi-6b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve.lm_engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.smoke(args.arch)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, max_batch=3, max_len=64)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 12))).astype(np.int32)
        eng.submit(Request(rid, prompt, max_new_tokens=args.max_new))

    done = eng.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.generated}")
    print(f"{len(done)} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU smoke config)")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
