"""Selection-as-a-service example: one warm ``MiloServer``, several tenants
submitting concurrent tuning requests that share a single preprocessing
artifact and one set of device-resident feature buffers.

Run:  PYTHONPATH=src python examples/serve_selection.py
"""
import tempfile
import time

from repro.data.datasets import GaussianMixtureDataset
from repro.selection import MiloSessionConfig
from repro.serve import MiloClient, MiloServer

SPACE = {"lr": ("log", 3e-3, 0.3)}
N_TENANTS = 3


def main():
    ds = GaussianMixtureDataset(n=1200, n_classes=6, dim=24, seed=0)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    vx, vy = ds.features()[va], ds.y[va]

    cfg = MiloSessionConfig(
        subset_fraction=0.1, n_sge_subsets=4, total_epochs=30,
        eval_every_epochs=10, gram_free=True, fused_training=True,
    )
    with MiloServer(cfg, store_root=tempfile.mkdtemp()) as server:
        # pay preprocessing + every compile ONCE, before traffic arrives
        t0 = time.time()
        warm = server.warm(feats, labs, val_x=vx, val_y=vy, space=SPACE)
        print(f"warm: {warm} ({time.time()-t0:.1f}s)")

        # N tenants submit tuning runs; each gets its own search seed but
        # every request resolves to the same cached artifact
        t0 = time.time()
        rids = [
            MiloClient(server, tenant=f"tenant-{i}").submit_tune(
                feats, labs, vx, vy, SPACE,
                max_budget=9, eta=3, seed=100 + i, deadline=300.0,
            )
            for i in range(N_TENANTS)
        ]
        for rid in rids:
            res = server.result(rid)
            row = server.poll(rid)
            print(f"{rid} [{row['tenant']:9s}] best={res.best_score:.4f} "
                  f"config={res.best_config} artifact={row['artifact_source']}")
        print(f"{N_TENANTS} tuning runs in {time.time()-t0:.1f}s "
              f"(shared artifact, zero re-preprocessing)")
        print("server stats:", server.stats())


if __name__ == "__main__":
    main()
