"""End-to-end driver (deliverable b): train an assigned-architecture LM on
MILO-selected data with checkpointing + restart.

Trains the granite-moe smoke config for a few hundred steps on the synthetic
LM corpus, with MILO's curriculum choosing the document subset each epoch,
then kills and resumes from the checkpoint to demonstrate fault tolerance.

Run:  PYTHONPATH=src python examples/train_lm_milo.py [--steps 200]
"""
import argparse
import shutil
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core import MiloPreprocessor
from repro.data.datasets import TokenLMDataset
from repro.data.pipeline import Pipeline
from repro.optim.optimizers import adamw
from repro.optim.schedules import cosine
from repro.selection import build_selector
from repro.train.train_state import init_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/milo_lm_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)
    cfg = registry.smoke(args.arch)
    ds = TokenLMDataset(n_docs=256, seq_len=64, vocab=cfg.vocab_size, seed=0)

    # MILO preprocessing over document features (frozen-encoder stand-in)
    pre = MiloPreprocessor(subset_fraction=0.5, n_sge_subsets=4, classwise=False)
    md = pre.preprocess(ds.features(), None, jax.random.PRNGKey(0))

    batch_size = 16
    steps_per_epoch = md.k // batch_size
    epochs = max(1, args.steps // steps_per_epoch)
    sel = build_selector("milo", metadata=md, total_epochs=epochs, kappa=1 / 6, R=1)
    pipe = Pipeline(ds.batch, sel, batch_size, seed=0)

    opt = adamw()
    step_fn = make_train_step(cfg, opt, cosine(1e-3, args.steps, warmup=10))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    trainer = Trainer(step_fn, pipe, TrainerConfig(
        epochs=epochs, checkpoint_dir=args.ckpt, checkpoint_every_steps=10,
        log_every_steps=10))

    t0 = time.time()
    state = trainer.fit(state)
    losses = [h["loss"] for h in trainer.history if "loss" in h]
    print(f"trained {int(state.step)} steps in {time.time()-t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss must decrease"

    # --- simulate failure + restart -----------------------------------------
    print("simulating restart from checkpoint...")
    state2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)  # fresh init
    trainer2 = Trainer(step_fn, pipe, TrainerConfig(
        epochs=epochs, checkpoint_dir=args.ckpt, log_every_steps=10))
    resumed = trainer2.fit(state2, resume=True)
    a = np.asarray(jax.tree.leaves(state.params)[0], np.float32)
    b = np.asarray(jax.tree.leaves(resumed.params)[0], np.float32)
    assert np.array_equal(a, b), "restart must restore the exact state"
    print("restart OK — resumed to identical parameters")


if __name__ == "__main__":
    main()
