"""Hyper-parameter tuning example: TPE + Hyperband with MILO subsets
(the paper's 20-75x tuning-speedup pipeline, CPU scale).

Run:  PYTHONPATH=src python examples/tune_hparams.py
"""
import time

import jax

from benchmarks.common import train_with_selector
from repro.core import CurriculumConfig, MiloPreprocessor, MiloSelector
from repro.data.datasets import GaussianMixtureDataset
from repro.data.pipeline import FullSelector
from repro.tuning.tuner import TPESearch, hyperband

SPACE = {"lr": ("log", 3e-3, 0.3), "hidden": ("choice", [32, 64, 128])}


def main():
    ds = GaussianMixtureDataset(n=1200, n_classes=6, dim=24, seed=0)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    vx, vy = ds.features()[va], ds.y[va]

    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4)
    md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))

    def make_objective(factory):
        def objective(cfg, budget):
            out = train_with_selector(feats, labs, factory(), epochs=max(2, budget),
                                      test_x=vx, test_y=vy, lr=cfg["lr"], eval_every=10)
            return out["final_acc"]
        return objective

    for name, factory in (
        ("FULL", lambda: FullSelector(len(tr))),
        ("MILO-10%", lambda: MiloSelector(md, CurriculumConfig(total_epochs=30, kappa=1 / 6))),
    ):
        t0 = time.time()
        res = hyperband(make_objective(factory), TPESearch(SPACE, seed=0),
                        max_budget=9, eta=3)
        print(f"{name:9s} best={res.best_score:.4f} "
              f"config={res.best_config} trials={len(res.trials)} "
              f"wall={time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
