"""Hyper-parameter tuning example: TPE + Hyperband with MILO subsets through
``MiloSession.tune`` (the paper's 20-75x tuning-speedup pipeline, CPU scale).

Run:  PYTHONPATH=src python examples/tune_hparams.py
"""
import time

from repro.data.datasets import GaussianMixtureDataset
from repro.selection import MiloSession, MiloSessionConfig

SPACE = {"lr": ("log", 3e-3, 0.3), "hidden": ("choice", [32, 64, 128])}


def main():
    ds = GaussianMixtureDataset(n=1200, n_classes=6, dim=24, seed=0)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    vx, vy = ds.features()[va], ds.y[va]

    session = MiloSession(MiloSessionConfig(
        subset_fraction=0.1, n_sge_subsets=4, total_epochs=30, eval_every_epochs=10,
    ))
    session.preprocess(feats, labs)

    for name in ("full", "milo"):
        t0 = time.time()
        res = session.tune(feats, labs, vx, vy, SPACE,
                           selector=name, search="tpe", max_budget=9, eta=3)
        print(f"{name:9s} best={res.best_score:.4f} "
              f"config={res.best_config} trials={len(res.trials)} "
              f"wall={time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
