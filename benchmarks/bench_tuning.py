"""Paper Fig. 7 + Table 9/10: hyper-parameter tuning with MILO subsets —
Random/TPE search x Hyperband, speedup vs accuracy tradeoff, and Kendall-tau
hyper-parameter ordering retention vs full-data tuning.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import csv_row, train_with_selector
from repro.core import MiloPreprocessor
from repro.data.datasets import GaussianMixtureDataset
from repro.selection import build_selector
from repro.tuning.tuner import RandomSearch, TPESearch, hyperband, kendall_tau

SPACE = {"lr": ("log", 3e-3, 0.3), "hidden": ("choice", [32, 64, 128])}


def _objective_factory(feats, labs, vx, vy, selector_factory, epochs_scale=1.0):
    def objective(cfg, budget):
        sel = selector_factory()
        out = train_with_selector(
            feats, labs, sel, epochs=max(2, int(budget * epochs_scale)),
            test_x=vx, test_y=vy, lr=cfg["lr"], seed=0, eval_every=10,
        )
        return out["final_acc"]

    return objective


def run(verbose: bool = True) -> list[str]:
    ds = GaussianMixtureDataset(n=1200, n_classes=6, dim=24, seed=2)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    vx, vy = ds.features()[va], ds.y[va]
    rows = []

    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4, gram_block=512)
    md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))
    k = md.k

    factories = {
        "full": lambda: build_selector("full", n=len(tr)),
        "milo": lambda: build_selector("milo", metadata=md, total_epochs=30, kappa=1 / 6),
        "random": lambda: build_selector("random", n=len(tr), k=k, seed=0),
        "adaptive_random": lambda: build_selector("adaptive_random", n=len(tr), k=k, R=1),
    }
    results = {}
    for sname, search_cls in (("random_hb", RandomSearch), ("tpe_hb", TPESearch)):
        base_time = None
        for fname, factory in factories.items():
            t0 = time.perf_counter()
            res = hyperband(_objective_factory(feats, labs, vx, vy, factory),
                            search_cls(SPACE, seed=0), max_budget=9, eta=3)
            wall = time.perf_counter() - t0
            if fname == "full":
                base_time = wall
            results[(sname, fname)] = res
            speedup = base_time / wall if base_time else 1.0
            rows.append(csv_row(
                f"tuning/{sname}/{fname}", wall * 1e6,
                f"best={res.best_score:.4f} speedup={speedup:.2f} trials={len(res.trials)}"))
            if verbose:
                print(rows[-1])

    # Kendall-tau ordering retention (Tab. 9): rank a fixed config grid by
    # full-data score vs subset scores (2-seed means, 8 grid points, with the
    # curriculum horizon matched to the actual budget).
    grid = [{"lr": lr} for lr in (0.003, 0.007, 0.015, 0.03, 0.07, 0.15, 0.25, 0.3)]
    k_epochs = 12

    tau_factories = dict(factories)
    tau_factories["milo"] = lambda: build_selector(
        "milo", metadata=md, total_epochs=k_epochs, kappa=1 / 6)

    def scores_with(factory):
        out = np.zeros(len(grid))
        for seed in (0, 1):
            out += np.asarray([
                train_with_selector(feats, labs, factory(), epochs=k_epochs,
                                    test_x=vx, test_y=vy, lr=c["lr"], seed=seed,
                                    eval_every=20)["final_acc"]
                for c in grid
            ])
        return out / 2

    full_scores = scores_with(tau_factories["full"])
    for fname in ("milo", "random", "adaptive_random"):
        tau = kendall_tau(full_scores, scores_with(tau_factories[fname]))
        rows.append(csv_row(f"tuning/kendall_tau/{fname}", 0, f"tau={tau:.4f}"))
        if verbose:
            print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
