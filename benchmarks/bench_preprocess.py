"""Paper App. H.3: pre-processing cost and its amortization, plus selection
throughput microbenchmarks (the jit-compiled greedy engines)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import MiloPreprocessor, gram_matrix, greedy, sge, stochastic_greedy
from repro.core.greedy import stochastic_candidate_count
from repro.core.submodular import facility_location, graph_cut
from repro.data.datasets import GaussianMixtureDataset


def run(verbose: bool = True) -> list[str]:
    rows = []
    # full preprocessing wall time vs dataset size
    for n in (1000, 4000):
        ds = GaussianMixtureDataset(n=n, n_classes=10, dim=32, seed=0)
        pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4, gram_block=1024)
        t0 = time.perf_counter()
        md = pre.preprocess(ds.features(), ds.y, jax.random.PRNGKey(0))
        dt = time.perf_counter() - t0
        rows.append(csv_row(f"preprocess/full_n{n}", dt * 1e6,
                            f"k={md.k} per_sample_us={dt/n*1e6:.1f}"))
        if verbose:
            print(rows[-1])

    # jit-compiled greedy engine throughput (whole-run-on-device; the
    # beyond-paper replacement for submodlib's per-element host loop)
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(2048, 64)).astype(np.float32))
    K = gram_matrix(z)
    for name, fn in (("facility_location", facility_location), ("graph_cut", graph_cut)):
        k = 205
        greedy(fn, K, k).indices.block_until_ready()  # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            greedy(fn, K, k).indices.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        rows.append(csv_row(f"preprocess/greedy_{name}_n2048_k205", dt * 1e6,
                            f"per_element_us={dt/k*1e6:.1f}"))
        if verbose:
            print(rows[-1])

    s = stochastic_candidate_count(2048, 205, 0.01)
    stochastic_greedy(facility_location, K, 205, jax.random.PRNGKey(0), s=s).indices.block_until_ready()
    t0 = time.perf_counter()
    stochastic_greedy(facility_location, K, 205, jax.random.PRNGKey(1), s=s).indices.block_until_ready()
    dt = time.perf_counter() - t0
    rows.append(csv_row("preprocess/stochastic_greedy_n2048_k205", dt * 1e6,
                        f"candidates_per_step={s}"))
    if verbose:
        print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
